"""Mamba2 (SSD) mixer — chunked parallel training form + O(1) decode step.

The state-space recurrence per head h with scalar decay ``a_t`` and input/
output projections B_t, C_t (state dim N, head dim P):

    H_t = a_t * H_{t-1} + B_t x_t^T          H in R^{N x P}
    y_t = C_t^T H_t

Training uses the SSD block decomposition (Mamba2 paper §6): within-chunk
quadratic term + between-chunk state scan, so the materialized state tensor
is only [B, n_chunks, heads, N, P]. Decode keeps (conv_state, ssm_state) and
advances in O(1) per token — this is what makes ``long_500k`` a runnable
cell for the SSM/hybrid architectures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.partitioning import ParamDef, constrain

__all__ = [
    "mamba_defs", "mamba_seq", "mamba_decode_step", "init_mamba_cache",
]

_CONV_K = 4


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    return d_inner, heads, cfg.ssm_state, cfg.ssm_head_dim


def mamba_defs(cfg):
    d = cfg.d_model
    d_inner, H, N, P = _dims(cfg)
    conv_dim = d_inner + 2 * N  # x, B, C go through the causal conv
    return {
        "w_in": ParamDef(
            (d, 2 * d_inner + 2 * N + H), ("embed", "mlp")
        ),  # [z, x, B, C, dt]
        "conv_w": ParamDef((_CONV_K, conv_dim), ("conv", "mlp")),
        "conv_b": ParamDef((conv_dim,), ("mlp",), init="zeros"),
        "a_log": ParamDef((H,), ("ssm_heads",), init="zeros"),
        "dt_bias": ParamDef((H,), ("ssm_heads",), init="zeros"),
        "d_skip": ParamDef((H,), ("ssm_heads",), init="ones"),
        "norm": {"scale": ParamDef((d_inner,), ("mlp",), init="ones")},
        "w_out": ParamDef((d_inner, d), ("mlp", "embed")),
    }


def _split_proj(p, cfg, x):
    d_inner, H, N, P = _dims(cfg)
    ct = x.dtype
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(ct))
    z, xin, Bc, Cc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1,
    )
    return z, xin, Bc, Cc, dt


def _gated_norm(p, x, z, eps=1e-6):
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def mamba_seq(p, cfg, x):
    """Full-sequence (train / prefill) forward.

    x[B, S, d] -> ([B, S, d], final_state) — final_state seeds decode.
    """
    B, S, d = x.shape
    d_inner, H, N, P = _dims(cfg)
    Lc = min(cfg.ssm_chunk, S)
    assert S % Lc == 0, (S, Lc)
    nc = S // Lc
    ct = x.dtype

    z, xin, Bc, Cc, dt = _split_proj(p, cfg, x)
    # causal depthwise conv over (x, B, C)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv = jnp.pad(conv_in, ((0, 0), (_CONV_K - 1, 0), (0, 0)))
    win = jnp.stack(
        [conv[:, i : i + S] for i in range(_CONV_K)], axis=-1
    )  # [B, S, conv_dim, K]
    conv_out = jax.nn.silu(
        jnp.einsum("bsck,kc->bsc", win, p["conv_w"].astype(ct))
        + p["conv_b"].astype(ct)
    )
    xin, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                   # [B, S, H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))        # [H]
    la = dt * a[None, None, :]                          # log decay, <= 0

    xh = xin.reshape(B, S, H, P).astype(jnp.float32)
    xh = xh * dt[..., None]                             # fold dt into input
    Bf = Bc.astype(jnp.float32)                         # [B, S, N] (shared)
    Cf = Cc.astype(jnp.float32)

    # --- chunked SSD ---
    lac = la.reshape(B, nc, Lc, H)
    cum = jnp.cumsum(lac, axis=2)                       # within-chunk cumsum
    total = cum[:, :, -1, :]                            # [B, nc, H]
    xc = xh.reshape(B, nc, Lc, H, P)
    Bcc = Bf.reshape(B, nc, Lc, N)
    Ccc = Cf.reshape(B, nc, Lc, N)

    # within-chunk (quadratic in Lc): y_intra[t] = sum_{s<=t} decay * (C_t.B_s) x_s
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,t,s,H]
    tri = jnp.tril(jnp.ones((Lc, Lc), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bctn,bcsn->bcts", Ccc, Bcc)
    y_intra = jnp.einsum("bcts,bctsh,bcshp->bcthp", cb, decay, xc)

    # chunk states: S_c = sum_s exp(total - cum_s) B_s x_s^T  [B,nc,H,N,P]
    sdecay = jnp.exp(total[:, :, None, :] - cum)        # [B,nc,Lc,H]
    states = jnp.einsum("bcsn,bcsh,bcshp->bchnp", Bcc, sdecay, xc)

    # inter-chunk scan: H_c = exp(total_c) H_{c-1} + S_c (associative)
    def comb(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 + a2, s1 * jnp.exp(a2)[..., None, None] + s2

    totals_t = jnp.moveaxis(total, 1, 0)                # [nc, B, H]
    states_t = jnp.moveaxis(states, 1, 0)               # [nc, B, H, N, P]
    _, hstates = jax.lax.associative_scan(comb, (totals_t, states_t))
    # state entering chunk c is hstates[c-1]
    h_prev = jnp.concatenate(
        [jnp.zeros_like(hstates[:1]), hstates[:-1]], axis=0
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)                 # [B, nc, H, N, P]

    # inter-chunk contribution: y_inter[t] = exp(cum_t) C_t . H_prev
    y_inter = jnp.einsum(
        "bctn,bcth,bchnp->bcthp", Ccc, jnp.exp(cum), h_prev
    )
    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(ct)

    y = _gated_norm(p["norm"], y, z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(ct))
    state = {
        "conv": conv_in[:, S - (_CONV_K - 1):, :],
        "ssm": jnp.moveaxis(hstates, 0, 1)[:, -1],  # [B, H, N, P]
    }
    return constrain(out, "batch", "seq", "act_embed"), state


def init_mamba_cache(cfg, batch, dtype):
    d_inner, H, N, P = _dims(cfg)
    conv_dim = d_inner + 2 * N
    return {
        "conv": jnp.zeros((batch, _CONV_K - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
    }


def mamba_decode_step(p, cfg, x, cache):
    """x[B, 1, d] -> ([B, 1, d], new_cache). O(1) per token."""
    B = x.shape[0]
    d_inner, H, N, P = _dims(cfg)
    ct = x.dtype
    z, xin, Bc, Cc, dt = _split_proj(p, cfg, x)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)   # [B, 1, conv_dim]
    win = jnp.concatenate([cache["conv"], conv_in], axis=1)  # [B, K, cd]
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", win, p["conv_w"].astype(ct))
        + p["conv_b"].astype(ct)
    )
    new_conv = win[:, 1:]
    xin, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)

    dtv = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                   # [B, H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dtv * a[None, :])                   # [B, H]
    xh = xin.reshape(B, H, P).astype(jnp.float32) * dtv[..., None]
    Bf = Bc.astype(jnp.float32)                         # [B, N]
    Cf = Cc.astype(jnp.float32)
    h = cache["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bn,bhp->bhnp", Bf, xh
    )
    y = jnp.einsum("bn,bhnp->bhp", Cf, h)
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, d_inner).astype(ct)
    y = _gated_norm(p["norm"], y, z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(ct))
    return out, {"conv": new_conv, "ssm": h}
