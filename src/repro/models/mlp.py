"""Dense feed-forward sublayers (SwiGLU / GELU) and the MoE variant.

MoE uses sort-based grouped dispatch (DESIGN.md): tokens are routed top-k,
sorted by expert, gathered into a capacity-bounded ``[E, C, d]`` tensor that
shards its expert dim over the ``model`` axis (expert parallelism), run
through stacked expert weights, and combined with router weights. Dropped
tokens (over capacity) fall back to a zero contribution, standard for
capacity-factor routing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.partitioning import ParamDef, constrain

__all__ = ["mlp_defs", "mlp", "moe_defs", "moe"]


def mlp_defs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_kind == "swiglu":
        return {
            "w_gate": ParamDef((d, f), ("embed", "mlp")),
            "w_up": ParamDef((d, f), ("embed", "mlp")),
            "w_down": ParamDef((f, d), ("mlp", "embed")),
        }
    return {
        "w_up": ParamDef((d, f), ("embed", "mlp")),
        "w_down": ParamDef((f, d), ("mlp", "embed")),
    }


def mlp(p, cfg, x):
    ct = x.dtype
    if cfg.mlp_kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(ct))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(ct))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(
            jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(ct))
        )
    h = constrain(h, "batch", "seq", "act_mlp")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(ct))
    return constrain(out, "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_defs(cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamDef((d, e), ("embed", None)),
        "w_gate": ParamDef((e, d, f), ("expert", "embed", "mlp")),
        "w_up": ParamDef((e, d, f), ("expert", "embed", "mlp")),
        "w_down": ParamDef((e, f, d), ("expert", "mlp", "embed")),
    }


def moe(p, cfg, x):
    """x[B, S, d] -> [B, S, d] with top-k expert routing.

    Returns (out, aux_loss) — aux is the switch-style load-balancing loss.
    """
    B, S, d = x.shape
    e, k = cfg.n_experts, cfg.expert_top_k
    ct = x.dtype
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)          # [T, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch):  e * sum_e(frac_tokens * frac_prob)
    frac_prob = probs.mean(0)
    frac_tok = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0
    ) / (T * k)
    aux = e * jnp.sum(frac_prob * frac_tok)

    # sort the T*k assignments by expert
    flat_e = top_e.reshape(-1)                       # [T*k]
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]

    # position of each assignment within its expert group
    C = int((T * k / e) * cfg.moe_capacity_factor) + 1
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
    )
    pos = jnp.arange(T * k) - starts[se]
    keep = pos < C

    # gather tokens into [E, C, d] (dropped -> slot C-1 overwritten later is
    # avoided by scattering with a mask)
    slot = jnp.where(keep, se * C + pos, e * C)      # spill to a trash slot
    disp = jnp.zeros((e * C + 1, d), ct).at[slot].set(xt[st].astype(ct))
    disp = disp[: e * C].reshape(e, C, d)
    disp = constrain(disp, "act_expert", None, None)

    h_g = jnp.einsum("ecd,edf->ecf", disp, p["w_gate"].astype(ct))
    h_u = jnp.einsum("ecd,edf->ecf", disp, p["w_up"].astype(ct))
    h = jax.nn.silu(h_g) * h_u
    h = constrain(h, "act_expert", None, "act_mlp")
    eo = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(ct))
    eo = constrain(eo, "act_expert", None, None).reshape(e * C, d)

    # combine back: each kept assignment adds w * expert_out to its token
    gath = jnp.where(keep[:, None], eo[jnp.clip(se * C + pos, 0, e * C - 1)],
                     0.0)
    out = jnp.zeros((T, d), ct).at[st].add(
        gath * sw[:, None].astype(ct)
    )
    out = out.reshape(B, S, d)
    return constrain(out, "batch", "seq", "act_embed"), aux
