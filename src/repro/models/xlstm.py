"""xLSTM blocks: mLSTM (matrix memory, parallel + recurrent forms) and
sLSTM (scalar memory, recurrent) — arXiv:2405.04517, simplified block wiring.

mLSTM training uses the stabilized parallel (quadratic) form; decode is the
O(1) recurrent update, which is why xlstm-125m runs the ``long_500k`` cell.
sLSTM is inherently recurrent (lax.scan over time).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.partitioning import ParamDef, constrain

__all__ = [
    "mlstm_defs", "mlstm_seq", "mlstm_decode_step", "init_mlstm_cache",
    "slstm_defs", "slstm_seq", "slstm_decode_step", "init_slstm_cache",
]

_CONV_K = 4


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mdims(cfg):
    d_inner = 2 * cfg.d_model
    dh = d_inner // cfg.n_heads
    return d_inner, cfg.n_heads, dh


def mlstm_defs(cfg):
    d = cfg.d_model
    d_inner, H, dh = _mdims(cfg)
    return {
        "w_up": ParamDef((d, 2 * d_inner), ("embed", "mlp")),
        "conv_w": ParamDef((_CONV_K, d_inner), ("conv", "mlp")),
        "conv_b": ParamDef((d_inner,), ("mlp",), init="zeros"),
        "wq": ParamDef((d_inner, d_inner), ("mlp", None)),
        "wk": ParamDef((d_inner, d_inner), ("mlp", None)),
        "wv": ParamDef((d_inner, d_inner), ("mlp", None)),
        "w_if": ParamDef((d_inner, 2 * H), ("mlp", None), scale=0.01),
        "b_if": ParamDef((2 * H,), (None,), init="zeros"),
        "norm": {"scale": ParamDef((d_inner,), ("mlp",), init="ones")},
        "w_down": ParamDef((d_inner, d), ("mlp", "embed")),
    }


def _causal_conv(x, w, b):  # x[B, S, C]
    S = x.shape[1]
    pad = jnp.pad(x, ((0, 0), (_CONV_K - 1, 0), (0, 0)))
    win = jnp.stack([pad[:, i : i + S] for i in range(_CONV_K)], axis=-1)
    return jax.nn.silu(jnp.einsum("bsck,kc->bsc", win, w) + b)


def mlstm_seq(p, cfg, x, chunk=256):
    """Chunkwise stabilized mLSTM (parallel within chunks, recurrent matrix
    state across chunks — keeps memory at O(S * Lc) instead of O(S^2))."""
    B, S, d = x.shape
    d_inner, H, dh = _mdims(cfg)
    ct = x.dtype
    Lc = min(chunk, S)
    assert S % Lc == 0, (S, Lc)
    nc = S // Lc

    up = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(ct))
    xi, z = jnp.split(up, 2, axis=-1)
    xc = _causal_conv(xi, p["conv_w"].astype(ct), p["conv_b"].astype(ct))
    q = jnp.einsum("bse,ef->bsf", xc, p["wq"].astype(ct))
    k = jnp.einsum("bse,ef->bsf", xc, p["wk"].astype(ct))
    v = jnp.einsum("bse,ef->bsf", xi, p["wv"].astype(ct))
    gates = (
        jnp.einsum("bse,eg->bsg", xc, p["w_if"].astype(ct))
        + p["b_if"].astype(ct)
    ).astype(jnp.float32)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)          # [B, S, H]

    def to_chunks(a, tail):
        return jnp.moveaxis(a.reshape((B, nc, Lc) + tail), 1, 0)

    qc = to_chunks(q.astype(jnp.float32).reshape(B, S, H, dh), (H, dh))
    kc = to_chunks(
        (k.astype(jnp.float32) / (dh ** 0.5)).reshape(B, S, H, dh), (H, dh)
    )
    vc = to_chunks(v.astype(jnp.float32).reshape(B, S, H, dh), (H, dh))
    ic = to_chunks(i_pre, (H,))
    fc = to_chunks(jax.nn.log_sigmoid(f_pre), (H,))
    tril = jnp.tril(jnp.ones((Lc, Lc), bool))

    def chunk_step(carry, blk):
        C_prev, n_prev, m_prev = carry
        q, k, v, i_p, logf = blk                          # [B, Lc, ...]
        fcum = jnp.cumsum(logf, axis=1)                   # [B, Lc, H]
        dtil = (
            fcum[:, :, None, :] - fcum[:, None, :, :] + i_p[:, None, :, :]
        )
        dtil = jnp.where(tril[None, :, :, None], dtil, -jnp.inf)
        inter_log = fcum + m_prev[:, None, :]             # [B, Lc, H]
        m_t = jnp.maximum(jnp.max(dtil, axis=2), inter_log)
        Dl = jnp.exp(dtil - m_t[:, :, None, :])
        scores = jnp.einsum("bthd,bshd->btsh", q, k) * Dl
        inter_w = jnp.exp(inter_log - m_t)                # [B, Lc, H]
        num = jnp.einsum("btsh,bshd->bthd", scores, v) + inter_w[
            ..., None
        ] * jnp.einsum("bthd,bhde->bthe", q, C_prev)
        qn = jnp.einsum("bthd,bhd->bth", q, n_prev)
        den = jnp.maximum(
            jnp.abs(scores.sum(axis=2) + inter_w * qn), jnp.exp(-m_t)
        )
        h = num / den[..., None]                          # [B, Lc, H, dh]
        # end-of-chunk state
        total = fcum[:, -1, :]                            # [B, H]
        su = total[:, None, :] - fcum + i_p               # [B, s, H]
        m_next = jnp.maximum(total + m_prev, jnp.max(su, axis=1))
        w_s = jnp.exp(su - m_next[:, None, :])
        carry_w = jnp.exp(total + m_prev - m_next)
        C_next = carry_w[..., None, None] * C_prev + jnp.einsum(
            "bsh,bshd,bshe->bhde", w_s, k, v
        )
        n_next = carry_w[..., None] * n_prev + jnp.einsum(
            "bsh,bshd->bhd", w_s, k
        )
        return (C_next, n_next, m_next), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    (Cf, nf, mf), hs = jax.lax.scan(
        chunk_step, (C0, n0, m0), (qc, kc, vc, ic, fc),
        unroll=True if cfg.scan_unroll else 1,
    )
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d_inner).astype(ct)

    h = L.rms_norm(p["norm"], h) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h, p["w_down"].astype(ct))
    state = {
        "conv": xi[:, S - (_CONV_K - 1):, :], "c": Cf, "n": nf, "m": mf,
    }
    return constrain(out, "batch", "seq", "act_embed"), state


def init_mlstm_cache(cfg, batch, dtype):
    d_inner, H, dh = _mdims(cfg)
    return {
        "conv": jnp.zeros((batch, _CONV_K - 1, d_inner), dtype),
        "c": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode_step(p, cfg, x, cache):
    B = x.shape[0]
    d_inner, H, dh = _mdims(cfg)
    ct = x.dtype
    up = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(ct))
    xi, z = jnp.split(up, 2, axis=-1)
    win = jnp.concatenate([cache["conv"], xi], axis=1)
    xc = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", win, p["conv_w"].astype(ct))
        + p["conv_b"].astype(ct)
    )
    q = (xc @ p["wq"].astype(ct)).reshape(B, H, dh).astype(jnp.float32)
    k = (xc @ p["wk"].astype(ct)).reshape(B, H, dh).astype(
        jnp.float32
    ) / (dh ** 0.5)
    v = (xi[:, 0] @ p["wv"].astype(ct)).reshape(B, H, dh).astype(jnp.float32)
    gates = (
        xc @ p["w_if"].astype(ct) + p["b_if"].astype(ct)
    ).astype(jnp.float32)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)          # [B, H]
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + cache["m"], i_pre)
    fs = jnp.exp(logf + cache["m"] - m_new)[..., None]
    is_ = jnp.exp(i_pre - m_new)[..., None]
    c = cache["c"] * fs[..., None] + is_[..., None] * jnp.einsum(
        "bhd,bhe->bhde", k, v
    )
    n = cache["n"] * fs + is_ * k
    num = jnp.einsum("bhde,bhd->bhe", c, q)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), jnp.exp(-m_new)
    )
    h = (num / den[..., None]).reshape(B, 1, d_inner).astype(ct)
    h = L.rms_norm(p["norm"], h) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h, p["w_down"].astype(ct))
    cache = {"conv": win[:, 1:], "c": c, "n": n, "m": m_new}
    return out, cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_defs(cfg):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    return {
        "w_gates": ParamDef((d, 4 * d), ("embed", "mlp")),
        "r_gates": ParamDef((H, dh, 4 * dh), ("ssm_heads", None, None),
                            scale=0.01),
        "b_gates": ParamDef((4 * d,), (None,), init="zeros"),
        "norm": {"scale": ParamDef((d,), (None,), init="ones")},
        "w_down": ParamDef((d, d), ("embed", None)),
    }


def _slstm_cell(p, cfg, xt, state):
    """One sLSTM step. xt[B, 4d] pre-projected gates; state dict."""
    B = xt.shape[0]
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    h, c, n, m = state["h"], state["c"], state["n"], state["m"]
    rec = jnp.einsum(
        "bhd,hdg->bhg", h.reshape(B, H, dh), p["r_gates"].astype(jnp.float32)
    ).reshape(B, 4 * d)
    g = xt.astype(jnp.float32) + rec + p["b_gates"].astype(jnp.float32)
    i_pre, f_pre, z_pre, o_pre = jnp.split(g, 4, axis=-1)  # [B, d]
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + m - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c_new = f_g * c + i_g * z
    n_new = f_g * n + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def init_slstm_cache(cfg, batch, dtype):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": z + 1e-6, "m": z - 1e30}


def slstm_seq(p, cfg, x):
    """Recurrent scan over time (sLSTM has no parallel form)."""
    B, S, d = x.shape
    ct = x.dtype
    xg = jnp.einsum("bsd,dg->bsg", x, p["w_gates"].astype(ct))

    def step(state, xt):
        new = _slstm_cell(p, cfg, xt, state)
        return new, new["h"]

    state0 = init_slstm_cache(cfg, B, ct)
    final, hs = jax.lax.scan(step, state0, jnp.moveaxis(xg, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(ct)                # [B, S, d]
    h = L.rms_norm(p["norm"], h)
    out = jnp.einsum("bsd,de->bse", h, p["w_down"].astype(ct))
    return constrain(out, "batch", "seq", "act_embed"), final


def slstm_decode_step(p, cfg, x, cache):
    ct = x.dtype
    xg = jnp.einsum("bsd,dg->bsg", x, p["w_gates"].astype(ct))
    new = _slstm_cell(p, cfg, xg[:, 0], cache)
    h = L.rms_norm(p["norm"], new["h"][:, None].astype(ct))
    out = jnp.einsum("bsd,de->bse", h, p["w_down"].astype(ct))
    return out, new
