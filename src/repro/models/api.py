"""Unified model API: one entry point per config regardless of family.

    model = Model(cfg)
    params = model.init(key)            # or model.abstract() for dry-run
    loss, metrics = model.loss(params, batch)
    logits, cache = model.prefill(params, **inputs)
    logits, cache = model.decode(params, token, cache, pos)
    emb = model.embed(params, tokens)   # mean-pooled hidden (RFANN producer)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import encdec, transformer
from repro.sharding import partitioning as part

__all__ = ["Model", "count_params"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    @property
    def is_encdec(self) -> bool:
        return self.cfg.family == "encdec"

    # -- params ------------------------------------------------------------
    def defs(self):
        mod = encdec if self.is_encdec else transformer
        return mod.defs(self.cfg)

    def init(self, key):
        return part.init_params(
            self.defs(), key, jnp.dtype(self.cfg.param_dtype)
        )

    def abstract(self):
        return part.abstract_params(
            self.defs(), jnp.dtype(self.cfg.param_dtype)
        )

    def param_specs(self, mesh):
        return part.param_specs(self.defs(), mesh)

    def param_shardings(self, mesh):
        return part.named_shardings(self.defs(), mesh)

    # -- compute -----------------------------------------------------------
    def loss(self, params, batch):
        mod = encdec if self.is_encdec else transformer
        return mod.loss_fn(params, self.cfg, batch)

    def prefill(self, params, **inputs):
        if self.is_encdec:
            return encdec.prefill(
                params, self.cfg, inputs["frames"], inputs["tokens"]
            )
        return transformer.prefill(params, self.cfg, inputs["tokens"])

    def decode(self, params, token, cache, pos):
        mod = encdec if self.is_encdec else transformer
        return mod.decode_step(params, self.cfg, token, cache, pos)

    def init_cache(self, batch, max_len, *, seq_shard=False):
        if self.is_encdec:
            return encdec.init_cache(
                self.cfg, batch, max_len, seq_shard=seq_shard
            )
        return transformer.init_cache(
            self.cfg, batch, max_len, seq_shard=seq_shard
        )

    def embed(self, params, tokens):
        """Mean-pooled final hidden state — the RFANN vector producer."""
        hidden, _, _ = transformer.forward_seq(params, self.cfg, tokens)
        return jnp.mean(hidden.astype(jnp.float32), axis=1)

    # -- batch shapes (ShapeDtypeStruct; no allocation) ----------------------
    def train_batch_specs(self, batch, seq):
        cfg = self.cfg
        tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        if self.is_encdec:
            frames = jax.ShapeDtypeStruct(
                (batch, seq, cfg.d_model), jnp.dtype(cfg.compute_dtype)
            )
            return {"frames": frames, "tokens": tok, "targets": tok}
        return {"tokens": tok, "targets": tok}

    def cache_specs(self, batch, max_len, *, seq_shard=False):
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_len, seq_shard=seq_shard)
        )


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    """Parameter count from the ParamDef tree (no allocation).

    active_only: MoE experts counted at top_k/n_experts utilization
    (MODEL_FLOPS = 6 * N_active * D in the roofline).
    """
    model = Model(cfg)
    total = 0
    leaves_with_path = jax.tree_util.tree_flatten_with_path(
        model.defs(), is_leaf=lambda x: isinstance(x, part.ParamDef)
    )[0]
    for path, d in leaves_with_path:
        n = int(np.prod(d.shape))
        is_expert = "expert" in d.axes
        if active_only and is_expert and cfg.n_experts:
            n = int(n * cfg.expert_top_k / cfg.n_experts)
        # padded vocab rows are not "real" params for accounting
        if "vocab" in d.axes and cfg.padded_vocab != cfg.vocab:
            n = int(n * cfg.vocab / cfg.padded_vocab)
        total += n
    return total
