"""GQA attention sublayer: RoPE, qk-norm, local windows, softcap, KV cache.

Decode keeps the KV cache in ``[B, Hkv, Smax, Dh]`` layout (heads-major so
the model-axis sharding of ``Hkv`` never moves between steps — a layout
chosen in the §Perf iterations).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers as L
from repro.sharding.partitioning import ParamDef, constrain

__all__ = ["attn_defs", "attention", "init_kv_cache", "decode_attention"]


def attn_defs(cfg, *, cross=False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    defs = {
        "wq": ParamDef((d, hq, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((hq, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = L.rms_norm_def(hd)
        defs["k_norm"] = L.rms_norm_def(hd)
    return defs


def _project_qkv(p, cfg, x, positions, *, rope_on=True):
    ct = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(ct))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(ct))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(ct))
    if cfg.qk_norm:
        q = L.rms_norm(p["q_norm"], q)
        k = L.rms_norm(p["k_norm"], k)
    if rope_on:
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    # [B, H, S, Dh]
    q = constrain(q.transpose(0, 2, 1, 3), "batch", "act_heads", "seq", None)
    k = constrain(k.transpose(0, 2, 1, 3), "batch", "act_heads", "seq", None)
    v = constrain(v.transpose(0, 2, 1, 3), "batch", "act_heads", "seq", None)
    return q, k, v


def attention(p, cfg, x, positions, *, window=None, causal=True,
              kv=None):
    """Full-sequence attention (train / prefill).

    kv: optional precomputed (k, v) for cross-attention (seamless decoder).
    Returns (out[B, S, d], (k, v)) so prefill can seed the cache.
    """
    if kv is None:
        q, k, v = _project_qkv(p, cfg, x, positions)
    else:
        ct = x.dtype
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(ct))
        if cfg.qk_norm:
            q = L.rms_norm(p["q_norm"], q)
        q = q.transpose(0, 2, 1, 3)
        k, v = kv
    out = kops.flash_attention(
        q, k, v, causal=causal, window=window, softcap=cfg.attn_softcap,
        impl=cfg.attention_impl, unroll=True if cfg.scan_unroll else 1,
    )
    out = out.transpose(0, 2, 1, 3)  # [B, S, H, Dh]
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return constrain(out, "batch", "seq", "act_embed"), (k, v)


def cross_kv(p, cfg, enc_out):
    """Precompute cross-attention K/V from encoder output."""
    ct = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(ct))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(ct))
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def init_kv_cache(cfg, batch, max_len, dtype, *, seq_shard=False):
    """Empty per-layer KV cache [B, Hkv, Smax, Dh] x2."""
    shape = (batch, cfg.n_kv_heads, max_len, cfg.hd)
    k = jnp.zeros(shape, dtype)
    v = jnp.zeros(shape, dtype)
    seq_ax = "seq_shard" if seq_shard else None
    k = constrain(k, "batch", "act_heads", seq_ax, None)
    v = constrain(v, "batch", "act_heads", seq_ax, None)
    return {"k": k, "v": v}


def decode_attention(p, cfg, x, cache, pos, *, window=None, update=True):
    """One-token decode against the KV cache.

    x: [B, 1, d]; pos: scalar int32 (current absolute position).
    Returns (out[B, 1, d], new_cache).
    """
    ct = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(ct))
    if update:
        k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(ct))
        v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(ct))
    if cfg.qk_norm:
        q = L.rms_norm(p["q_norm"], q)
        if update:
            k_new = L.rms_norm(p["k_norm"], k_new)
    posv = jnp.full((1,), pos, jnp.int32)
    if update:  # self-attention decode (cross-attention skips rope)
        q = L.rope(q, posv, cfg.rope_theta)
    q = q.transpose(0, 2, 1, 3)                       # [B, H, 1, Dh]
    if update:
        k_new = L.rope(k_new, posv, cfg.rope_theta).transpose(0, 2, 1, 3)
        v_new = v_new.transpose(0, 2, 1, 3)
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos, axis=2)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos, axis=2)
        cache = {"k": k, "v": v}
    else:  # cross-attention: cache is static
        k, v = cache["k"], cache["v"]

    # masked softmax over the cache (XLA path: decode is a matvec; the
    # Pallas flash kernel targets the prefill/train shapes)
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    qf = q.astype(jnp.float32) * (cfg.hd ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(q.shape[0], hkv, g, cfg.hd)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, kf)
    s = L.softcap(s, cfg.attn_softcap)
    kpos = jnp.arange(k.shape[2])
    mask = kpos[None, :] <= pos if update else jnp.ones(
        (1, k.shape[2]), bool
    )
    if window is not None:
        mask = mask & (kpos[None, :] > pos - window)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", w, vf)
    out = out.reshape(q.shape[0], hq, 1, cfg.hd).transpose(0, 2, 1, 3)
    out = jnp.einsum(
        "bshk,hkd->bsd", out.astype(ct), p["wo"].astype(ct)
    )
    return constrain(out, "batch", "seq", "act_embed"), cache
