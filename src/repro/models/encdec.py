"""Encoder–decoder assembly (seamless-m4t backbone).

The modality frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings [B, S_enc, d]. The decoder is a causal stack
with cross-attention over encoder output; decode caches both the self-KV
(updated each step) and the static cross-KV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import mlp as mlp_mod
from repro.models.transformer import _remat, _stack_defs, _unroll
from repro.sharding.partitioning import ParamDef

__all__ = [
    "defs", "loss_fn", "encode", "prefill", "decode_step", "init_cache",
]


def _enc_block_defs(cfg):
    return {
        "norm1": L.rms_norm_def(cfg.d_model),
        "attn": attn_mod.attn_defs(cfg),
        "norm2": L.rms_norm_def(cfg.d_model),
        "ffn": mlp_mod.mlp_defs(cfg),
    }


def _dec_block_defs(cfg):
    return {
        "norm1": L.rms_norm_def(cfg.d_model),
        "self_attn": attn_mod.attn_defs(cfg),
        "norm_x": L.rms_norm_def(cfg.d_model),
        "cross_attn": attn_mod.attn_defs(cfg, cross=True),
        "norm2": L.rms_norm_def(cfg.d_model),
        "ffn": mlp_mod.mlp_defs(cfg),
    }


def defs(cfg):
    d = cfg.d_model
    return {
        "embed": L.embed_def(cfg.padded_vocab, d),
        "enc_in": ParamDef((d, d), ("embed", None)),  # frame-embedding adapter
        "enc_blocks": _stack_defs(_enc_block_defs(cfg), cfg.enc_layers),
        "enc_norm": L.rms_norm_def(d),
        "dec_blocks": _stack_defs(_dec_block_defs(cfg), cfg.n_layers),
        "final_norm": L.rms_norm_def(d),
    }


def encode(params, cfg, frames):
    """frames[B, S_enc, d_model] (stub frontend output) -> enc hidden."""
    ct = jnp.dtype(cfg.compute_dtype)
    x = jnp.einsum("bsd,de->bse", frames.astype(ct),
                   params["enc_in"].astype(ct))
    positions = jnp.arange(frames.shape[1])

    def body(x, bp):
        def inner(bp, x):
            h = L.rms_norm(bp["norm1"], x)
            mix, _ = attn_mod.attention(bp["attn"], cfg, h, positions,
                                        causal=False)
            x = x + mix
            h2 = L.rms_norm(bp["norm2"], x)
            return x + mlp_mod.mlp(bp["ffn"], cfg, h2)

        return _remat(inner, cfg)(bp, x), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"], unroll=_unroll(cfg))
    return L.rms_norm(params["enc_norm"], x)


def _dec_block_seq(bp, cfg, x, positions, enc_out):
    h = L.rms_norm(bp["norm1"], x)
    mix, (k, v) = attn_mod.attention(bp["self_attn"], cfg, h, positions,
                                     causal=True)
    x = x + mix
    hx = L.rms_norm(bp["norm_x"], x)
    ck, cv = attn_mod.cross_kv(bp["cross_attn"], cfg, enc_out)
    cx, _ = attn_mod.attention(
        bp["cross_attn"], cfg, hx, positions, causal=False, kv=(ck, cv)
    )
    x = x + cx
    h2 = L.rms_norm(bp["norm2"], x)
    x = x + mlp_mod.mlp(bp["ffn"], cfg, h2)
    return x, {"k": k, "v": v}, {"k": ck, "v": cv}


def decode_seq(params, cfg, tokens, enc_out, *, collect_cache=False):
    ct = jnp.dtype(cfg.compute_dtype)
    x = L.embed_lookup(params["embed"], tokens, ct)
    positions = jnp.arange(tokens.shape[1])

    def body(x, bp):
        def inner(bp, x):
            return _dec_block_seq(bp, cfg, x, positions, enc_out)

        x, sc, cc = _remat(inner, cfg)(bp, x)
        return x, ((sc, cc) if collect_cache else None)

    x, caches = jax.lax.scan(body, x, params["dec_blocks"], unroll=_unroll(cfg))
    x = L.rms_norm(params["final_norm"], x)
    return x, caches


def loss_fn(params, cfg, batch):
    """batch: frames[B, S_enc, d], tokens[B, S_dec], targets[B, S_dec]."""
    enc_out = encode(params, cfg, batch["frames"])
    hidden, _ = decode_seq(params, cfg, batch["tokens"], enc_out)
    loss = L.chunked_cross_entropy(
        params["embed"]["table"], hidden, batch["targets"], cfg
    )
    return loss, {"nll": loss, "aux": jnp.float32(0.0)}


def prefill(params, cfg, frames, tokens):
    enc_out = encode(params, cfg, frames)
    hidden, caches = decode_seq(params, cfg, tokens, enc_out,
                                collect_cache=True)
    logits = L.logits(params["embed"], None, hidden[:, -1:, :], cfg)
    return logits[:, 0], caches


def init_cache(cfg, batch, max_len, enc_len=None, *, seq_shard=False):
    ct = jnp.dtype(cfg.compute_dtype)
    enc_len = enc_len or max_len
    self_kv = attn_mod.init_kv_cache(cfg, batch, max_len, ct,
                                     seq_shard=seq_shard)
    cross_kv_c = attn_mod.init_kv_cache(cfg, batch, enc_len, ct,
                                        seq_shard=seq_shard)
    st = lambda c: jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), c
    )
    return {"self": st(self_kv), "cross": st(cross_kv_c)}


def decode_step(params, cfg, token, cache, pos):
    """One decoder token; cross-KV is static, self-KV updates."""
    ct = jnp.dtype(cfg.compute_dtype)
    x = L.embed_lookup(params["embed"], token, ct)

    def body(x, scanned):
        bp, sc, cc = scanned
        h = L.rms_norm(bp["norm1"], x)
        mix, sc2 = attn_mod.decode_attention(bp["self_attn"], cfg, h, sc,
                                             pos)
        x = x + mix
        hx = L.rms_norm(bp["norm_x"], x)
        cx, _ = attn_mod.decode_attention(
            bp["cross_attn"], cfg, hx, cc, pos, update=False
        )
        x = x + cx
        h2 = L.rms_norm(bp["norm2"], x)
        x = x + mlp_mod.mlp(bp["ffn"], cfg, h2)
        return x, sc2

    x, self_new = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"], cache["cross"]),
        unroll=_unroll(cfg),
    )
    x = L.rms_norm(params["final_norm"], x)
    logits = L.logits(params["embed"], None, x, cfg)
    return logits, {"self": self_new, "cross": cache["cross"]}
