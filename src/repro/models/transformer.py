"""Decoder-only model assembly for every assigned family.

One block skeleton with a pluggable mixer (attention / mamba2 / mLSTM /
sLSTM) + FFN (dense / MoE / none). Uniform stacks are scanned
(``lax.scan`` over stacked params — one compiled block body regardless of
depth, which keeps the 512-device dry-run compile tractable); heterogeneous
stacks (gemma2 local/global pairs, zamba2 shared-attention groups, xlstm
mixed blocks) get family-specific assembly below.

Public surface (used by train/serve/launch):
  defs(cfg)                      — ParamDef tree
  forward_seq(params, cfg, tok)  — hidden states + per-layer caches (+aux)
  loss_fn / prefill / decode_step
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import mamba2 as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import xlstm as xlstm_mod
from repro.sharding.partitioning import ParamDef, constrain

__all__ = [
    "defs", "loss_fn", "prefill", "decode_step", "init_cache",
]


# ---------------------------------------------------------------------------
# layer-kind layout per family
# ---------------------------------------------------------------------------

def layer_kinds(cfg):
    if cfg.layer_pattern == "local_global":
        return ["attn_local" if i % 2 == 0 else "attn"
                for i in range(cfg.n_layers)]
    if cfg.layer_pattern == "xlstm":
        return ["slstm" if i in cfg.slstm_layers else "mlstm"
                for i in range(cfg.n_layers)]
    if cfg.layer_pattern == "hybrid_shared_attn":
        return ["mamba"] * cfg.n_layers  # shared attn handled separately
    if cfg.layer_pattern == "ssm":
        return ["mamba"] * cfg.n_layers
    return ["attn"] * cfg.n_layers


def _mixer_defs(cfg, kind):
    if kind.startswith("attn"):
        return attn_mod.attn_defs(cfg)
    if kind == "mamba":
        return mamba_mod.mamba_defs(cfg)
    if kind == "mlstm":
        return xlstm_mod.mlstm_defs(cfg)
    if kind == "slstm":
        return xlstm_mod.slstm_defs(cfg)
    raise ValueError(kind)


def _has_ffn(cfg, kind):
    if kind in ("mlstm", "slstm"):
        return False  # xlstm blocks carry their own projections
    if cfg.layer_pattern == "hybrid_shared_attn" and kind == "mamba":
        return False  # zamba2: only the shared attention block has an MLP
    return cfg.d_ff > 0 or cfg.n_experts > 0


def block_defs(cfg, kind):
    d = cfg.d_model
    out = {"norm1": L.rms_norm_def(d), "mixer": _mixer_defs(cfg, kind)}
    if cfg.sandwich_norm:
        out["norm1b"] = L.rms_norm_def(d)
    if _has_ffn(cfg, kind):
        out["norm2"] = L.rms_norm_def(d)
        if cfg.n_experts > 0:
            out["ffn"] = mlp_mod.moe_defs(cfg)
        else:
            out["ffn"] = mlp_mod.mlp_defs(cfg)
        if cfg.sandwich_norm:
            out["norm2b"] = L.rms_norm_def(d)
    return out


def _stack_defs(defs, n):
    """Prepend a ("layers",) stacking dim to every ParamDef."""
    return jax.tree.map(
        lambda p: ParamDef((n,) + p.shape, ("layers",) + p.axes,
                           init=p.init, scale=p.scale),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def defs(cfg):
    kinds = layer_kinds(cfg)
    d = cfg.d_model
    out = {
        "embed": L.embed_def(cfg.padded_vocab, d),
        "final_norm": L.rms_norm_def(d),
    }
    if not cfg.tie_embeddings:
        out["head"] = {
            "w": ParamDef((cfg.padded_vocab, d), ("vocab", "embed"))
        }
    if cfg.layer_pattern == "hybrid_shared_attn":
        out["blocks"] = _stack_defs(block_defs(cfg, "mamba"), cfg.n_layers)
        out["shared_attn"] = block_defs(cfg, "attn")
        return out
    if cfg.layer_pattern == "local_global":
        assert cfg.n_layers % 2 == 0
        out["blocks"] = _stack_defs(
            {"a": block_defs(cfg, "attn_local"), "b": block_defs(cfg, "attn")},
            cfg.n_layers // 2,
        )
        return out
    if cfg.layer_pattern == "xlstm":
        # periodic (mLSTM, mLSTM, mLSTM, sLSTM) groups -> scannable stack
        assert cfg.n_layers % 4 == 0, "xlstm stack uses groups of 4"
        assert tuple(cfg.slstm_layers) == tuple(
            range(3, cfg.n_layers, 4)
        ), "slstm blocks sit at positions 3 mod 4"
        out["blocks"] = _stack_defs(
            {"m0": block_defs(cfg, "mlstm"),
             "m1": block_defs(cfg, "mlstm"),
             "m2": block_defs(cfg, "mlstm"),
             "s": block_defs(cfg, "slstm")},
            cfg.n_layers // 4,
        )
        return out
    out["blocks"] = _stack_defs(block_defs(cfg, kinds[0]), cfg.n_layers)
    return out


# ---------------------------------------------------------------------------
# block application (full sequence)
# ---------------------------------------------------------------------------

def _mixer_seq(bp, cfg, kind, h, positions):
    """Returns (mix_out, cache_seed). cache_seed is the prefill KV/state."""
    if kind.startswith("attn"):
        window = cfg.local_window if kind == "attn_local" else None
        out, (k, v) = attn_mod.attention(
            bp, cfg, h, positions, window=window, causal=True
        )
        return out, {"k": k, "v": v}
    if kind == "mamba":
        return mamba_mod.mamba_seq(bp, cfg, h)
    if kind == "mlstm":
        return xlstm_mod.mlstm_seq(bp, cfg, h)
    if kind == "slstm":
        return xlstm_mod.slstm_seq(bp, cfg, h)
    raise ValueError(kind)


def block_seq(bp, cfg, kind, x, positions):
    h = L.rms_norm(bp["norm1"], x)
    mix, cache = _mixer_seq(bp["mixer"], cfg, kind, h, positions)
    if cfg.sandwich_norm:
        mix = L.rms_norm(bp["norm1b"], mix)
    x = x + mix
    aux = jnp.float32(0.0)
    if _has_ffn(cfg, kind):
        h2 = L.rms_norm(bp["norm2"], x)
        if cfg.n_experts > 0:
            f, aux = mlp_mod.moe(bp["ffn"], cfg, h2)
        else:
            f = mlp_mod.mlp(bp["ffn"], cfg, h2)
        if cfg.sandwich_norm:
            f = L.rms_norm(bp["norm2b"], f)
        x = x + f
    return x, cache, aux


def _split_hybrid(cfg, blocks):
    """Split the stacked [L, ...] mamba params into [G, period, ...] full
    groups + an [rem, ...] tail."""
    period = cfg.shared_attn_period
    G = cfg.n_layers // period
    rem = cfg.n_layers - G * period
    g = jax.tree.map(
        lambda a: a[: G * period].reshape((G, period) + a.shape[1:]), blocks
    )
    r = jax.tree.map(lambda a: a[G * period:], blocks)
    return g, r, G, rem


def _unroll(cfg):
    return True if cfg.scan_unroll else 1


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward_seq(params, cfg, tokens, *, collect_cache=False):
    """tokens[B, S] -> (hidden[B, S, d], caches, aux_loss).

    caches: per-layer prefill cache (stacked for scanned stacks) or None.
    """
    B, S = tokens.shape
    ct = jnp.dtype(cfg.compute_dtype)
    x = L.embed_lookup(params["embed"], tokens, ct)
    positions = jnp.arange(S)
    kinds = layer_kinds(cfg)

    if cfg.layer_pattern == "xlstm":
        def body(x, bp):
            def inner(bp, x):
                cs = {}
                for key, kind in (("m0", "mlstm"), ("m1", "mlstm"),
                                  ("m2", "mlstm"), ("s", "slstm")):
                    x, c, _ = block_seq(bp[key], cfg, kind, x, positions)
                    cs[key] = c
                return x, cs

            x, cs = _remat(inner, cfg)(bp, x)
            return x, (cs if collect_cache else None)

        x, caches = jax.lax.scan(body, x, params["blocks"],
                                 unroll=_unroll(cfg))
        x = L.rms_norm(params["final_norm"], x)
        return x, caches, jnp.float32(0.0)

    if cfg.layer_pattern == "local_global":
        def body(x, bp):
            def inner(bp, x):
                x, c1, a1 = block_seq(bp["a"], cfg, "attn_local", x,
                                      positions)
                x, c2, a2 = block_seq(bp["b"], cfg, "attn", x, positions)
                return x, {"a": c1, "b": c2}, a1 + a2
            x, cs, a = _remat(inner, cfg)(bp, x)
            return x, (cs if collect_cache else None, a)

        x, (caches, auxs) = jax.lax.scan(body, x, params["blocks"], unroll=_unroll(cfg))
        x = L.rms_norm(params["final_norm"], x)
        return x, caches, jnp.sum(auxs)

    if cfg.layer_pattern == "hybrid_shared_attn":
        g_params, r_params, G, rem = _split_hybrid(cfg, params["blocks"])
        sp = params["shared_attn"]

        def group(x, bp_group):
            """period mamba layers + one shared-attention application."""
            def inner_layer(x, bp):
                x, c, a = block_seq(bp, cfg, "mamba", x, positions)
                return x, (c, a)

            def inner(bp_group, x):
                x, (mcs, auxs) = jax.lax.scan(inner_layer, x, bp_group, unroll=_unroll(cfg))
                x, ac, a2 = block_seq(sp, cfg, "attn", x, positions)
                return x, mcs, ac, jnp.sum(auxs) + a2

            x, mcs, ac, a = _remat(inner, cfg)(bp_group, x)
            return x, ((mcs, ac) if collect_cache else None, a)

        x, (gcaches, auxs) = jax.lax.scan(group, x, g_params, unroll=_unroll(cfg))
        aux = jnp.sum(auxs)
        rcaches = None
        if rem:
            def tail(x, bp):
                def inner(bp, x):
                    return block_seq(bp, cfg, "mamba", x, positions)

                x, c, a = _remat(inner, cfg)(bp, x)
                return x, (c if collect_cache else None, a)

            x, (rcaches, auxs2) = jax.lax.scan(tail, x, r_params, unroll=_unroll(cfg))
            aux = aux + jnp.sum(auxs2)
        x = L.rms_norm(params["final_norm"], x)
        caches = None
        if collect_cache:
            caches = {"mamba_g": gcaches[0], "attn": gcaches[1],
                      "mamba_r": rcaches}
        return x, caches, aux

    # uniform stack (dense / moe / pure ssm)
    kind = kinds[0]

    def body(x, bp):
        def inner(bp, x):
            return block_seq(bp, cfg, kind, x, positions)

        x, c, a = _remat(inner, cfg)(bp, x)
        return x, (c if collect_cache else None, a)

    x, (caches, auxs) = jax.lax.scan(body, x, params["blocks"], unroll=_unroll(cfg))
    x = L.rms_norm(params["final_norm"], x)
    return x, caches, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# train / serve entry points
# ---------------------------------------------------------------------------

def compute_logits(params, cfg, hidden):
    return L.logits(params["embed"], params.get("head"), hidden, cfg)


def loss_fn(params, cfg, batch):
    """Next-token CE, ignoring target==-1; adds MoE aux loss.

    CE stays in compute dtype with f32 accumulation (layers.cross_entropy)
    — materializing f32 [B, S, V] buffers was the dominant memory term on
    the big-vocab archs (see EXPERIMENTS.md §Perf)."""
    hidden, _, aux = forward_seq(params, cfg, tokens=batch["tokens"])
    w = params["embed"]["table"] if cfg.tie_embeddings else \
        params["head"]["w"]
    loss = L.chunked_cross_entropy(w, hidden, batch["targets"], cfg)
    return loss + 0.01 * aux, {"nll": loss, "aux": aux}


def init_cache(cfg, batch, max_len, *, seq_shard=False):
    """Decode cache pytree matching what decode_step consumes."""
    ct = jnp.dtype(cfg.compute_dtype)
    kinds = layer_kinds(cfg)

    def one(kind):
        if kind.startswith("attn"):
            return attn_mod.init_kv_cache(
                cfg, batch, max_len, ct, seq_shard=seq_shard
            )
        if kind == "mamba":
            return mamba_mod.init_mamba_cache(cfg, batch, ct)
        if kind == "mlstm":
            return xlstm_mod.init_mlstm_cache(cfg, batch, ct)
        if kind == "slstm":
            return xlstm_mod.init_slstm_cache(cfg, batch, ct)
        raise ValueError(kind)

    if cfg.layer_pattern == "xlstm":
        G = cfg.n_layers // 4
        grp = {"m0": one("mlstm"), "m1": one("mlstm"), "m2": one("mlstm"),
               "s": one("slstm")}
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (G,) + a.shape), grp
        )
    if cfg.layer_pattern == "local_global":
        pair = {"a": one("attn_local"), "b": one("attn")}
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers // 2,) + a.shape),
            pair,
        )
    if cfg.layer_pattern == "hybrid_shared_attn":
        period = cfg.shared_attn_period
        G = cfg.n_layers // period
        rem = cfg.n_layers - G * period
        return {
            "mamba_g": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (G, period) + a.shape),
                one("mamba"),
            ),
            "attn": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (G,) + a.shape), one("attn")
            ),
            "mamba_r": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (rem,) + a.shape), one("mamba")
            ) if rem else None,
        }
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
        one(kinds[0]),
    )


def _mixer_decode(bp, cfg, kind, h, cache, pos):
    if kind.startswith("attn"):
        window = cfg.local_window if kind == "attn_local" else None
        return attn_mod.decode_attention(bp, cfg, h, cache, pos,
                                         window=window)
    if kind == "mamba":
        return mamba_mod.mamba_decode_step(bp, cfg, h, cache)
    if kind == "mlstm":
        return xlstm_mod.mlstm_decode_step(bp, cfg, h, cache)
    if kind == "slstm":
        return xlstm_mod.slstm_decode_step(bp, cfg, h, cache)
    raise ValueError(kind)


def block_decode(bp, cfg, kind, x, cache, pos):
    h = L.rms_norm(bp["norm1"], x)
    mix, cache = _mixer_decode(bp["mixer"], cfg, kind, h, cache, pos)
    if cfg.sandwich_norm:
        mix = L.rms_norm(bp["norm1b"], mix)
    x = x + mix
    if _has_ffn(cfg, kind):
        h2 = L.rms_norm(bp["norm2"], x)
        if cfg.n_experts > 0:
            f, _ = mlp_mod.moe(bp["ffn"], cfg, h2)
        else:
            f = mlp_mod.mlp(bp["ffn"], cfg, h2)
        if cfg.sandwich_norm:
            f = L.rms_norm(bp["norm2b"], f)
        x = x + f
    return x, cache


def decode_step(params, cfg, token, cache, pos):
    """token[B, 1] + cache -> (logits[B, 1, V], new_cache). pos: scalar."""
    B = token.shape[0]
    ct = jnp.dtype(cfg.compute_dtype)
    x = L.embed_lookup(params["embed"], token, ct)
    kinds = layer_kinds(cfg)

    if cfg.layer_pattern == "xlstm":
        def body(x, scanned):
            bp, cc = scanned
            cs = {}
            for key, kind in (("m0", "mlstm"), ("m1", "mlstm"),
                              ("m2", "mlstm"), ("s", "slstm")):
                x, c = block_decode(bp[key], cfg, kind, x, cc[key], pos)
                cs[key] = c
            return x, cs

        x, new = jax.lax.scan(body, x, (params["blocks"], cache),
                              unroll=_unroll(cfg))
        x = L.rms_norm(params["final_norm"], x)
        return compute_logits(params, cfg, x), new

    if cfg.layer_pattern == "local_global":
        def body(x, scanned):
            bp, cc = scanned
            x, c1 = block_decode(bp["a"], cfg, "attn_local", x, cc["a"], pos)
            x, c2 = block_decode(bp["b"], cfg, "attn", x, cc["b"], pos)
            return x, {"a": c1, "b": c2}

        x, new = jax.lax.scan(body, x, (params["blocks"], cache), unroll=_unroll(cfg))
        x = L.rms_norm(params["final_norm"], x)
        return compute_logits(params, cfg, x), new

    if cfg.layer_pattern == "hybrid_shared_attn":
        g_params, r_params, G, rem = _split_hybrid(cfg, params["blocks"])
        sp = params["shared_attn"]

        def group(x, scanned):
            bp_group, mcs, ac = scanned

            def layer(x, sc):
                bp, mc = sc
                x, mc2 = block_decode(bp, cfg, "mamba", x, mc, pos)
                return x, mc2

            x, mcs2 = jax.lax.scan(layer, x, (bp_group, mcs), unroll=_unroll(cfg))
            x, ac2 = block_decode(sp, cfg, "attn", x, ac, pos)
            return x, (mcs2, ac2)

        x, (mg_new, ac_new) = jax.lax.scan(
            group, x, (g_params, cache["mamba_g"], cache["attn"]),
            unroll=_unroll(cfg),
        )
        mr_new = None
        if rem:
            def tail(x, sc):
                bp, mc = sc
                return block_decode(bp, cfg, "mamba", x, mc, pos)

            x, mr_new = jax.lax.scan(tail, x, (r_params, cache["mamba_r"]), unroll=_unroll(cfg))
        x = L.rms_norm(params["final_norm"], x)
        new = {"mamba_g": mg_new, "attn": ac_new, "mamba_r": mr_new}
        return compute_logits(params, cfg, x), new

    kind = kinds[0]

    def body(x, scanned):
        bp, cc = scanned
        return block_decode(bp, cfg, kind, x, cc, pos)

    x, new = jax.lax.scan(body, x, (params["blocks"], cache), unroll=_unroll(cfg))
    x = L.rms_norm(params["final_norm"], x)
    return compute_logits(params, cfg, x), new


def prefill(params, cfg, tokens):
    """tokens[B, S] -> (last-position logits [B, V], caches)."""
    hidden, caches, _ = forward_seq(params, cfg, tokens, collect_cache=True)
    logits = compute_logits(params, cfg, hidden[:, -1:, :])
    return logits[:, 0], caches
