"""Shared model layers: norms, RoPE, embeddings, logits."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.partitioning import ParamDef, constrain

__all__ = [
    "rms_norm", "rms_norm_def", "rope", "embed_def", "embed_lookup",
    "logits", "softcap",
]


def rms_norm_def(d):
    return {"scale": ParamDef((d,), (None,), init="ones")}


def rms_norm(p, x, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(dt)


def rope(x, positions, theta=10000.0):
    """x[B, S, H, Dh] or [B, S, Dh], rotated by absolute positions[S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs      # [S, half]
    cos = jnp.cos(ang)
    sin = jnp.sin(ang)
    if x.ndim == 4:   # [B, S, H, Dh]
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:             # [B, S, Dh]
        cos = cos[None]
        sin = sin[None]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def embed_def(vocab, d):
    return {"table": ParamDef((vocab, d), ("vocab", "embed"))}


def embed_lookup(p, tokens, compute_dtype):
    out = p["table"].astype(compute_dtype)[tokens]
    return constrain(out, "batch", "seq", "act_embed")


def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def chunked_cross_entropy(w, hidden, targets, cfg, *, chunk=512):
    """CE fused with the output projection, scanned over sequence chunks —
    the full [B, S, V] logits tensor is never materialized (the dominant
    activation on big-vocab archs; see EXPERIMENTS.md §Perf).

    w: [padded_vocab, d] projection (tied embedding or head weight).
    """
    B, S, d = hidden.shape
    ct = hidden.dtype
    Sc = min(chunk, S)
    if S % Sc:
        return cross_entropy(
            softcap(jnp.einsum("bsd,vd->bsv", hidden, w.astype(ct)),
                    cfg.logit_softcap),
            targets, cfg.vocab, cfg.padded_vocab,
        )
    nc = S // Sc
    xs = jnp.moveaxis(hidden.reshape(B, nc, Sc, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, nc, Sc), 1, 0)

    @jax.checkpoint  # recompute chunk logits in backward: O(Sc*V) live
    def body(carry, blk):
        tot, cnt = carry
        xb, tb = blk
        logits = jnp.einsum("bsd,vd->bsv", xb, w.astype(ct))
        logits = softcap(logits, cfg.logit_softcap)
        if cfg.padded_vocab != cfg.vocab:
            pad = (jnp.arange(cfg.padded_vocab) >= cfg.vocab).astype(ct)
            logits = logits - pad[None, None, :] * jnp.asarray(1e30, ct)
        m = jnp.max(logits, axis=-1).astype(jnp.float32)
        ex = jnp.exp(logits - m[..., None].astype(ct))
        s = jnp.sum(ex, axis=-1, dtype=jnp.float32)
        logz = m + jnp.log(s)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(tb, 0)[..., None], axis=-1
        )[..., 0].astype(jnp.float32)
        mask = (tb >= 0).astype(jnp.float32)
        return (tot + jnp.sum((logz - gold) * mask),
                cnt + jnp.sum(mask)), None

    unroll = True if cfg.scan_unroll else 1
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (xs, ts), unroll=unroll
    )
    return tot / jnp.maximum(cnt, 1.0)


def cross_entropy(logits, targets, vocab, padded_vocab):
    """Masked next-token CE without materializing f32 full-vocab buffers.

    ``logits`` stay in compute dtype (bf16 on the prod path); the max and
    the exp-sum reductions accumulate in f32 (MaxText-style). Entries of the
    padded vocab tail are excluded by a -1e30 bias (bf16 exponent range
    covers it). targets == -1 are ignored.
    """
    if padded_vocab != vocab:
        pad = (jnp.arange(padded_vocab) >= vocab).astype(logits.dtype)
        logits = logits - pad[None, None, :] * jnp.asarray(
            1e30, logits.dtype
        )
    m = jnp.max(logits, axis=-1).astype(jnp.float32)
    ex = jnp.exp(logits - m[..., None].astype(logits.dtype))
    s = jnp.sum(ex, axis=-1, dtype=jnp.float32)
    logz = m + jnp.log(s)
    tgt = jnp.maximum(targets, 0)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[
        ..., 0
    ].astype(jnp.float32)
    nll = logz - gold
    mask = (targets >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def logits(embed_p, head_p, x, cfg):
    """Project to (padded) vocab; ties to embedding when cfg.tie_embeddings."""
    if cfg.tie_embeddings:
        w = embed_p["table"]
    else:
        w = head_p["w"]
    out = jnp.einsum(
        "...d,vd->...v", x, w.astype(x.dtype)
    )
    out = softcap(out, cfg.logit_softcap)
    out = constrain(out, "batch", "seq", "act_vocab")
    return out
