"""Fault-tolerant checkpointing: msgpack + zstd, atomic, elastic restore.

Design points for 1000+ node deployments:
  * checkpoints are written to ``<dir>/step_<n>.ckpt.tmp`` and atomically
    renamed — a preemption mid-write never corrupts the latest checkpoint;
  * arrays are stored *logically* (unsharded): restore re-shards via
    ``jax.device_put`` against whatever mesh the restarted job has, so a job
    can come back on a different device count (elastic restore). On a real
    multi-host deployment the save path gathers via process 0 or uses a
    per-shard layout; the format carries shard metadata for that extension;
  * content is sha256-checksummed; retention keeps the newest K checkpoints;
  * ``latest_step`` scans the directory so a crashed run resumes without a
    side database.
"""
from __future__ import annotations

import hashlib
import os
import re

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro import compressio

__all__ = ["save", "restore", "latest_step", "gc_old"]

_NAME = re.compile(r"step_(\d+)\.ckpt$")


def _pack_tree(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [
            {
                "dtype": str(np.asarray(a).dtype),
                "shape": list(np.asarray(a).shape),
                "data": np.asarray(a).tobytes(),
            }
            for a in leaves
        ],
    }
    return payload


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3, extra=None):
    """Atomic checkpoint write. ``extra``: small JSON-able metadata dict."""
    os.makedirs(ckpt_dir, exist_ok=True)
    payload = _pack_tree(tree)
    payload["step"] = int(step)
    payload["extra"] = extra or {}
    raw = msgpack.packb(payload)
    blob = msgpack.packb(
        {"sha256": hashlib.sha256(raw).hexdigest(), "payload": raw}
    )
    comp = compressio.compress(blob, level=3)
    final = os.path.join(ckpt_dir, f"step_{step}.ckpt")
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        f.write(comp)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    gc_old(ckpt_dir, keep=keep)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(ckpt_dir)
        if (m := _NAME.search(f))
    ]
    return max(steps) if steps else None


def gc_old(ckpt_dir: str, *, keep: int = 3):
    steps = sorted(
        int(m.group(1))
        for f in os.listdir(ckpt_dir)
        if (m := _NAME.search(f))
    )
    for s in steps[:-keep]:
        try:
            os.remove(os.path.join(ckpt_dir, f"step_{s}.ckpt"))
        except OSError:
            pass


def restore(ckpt_dir: str, template, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``template``.

    shardings: optional pytree of NamedSharding congruent with template —
    this is the elastic-restore path: the stored logical arrays are placed
    against the *current* mesh regardless of the mesh they were saved under.
    Returns (tree, step, extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}.ckpt")
    with open(path, "rb") as f:
        blob = compressio.decompress(f.read())
    outer = msgpack.unpackb(blob)
    raw = outer["payload"]
    if hashlib.sha256(raw).hexdigest() != outer["sha256"]:
        raise IOError(f"checksum mismatch in {path}")
    payload = msgpack.unpackb(raw)
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    stored = payload["leaves"]
    if len(stored) != len(leaves_t):
        raise ValueError(
            f"checkpoint has {len(stored)} leaves, template expects "
            f"{len(leaves_t)} — structure changed?"
        )
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
        else [None] * len(stored)
    )
    out = []
    for meta, tmpl, shd in zip(stored, leaves_t, shard_leaves):
        a = np.frombuffer(meta["data"], dtype=meta["dtype"]).reshape(
            meta["shape"]
        )
        if tuple(a.shape) != tuple(np.shape(tmpl)):
            raise ValueError(
                f"shape mismatch: ckpt {a.shape} vs template "
                f"{np.shape(tmpl)}"
            )
        if shd is not None:
            out.append(jax.device_put(a, shd))
        else:
            out.append(jnp.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, out), step, payload["extra"]
