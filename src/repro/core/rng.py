"""Vectorized RNG-style edge pruning (paper Def. 2.1 / DiskANN alpha rule).

Candidates for a node ``u`` are processed in ascending distance-to-``u``
order; candidate ``v`` is pruned iff some already-kept ``w`` satisfies
``alpha * delta(w, v) < delta(u, v)`` (with ``alpha = 1`` this is exactly the
RNG rule — the symmetric first condition ``delta(u, w) < delta(u, v)`` holds
automatically from the processing order). Distances here are *squared* L2, so
``alpha`` acts as the square of DiskANN's alpha; ``alpha=1`` is identical.

The sequential keep-set recurrence is an O(C) ``fori_loop`` over a
precomputed candidate-candidate distance matrix, vmapped over every node of a
segment-tree level at once — the bulk-synchronous construction of DESIGN.md.

This eager [C, C] formulation is the historical build path, retained as the
bit-identical oracle and benchmark baseline (``impl="legacy"`` in
``kernels/ops.py::prune``); production builds dispatch through the fused
lazy-column formulation (``kernels/ref.py::prune`` off-TPU, the Pallas
construction-prune kernel on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["prune", "prune_batch", "pairwise_sq_dists"]

_INF = jnp.float32(jnp.inf)


def pairwise_sq_dists(x):
    """x[..., C, d] -> squared L2 distances [..., C, C]."""
    xx = jnp.sum(x * x, axis=-1)
    xy = jnp.einsum("...id,...jd->...ij", x, x)
    d = xx[..., :, None] - 2.0 * xy + xx[..., None, :]
    return jnp.maximum(d, 0.0)


@functools.partial(jax.jit, static_argnames=("m", "fill"))
def prune(cand_ids, cand_dists, cc_dists, *, m, alpha=1.0, fill=True):
    """Prune one node's candidate list to <= m RNG edges.

    Args:
      cand_ids: int32[C]; -1 = invalid slot.
      cand_dists: f32[C] squared distance to u (inf for invalid).
      cc_dists: f32[C, C] squared candidate-candidate distances.
      m: max out-degree.
      alpha: >= 1 keeps more (longer) edges; applied on squared distances.
      fill: fill remaining slots with nearest pruned candidates (HNSW's
        keepPrunedConnections) — improves connectivity on small segments.

    Returns: int32[m] neighbor ids (-1 padded).
    """
    C = cand_ids.shape[0]
    order = jnp.argsort(cand_dists, stable=True)
    ids = cand_ids[order]
    du = cand_dists[order]
    cc = cc_dists[order][:, order]
    valid = (ids >= 0) & jnp.isfinite(du)
    # duplicate ids keep only the first occurrence
    ids_for_dup = jnp.where(valid, ids, jnp.int32(2**30) + jnp.arange(C))
    o2 = jnp.argsort(ids_for_dup, stable=True)
    first = jnp.zeros((C,), bool).at[o2].set(
        jnp.concatenate(
            [jnp.array([True]), ids_for_dup[o2][1:] != ids_for_dup[o2][:-1]]
        )
    )
    valid &= first

    def body(j, carry):
        keep, count = carry
        pruned = jnp.any(keep & (alpha * cc[:, j] < du[j]))
        add = valid[j] & ~pruned & (count < m)
        return keep.at[j].set(add), count + add.astype(jnp.int32)

    keep, _ = jax.lax.fori_loop(
        0, C, body, (jnp.zeros((C,), bool), jnp.int32(0))
    )

    if fill:
        key = jnp.where(
            valid,
            jnp.where(keep, jnp.arange(C), C + jnp.arange(C)),
            jnp.int32(2**30),
        )
    else:
        key = jnp.where(keep, jnp.arange(C), jnp.int32(2**30))
    kk = min(m, C)
    _, take = jax.lax.top_k(-key, kk)
    out = jnp.where(key[take] < 2**30, ids[take], jnp.int32(-1))
    if kk < m:
        out = jnp.concatenate([out, jnp.full((m - kk,), -1, jnp.int32)])
    return out


@functools.partial(jax.jit, static_argnames=("m", "fill"))
def prune_batch(cand_ids, cand_dists, cand_vecs, *, m, alpha=1.0, fill=True):
    """Batched prune: computes cc distances then vmaps ``prune``.

    cand_ids: int32[B, C]; cand_dists: f32[B, C]; cand_vecs: f32[B, C, d].
    Returns int32[B, m].
    """
    cc = pairwise_sq_dists(cand_vecs)
    return jax.vmap(
        functools.partial(prune, m=m, alpha=alpha, fill=fill)
    )(cand_ids, cand_dists, cc)
