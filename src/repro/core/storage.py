"""Compact storage: bf16 vector table + narrow neighbor codec.

The index's HBM footprint and per-hop bandwidth are the two largest arrays
every hop reads — the vector table ``[n, d]`` and the packed elemental-graph
table ``[n, logn+1, m]`` (DESIGN.md §storage). This module is the ONE place
their storage dtypes are chosen, encoded, and decoded:

  * **Vectors** store as ``float32`` (default), ``bfloat16`` (the compact
    default — f32's full exponent range, so no scale bookkeeping), or
    ``float16`` (for CPU hosts where bf16 arithmetic emulation is slow).
    Every consumer computes distances in f32: the Pallas kernels upcast
    in-register after the row DMA (the scratch buffer is ``table.dtype``, so
    the bandwidth saving survives end-to-end), the jnp contracts upcast in
    ``kernels/ref.py``, and numpy consumers (``brute_force``) decode through
    :func:`decode_vectors`.
  * **Neighbor ids** store as ``int16`` when every id fits (``n <= 32768``)
    and ``int32`` otherwise (``neighbor_dtype="auto"``). There is ONE
    sentinel convention: ``-1`` is the absent-edge marker in *every* storage
    dtype — int16's ``-1`` widens to int32's ``-1``, so decode is a plain
    ``astype(int32)`` and ids are bit-identical across codecs. (A historical
    dtype-max sentinel once decoded in ``core/distributed.py`` without any
    encoder ever producing it; it is retired — :func:`decode_neighbors` is
    the documented decode for every consumer.)

Decode-at-the-edge: compact arrays flow as far as possible — through
``RangeGraphIndex`` storage, serialization, ``ShardedRangeIndex`` stacking,
and into the jit boundary — and widen exactly once per consumer, at the top
of the jitted searches (``core/search.py``), the sharded serve step
(``core/distributed.py::rfann_serve_step``) and the kernel dispatch layer
(``kernels/ops.py::select_edges``).
"""
from __future__ import annotations

import dataclasses
import os

import jax.numpy as jnp
import numpy as np

__all__ = [
    "StorageConfig",
    "default_config",
    "np_dtype",
    "resolve_neighbor_dtype",
    "encode_vectors",
    "decode_vectors",
    "encode_neighbors",
    "decode_neighbors",
    "NEIGHBOR_SENTINEL",
]

# The one absent-edge marker, in every storage dtype.
NEIGHBOR_SENTINEL = -1

_VECTOR_DTYPES = ("float32", "bfloat16", "float16")
_NEIGHBOR_DTYPES = ("auto", "int16", "int32")

# numpy resolves "bfloat16" only after ml_dtypes registration (importing
# jax.numpy above guarantees it); keep an explicit map so unpacking a saved
# index never depends on registration order.
_NP_DTYPES = {
    "float32": np.dtype(np.float32),
    "bfloat16": np.dtype(jnp.bfloat16),
    "float16": np.dtype(np.float16),
    "int16": np.dtype(np.int16),
    "int32": np.dtype(np.int32),
}


@dataclasses.dataclass(frozen=True)
class StorageConfig:
    """Storage dtypes for the two hot-path tables.

    vector_dtype:   "float32" | "bfloat16" | "float16" — math stays f32.
    neighbor_dtype: "auto" | "int16" | "int32" — "auto" picks the narrowest
      width that holds every id of an ``n``-object index; explicit "int16"
      raises at encode time when ids don't fit. The default is the full-width
      f32/int32 baseline; :meth:`compact` opts into the narrow codecs.
    """

    vector_dtype: str = "float32"
    neighbor_dtype: str = "int32"

    def __post_init__(self):
        if self.vector_dtype not in _VECTOR_DTYPES:
            raise ValueError(
                f"vector_dtype {self.vector_dtype!r} not in {_VECTOR_DTYPES}"
            )
        if self.neighbor_dtype not in _NEIGHBOR_DTYPES:
            raise ValueError(
                f"neighbor_dtype {self.neighbor_dtype!r} not in "
                f"{_NEIGHBOR_DTYPES}"
            )

    @classmethod
    def compact(cls, vector_dtype: str = "bfloat16") -> "StorageConfig":
        """The halved-footprint configuration the benchmarks gate on."""
        return cls(vector_dtype=vector_dtype, neighbor_dtype="auto")


def default_config() -> StorageConfig:
    """StorageConfig for callers that pass ``storage=None``.

    ``REPRO_STORAGE`` overrides: "compact" (bf16 + auto-narrow ids), "f16"
    (f16 + auto-narrow ids), "f32"/unset (full precision). This is the hook
    the CI compact-storage leg uses to force every build through the codec.
    """
    env = os.environ.get("REPRO_STORAGE", "").strip().lower()
    if env in ("", "f32", "float32"):
        return StorageConfig()
    if env == "compact":
        return StorageConfig.compact()
    if env in ("f16", "float16"):
        return StorageConfig.compact("float16")
    raise ValueError(
        f"REPRO_STORAGE={env!r}: expected 'compact', 'f16' or 'f32'"
    )


def np_dtype(name: str) -> np.dtype:
    """Resolve a serialized dtype string, including the ml_dtypes names."""
    if name in _NP_DTYPES:
        return _NP_DTYPES[name]
    return np.dtype(name)


def resolve_neighbor_dtype(n: int, spec: str = "auto") -> np.dtype:
    """Narrowest id dtype for an ``n``-object table under ``spec``."""
    fits16 = n - 1 <= np.iinfo(np.int16).max
    if spec == "int32":
        return _NP_DTYPES["int32"]
    if spec == "int16":
        if not fits16:
            raise ValueError(
                f"neighbor_dtype=int16 cannot hold ids up to {n - 1} "
                f"(max {np.iinfo(np.int16).max})"
            )
        return _NP_DTYPES["int16"]
    if spec == "auto":
        return _NP_DTYPES["int16" if fits16 else "int32"]
    raise ValueError(f"neighbor_dtype {spec!r} not in {_NEIGHBOR_DTYPES}")


def encode_vectors(vectors, cfg: StorageConfig) -> np.ndarray:
    """Vector table -> its storage dtype (host-side, numpy)."""
    dt = np_dtype(cfg.vector_dtype)
    vectors = np.asarray(vectors)
    if vectors.dtype == dt:
        return vectors
    return np.ascontiguousarray(vectors.astype(dt))


def decode_vectors(vectors) -> np.ndarray:
    """Vector table -> f32 for numpy consumers (``brute_force`` et al.).

    jnp consumers skip this: kernels/ref upcast in-register so the compact
    table is what actually crosses HBM.
    """
    vectors = np.asarray(vectors)
    if vectors.dtype == np.float32:
        return vectors
    return np.ascontiguousarray(vectors.astype(np.float32))


def encode_neighbors(nbrs, n: int, cfg: StorageConfig) -> np.ndarray:
    """Neighbor table -> the narrowest id dtype. ``-1`` stays ``-1``."""
    dt = resolve_neighbor_dtype(n, cfg.neighbor_dtype)
    nbrs = np.asarray(nbrs)
    if nbrs.size and int(nbrs.max(initial=-1)) >= n:
        raise ValueError(
            f"neighbor id {int(nbrs.max())} out of range for n={n}"
        )
    if nbrs.dtype == dt:
        return nbrs
    return np.ascontiguousarray(nbrs.astype(dt))


def decode_neighbors(nbrs):
    """Neighbor table -> int32 at the consumption edge (numpy OR jnp).

    Because ``-1`` is the sentinel in every storage dtype, decode is a plain
    widening cast — ids are bit-identical across int16/int32 storage. Safe
    inside a trace; a no-op (no copy) when the table is already int32.
    """
    if nbrs.dtype == np.int32:
        return nbrs
    return nbrs.astype(jnp.int32 if isinstance(nbrs, jnp.ndarray)
                       else np.int32)
