"""Storage codecs: compact floats, quantized vectors, narrow neighbor ids.

The index's HBM footprint and per-hop bandwidth are the two largest arrays
every hop reads — the vector table ``[n, d]`` and the packed elemental-graph
table ``[n, logn+1, m]`` (DESIGN.md §storage, §9). This module is the ONE
place their storage dtypes are chosen, encoded, and decoded:

  * **Vectors** store as ``float32`` (default), ``bfloat16`` (the compact
    default — f32's full exponent range, so no scale bookkeeping),
    ``float16`` (for CPU hosts where bf16 arithmetic emulation is slow),
    per-vector scaled ``int8`` (:class:`Int8Vectors`: ``codes int8[n, d]`` +
    ``scales f32[n]``, symmetric max-abs quantization), or product
    quantization ``pq`` (:class:`PQVectors`: ``codes uint8[n, M]`` + a
    ``codebook f32[M, 256, d/M]`` trained by a deterministic k-means).
    Every consumer computes distances in f32: the Pallas kernels dequantize
    in VMEM registers right after the row DMA (the gather scratch holds the
    *stored* rows, so the bandwidth saving survives end-to-end — no widened
    table ever hits HBM), the jnp contracts decode through
    :func:`decode_rows` in ``kernels/ref.py``, and numpy consumers
    (``brute_force``) decode through :func:`decode_vectors`.
  * **Neighbor ids** store as ``int16`` when every id fits (``n <= 32768``),
    ``int32`` otherwise (``neighbor_dtype="auto"``), or as the ``"split"``
    codec (:class:`SplitNeighbors`): elemental-graph edges at layer ``l``
    stay inside their node's layer-``l`` segment of width ``2^(logn-l)``,
    so every layer whose segments hold ≤128 nodes stores **int8 offsets
    from the segment base** instead of absolute ids — at the bench shapes
    that is 8 of ~14 layers, and it is what pushes the whole-index ratio
    past what vector codecs alone can reach. There is ONE sentinel
    convention: ``-1`` is the absent-edge marker in *every* storage dtype
    (including the int8 offsets), so decode widens/rebases without a
    special case and ids are bit-identical across codecs.

Decode-at-the-edge: stored arrays flow as far as possible — through
``RangeGraphIndex`` storage, serialization, and into the jit boundary — and
widen exactly once per consumer: neighbor tables at the top of the jitted
searches (``core/search.py``) and in ``kernels/ops.py``; vector tables never
widen outside a kernel register file (§9's fused-decode contract).

Reranking: quantized distances can swap near-ties, so ``rerank_dtype``
declares an optional exact(er) sidecar table the jitted search re-scores its
top-``r`` candidates against (``SearchConfig.rerank``). The PQ profile pairs
a ``uint8`` navigation table with an int8 rerank sidecar; the footprint gate
accounts for both (``nav`` vs total ratio, ``benchmarks/ci_gate.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import knobs as knobs_mod

__all__ = [
    "StorageConfig",
    "default_config",
    "np_dtype",
    "resolve_neighbor_dtype",
    "encode_vectors",
    "decode_vectors",
    "encode_neighbors",
    "decode_neighbors",
    "encode_rerank",
    "decode_rows",
    "train_pq",
    "table_n",
    "table_dim",
    "table_nbytes",
    "as_device",
    "split_layer",
    "Int8Vectors",
    "PQVectors",
    "SplitNeighbors",
    "NEIGHBOR_SENTINEL",
    "PQ_CENTROIDS",
]

# The one absent-edge marker, in every storage dtype.
NEIGHBOR_SENTINEL = -1

# Centroids per PQ subspace: one uint8 code book.
PQ_CENTROIDS = 256

_VECTOR_DTYPES = ("float32", "bfloat16", "float16", "int8", "pq")
_NEIGHBOR_DTYPES = ("auto", "int16", "int32", "split")
_RERANK_DTYPES = ("none", "int8", "bfloat16", "float16", "float32")

# numpy resolves "bfloat16" only after ml_dtypes registration (importing
# jax.numpy above guarantees it); keep an explicit map so unpacking a saved
# index never depends on registration order.
_NP_DTYPES = {
    "float32": np.dtype(np.float32),
    "bfloat16": np.dtype(jnp.bfloat16),
    "float16": np.dtype(np.float16),
    "int16": np.dtype(np.int16),
    "int32": np.dtype(np.int32),
    "int8": np.dtype(np.int8),
    "uint8": np.dtype(np.uint8),
}


class Int8Vectors(NamedTuple):
    """Per-vector symmetric int8 quantization: ``x ≈ codes * scales[:,None]``.

    codes:  int8[n, d], values in [-127, 127]
    scales: f32[n], ``max|x_i| / 127`` per row (1.0 for all-zero rows)

    A NamedTuple is a registered jax pytree, so the pair flows through
    ``jnp.asarray`` uploads, jit arguments, and AOT-compiled executables with
    the structure folded into the trace signature — the executor's
    zero-post-warmup-compile guarantee is untouched.
    """

    codes: Any
    scales: Any


class PQVectors(NamedTuple):
    """Product quantization: ``x[i] ≈ concat_j codebook[j, codes[i, j]]``.

    codes:    uint8[n, M] — per-subspace centroid index
    codebook: f32[M, 256, dsub] — per-subspace centroids, ``dsub = d // M``
    """

    codes: Any
    codebook: Any


class SplitNeighbors(NamedTuple):
    """Segment-offset neighbor codec (DESIGN.md §9).

    hi: int16/int32[n, split, m]          — absolute ids, layers [0, split)
    lo: int8[n, logn+1-split, m]          — offsets from the node's own
        layer-``l`` segment base ``(u >> (logn-l)) << (logn-l)``, layers
        [split, logn]; ``-1`` stays the absent-edge sentinel.

    ``split = max(0, logn - 7)``: below it segments are wider than 128 nodes
    and offsets would overflow int8.
    """

    hi: Any
    lo: Any


def split_layer(logn: int) -> int:
    """First layer whose segment offsets fit int8 (segment width <= 128)."""
    return max(0, logn - 7)


@dataclasses.dataclass(frozen=True)
class StorageConfig:
    """Storage codecs for the hot-path tables.

    vector_dtype:   "float32" | "bfloat16" | "float16" | "int8" | "pq" —
      math stays f32 everywhere; the quantized codecs decode inside the
      kernels (DESIGN.md §9).
    neighbor_dtype: "auto" | "int16" | "int32" | "split" — "auto" picks the
      narrowest width that holds every id of an ``n``-object index;
      explicit "int16" raises at encode time when ids don't fit; "split"
      stores int8 segment offsets for the narrow layers (requires a
      segment-aligned elemental-graph table, i.e. every real index).
    rerank_dtype:   "none" | "int8" | "bfloat16" | "float16" | "float32" —
      optional exact(er) sidecar the search re-scores top-``r`` candidates
      against (``SearchConfig.rerank``); "none" reranks against the stored
      navigation vectors, which is a no-op refinement for exact codecs.
    pq_m:           subspace count for "pq" (0 = auto: ``d // 4`` when d is
      divisible by 4, else ``d``).

    The default is the full-width f32/int32 baseline; :meth:`compact`,
    :meth:`int8` and :meth:`pq` opt into the codecs.
    """

    vector_dtype: str = "float32"
    neighbor_dtype: str = "int32"
    rerank_dtype: str = "none"
    pq_m: int = 0

    def __post_init__(self):
        if self.vector_dtype not in _VECTOR_DTYPES:
            raise ValueError(
                f"vector_dtype {self.vector_dtype!r} not in {_VECTOR_DTYPES}"
            )
        if self.neighbor_dtype not in _NEIGHBOR_DTYPES:
            raise ValueError(
                f"neighbor_dtype {self.neighbor_dtype!r} not in "
                f"{_NEIGHBOR_DTYPES}"
            )
        if self.rerank_dtype not in _RERANK_DTYPES:
            raise ValueError(
                f"rerank_dtype {self.rerank_dtype!r} not in {_RERANK_DTYPES}"
            )
        if self.pq_m < 0:
            raise ValueError(f"pq_m must be >= 0, got {self.pq_m}")

    @classmethod
    def compact(cls, vector_dtype: str = "bfloat16") -> "StorageConfig":
        """The halved-footprint configuration (bf16 + narrow ids)."""
        return cls(vector_dtype=vector_dtype, neighbor_dtype="auto")

    @classmethod
    def int8(cls) -> "StorageConfig":
        """Scaled-int8 vectors + split neighbor offsets (~0.33 ratio)."""
        return cls(vector_dtype="int8", neighbor_dtype="split")

    @classmethod
    def pq(cls, pq_m: int = 0) -> "StorageConfig":
        """PQ navigation vectors + split offsets + int8 rerank sidecar.

        The navigation tables alone reach ~0.27 of the f32 footprint; the
        int8 sidecar (for ``SearchConfig.rerank``) is what holds the recall
        gate, and the footprint gate accounts for it separately.
        """
        return cls(vector_dtype="pq", neighbor_dtype="split",
                   rerank_dtype="int8", pq_m=pq_m)


def default_config() -> StorageConfig:
    """StorageConfig for callers that pass ``storage=None``.

    ``REPRO_STORAGE`` overrides: "compact" (bf16 + auto-narrow ids), "f16"
    (f16 + auto-narrow ids), "int8" (scaled int8 + split offsets), "pq"
    (PQ + split offsets + int8 rerank), "f32"/unset (full precision). This
    is the hook the CI storage legs use to force every build through a
    codec (docs/KNOBS.md).
    """
    env = (knobs_mod.get_str("REPRO_STORAGE") or "").strip().lower()
    if env in ("", "f32", "float32"):
        return StorageConfig()
    if env == "compact":
        return StorageConfig.compact()
    if env in ("f16", "float16"):
        return StorageConfig.compact("float16")
    if env == "int8":
        return StorageConfig.int8()
    if env == "pq":
        return StorageConfig.pq()
    raise ValueError(
        f"REPRO_STORAGE={env!r}: expected 'compact', 'f16', 'int8', 'pq' "
        f"or 'f32'"
    )


def np_dtype(name: str) -> np.dtype:
    """Resolve a serialized dtype string, including the ml_dtypes names."""
    if name in _NP_DTYPES:
        return _NP_DTYPES[name]
    return np.dtype(name)


def resolve_neighbor_dtype(n: int, spec: str = "auto") -> np.dtype:
    """Narrowest id dtype for an ``n``-object table under ``spec``.

    For ``spec="split"`` this resolves the dtype of the *wide* (absolute-id)
    layers; the narrow layers are always int8 offsets.
    """
    fits16 = (
        n - 1 <= np.iinfo(np.int16).max  # replint: allow[R5] capacity math
    )
    if spec == "int32":
        return _NP_DTYPES["int32"]
    if spec == "int16":
        if not fits16:
            raise ValueError(
                f"neighbor_dtype=int16 cannot hold ids up to {n - 1} "
                f"(max {np.iinfo(np.int16).max})"  # replint: allow[R5] error message cites the dtype ceiling
            )
        return _NP_DTYPES["int16"]
    if spec in ("auto", "split"):
        return _NP_DTYPES["int16" if fits16 else "int32"]
    raise ValueError(f"neighbor_dtype {spec!r} not in {_NEIGHBOR_DTYPES}")


# ---------------------------------------------------------------------------
# vector codecs
# ---------------------------------------------------------------------------

def _encode_int8(vectors: np.ndarray) -> Int8Vectors:
    v = np.asarray(vectors, np.float32)
    amax = np.abs(v).max(axis=1)
    scales = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    codes = np.clip(np.rint(v / scales[:, None]), -127, 127).astype(np.int8)
    return Int8Vectors(np.ascontiguousarray(codes), scales)


def resolve_pq_m(d: int, pq_m: int = 0) -> int:
    """Subspace count: explicit (must divide d) or auto ``d // 4``."""
    if pq_m:
        if d % pq_m:
            raise ValueError(f"pq_m={pq_m} does not divide d={d}")
        return pq_m
    return d // 4 if d % 4 == 0 and d >= 4 else d


def train_pq(vectors, pq_m: int = 0, *, seed: int = 0, iters: int = 8,
             sample: int = 4096) -> PQVectors:
    """Deterministic per-subspace k-means PQ (numpy, host-side).

    Subsamples up to ``sample`` training rows per subspace, runs ``iters``
    Lloyd iterations from a seeded init (empty clusters keep their previous
    centroid), then encodes every row. Same (vectors, pq_m, seed) ->
    bit-identical codebook on every host.
    """
    v = np.asarray(vectors, np.float32)
    n, d = v.shape
    M = resolve_pq_m(d, pq_m)
    dsub = d // M
    rng = np.random.default_rng(seed)
    train_idx = (np.arange(n) if n <= sample
                 else rng.choice(n, sample, replace=False))
    codebook = np.empty((M, PQ_CENTROIDS, dsub), np.float32)
    codes = np.empty((n, M), np.uint8)
    for j in range(M):
        sub = v[:, j * dsub:(j + 1) * dsub]
        train = sub[train_idx]
        init = rng.choice(train.shape[0], PQ_CENTROIDS,
                          replace=train.shape[0] < PQ_CENTROIDS)
        cent = train[init].copy()
        for _ in range(iters):
            # [S, 256] squared distances; argmin assign; mean update
            d2 = ((train[:, None, :] - cent[None, :, :]) ** 2).sum(-1)
            assign = d2.argmin(1)
            for c in range(PQ_CENTROIDS):
                sel = assign == c
                if sel.any():
                    cent[c] = train[sel].mean(0)
        codebook[j] = cent
        d2 = ((sub[:, None, :] - cent[None, :, :]) ** 2).sum(-1)
        codes[:, j] = d2.argmin(1).astype(np.uint8)
    return PQVectors(np.ascontiguousarray(codes),
                     np.ascontiguousarray(codebook))


def encode_vectors(vectors, cfg: StorageConfig):
    """Vector table -> its storage representation (host-side, numpy).

    Returns a plain ndarray for the float codecs, :class:`Int8Vectors` /
    :class:`PQVectors` for the quantized ones.
    """
    if cfg.vector_dtype == "int8":
        return _encode_int8(vectors)
    if cfg.vector_dtype == "pq":
        return train_pq(vectors, cfg.pq_m)
    dt = np_dtype(cfg.vector_dtype)
    vectors = np.asarray(vectors)
    if vectors.dtype == dt:
        return vectors
    return np.ascontiguousarray(vectors.astype(dt))


def decode_vectors(vectors) -> np.ndarray:
    """Vector table -> f32 numpy (``brute_force``, oracle baselines).

    jnp consumers skip this: kernels decode per-row in VMEM registers
    (:func:`decode_rows` is the in-trace contract), so the stored table is
    what actually crosses HBM.
    """
    if isinstance(vectors, Int8Vectors):
        codes = np.asarray(vectors.codes, np.float32)
        return codes * np.asarray(vectors.scales, np.float32)[:, None]
    if isinstance(vectors, PQVectors):
        codes = np.asarray(vectors.codes)
        cb = np.asarray(vectors.codebook, np.float32)
        M, _, dsub = cb.shape
        out = cb[np.arange(M)[None, :], codes.astype(np.int64)]  # [n, M, dsub]
        return np.ascontiguousarray(out.reshape(codes.shape[0], M * dsub))
    vectors = np.asarray(vectors)
    if vectors.dtype == np.float32:
        return vectors
    return np.ascontiguousarray(vectors.astype(np.float32))


def decode_rows(table, ids):
    """Gather + decode rows -> f32, numpy OR inside a trace.

    ``ids`` must already be clipped non-negative (callers use
    ``maximum(ids, 0)`` and mask afterwards, the ``kernels/ref.py``
    convention). For plain arrays this is the historical widening gather;
    for the quantized codecs it is the jnp contract the Pallas kernels'
    in-VMEM decode is pinned against (bit-identical under f32 ordering,
    ``tests/test_codecs.py``).
    """
    if isinstance(table, Int8Vectors):
        x = table.codes[ids].astype(jnp.float32
                                    if not isinstance(table.codes, np.ndarray)
                                    else np.float32)
        s = table.scales[ids]
        return x * s[..., None]
    if isinstance(table, PQVectors):
        cb = table.codebook
        M, K, dsub = cb.shape
        codes = table.codes[ids]
        if isinstance(cb, np.ndarray):
            out = cb[np.arange(M), codes.astype(np.int64)]
            return out.reshape(*codes.shape[:-1], M * dsub).astype(np.float32)
        flat = cb.reshape(M * K, dsub)
        idx = codes.astype(jnp.int32) + jnp.arange(M, dtype=jnp.int32) * K
        out = jnp.take(flat, idx.reshape(-1), axis=0)
        return out.reshape(*codes.shape, dsub).reshape(
            *codes.shape[:-1], M * dsub)
    x = table[ids]
    if isinstance(x, np.ndarray):
        return x.astype(np.float32)
    return x.astype(jnp.float32)


def encode_rerank(vectors, cfg: StorageConfig):
    """f32 vector table -> the rerank sidecar, or None for "none"."""
    if cfg.rerank_dtype == "none":
        return None
    if cfg.rerank_dtype == "int8":
        return _encode_int8(vectors)
    dt = np_dtype(cfg.rerank_dtype)
    return np.ascontiguousarray(np.asarray(vectors).astype(dt))


# ---------------------------------------------------------------------------
# neighbor codecs
# ---------------------------------------------------------------------------

def _encode_split(nbrs: np.ndarray, n: int, cfg: StorageConfig
                  ) -> SplitNeighbors:
    nodes, layers, m = nbrs.shape
    logn = layers - 1
    split = split_layer(logn)
    hi = np.ascontiguousarray(
        nbrs[:, :split, :].astype(resolve_neighbor_dtype(n, "split")))
    u = np.arange(nodes, dtype=np.int64)
    shifts = logn - np.arange(split, layers)          # [nl], each <= 7
    base = (u[:, None] >> shifts[None, :]) << shifts[None, :]  # [nodes, nl]
    narrow = nbrs[:, split:, :].astype(np.int64)
    off = narrow - base[:, :, None]
    absent = narrow < 0
    width = 1 << shifts[None, :, None]                # segment width, <= 128
    bad = ~absent & ((off < 0) | (off > width - 1))
    if bad.any():
        l_bad = split + int(np.argwhere(bad)[0][1])
        raise ValueError(
            f"neighbor_dtype='split' requires segment-aligned edges: layer "
            f"{l_bad} has an edge outside its node's segment"
        )
    lo = np.where(absent, -1, off).astype(np.int8)
    return SplitNeighbors(hi, np.ascontiguousarray(lo))


def encode_neighbors(nbrs, n: int, cfg: StorageConfig):
    """Neighbor table -> its storage codec. ``-1`` stays ``-1``."""
    nbrs = np.asarray(nbrs)
    if nbrs.size and int(nbrs.max(initial=-1)) >= n:
        raise ValueError(
            f"neighbor id {int(nbrs.max())} out of range for n={n}"
        )
    if cfg.neighbor_dtype == "split":
        return _encode_split(nbrs, n, cfg)
    dt = resolve_neighbor_dtype(n, cfg.neighbor_dtype)
    if nbrs.dtype == dt:
        return nbrs
    return np.ascontiguousarray(nbrs.astype(dt))


def _decode_split(sn: SplitNeighbors):
    hi, lo = sn.hi, sn.lo
    nodes = hi.shape[0]
    layers = hi.shape[1] + lo.shape[1]
    logn = layers - 1
    split = hi.shape[1]
    xp = np if isinstance(lo, np.ndarray) else jnp
    i32 = np.int32 if xp is np else jnp.int32
    u = xp.arange(nodes, dtype=i32)
    shifts = logn - xp.arange(split, layers, dtype=i32)
    base = (u[:, None] >> shifts[None, :]) << shifts[None, :]  # [nodes, nl]
    narrow = lo.astype(i32)
    absn = xp.where(narrow < 0, -1, narrow + base[:, :, None])
    return xp.concatenate([hi.astype(i32), absn], axis=1)


def decode_neighbors(nbrs):
    """Neighbor table -> int32 at the consumption edge (numpy OR jnp).

    Because ``-1`` is the sentinel in every storage dtype, decode is a plain
    widening cast (int16/int32) or a widen+rebase (``split``: offset plus
    the closed-form segment base) — ids are bit-identical across codecs.
    Safe inside a trace; a no-op (no copy) when the table is already int32.
    """
    if isinstance(nbrs, SplitNeighbors):
        return _decode_split(nbrs)
    if nbrs.dtype == np.int32:
        return nbrs
    return nbrs.astype(jnp.int32 if isinstance(nbrs, jnp.ndarray)
                       else np.int32)


# ---------------------------------------------------------------------------
# table introspection — the struct-safe .shape/.nbytes/.asarray accessors
# ---------------------------------------------------------------------------

def table_n(table) -> int:
    """Row count of a (possibly codec-struct) vector or neighbor table."""
    if isinstance(table, (Int8Vectors, PQVectors)):
        return table.codes.shape[0]
    if isinstance(table, SplitNeighbors):
        return table.hi.shape[0]
    return table.shape[0]


def table_dim(table) -> int:
    """Decoded vector dimensionality of a (possibly codec-struct) table."""
    if isinstance(table, Int8Vectors):
        return table.codes.shape[1]
    if isinstance(table, PQVectors):
        M, _, dsub = table.codebook.shape
        return M * dsub
    return table.shape[1]


def table_nbytes(table) -> int:
    """Real stored bytes of a table — the sum over codec-struct leaves."""
    if table is None:
        return 0
    if isinstance(table, (Int8Vectors, PQVectors, SplitNeighbors)):
        return sum(int(np.asarray(leaf).nbytes) for leaf in table)
    return int(table.nbytes)


def as_device(table):
    """Upload a (possibly codec-struct) table: ``jnp.asarray`` per leaf.

    NamedTuple codecs are jax pytrees, so the returned struct feeds jit /
    AOT-compiled executables directly with its structure in the trace
    signature.
    """
    if table is None:
        return None
    if isinstance(table, (Int8Vectors, PQVectors, SplitNeighbors)):
        return type(table)(*(jnp.asarray(leaf) for leaf in table))
    return jnp.asarray(table)
