"""Distributed RFANN: iRangeGraph sharded over the ``data`` mesh axis.

Sharding scheme (DESIGN.md §2): objects are split into *contiguous
attribute-rank chunks*, one per data-parallel device group. Each shard holds
its slice of vectors plus a full iRangeGraph (segment tree + elemental
graphs) built on the slice. A query range [L, R] then intersects a
contiguous run of shards; each shard improvises its dedicated graph for the
clipped local range and the per-shard top-k are merged with one all-gather
over the ``data`` axis. The ``model`` axis replicates the index and splits
the query batch (so both axes contribute to serving throughput).

This is the paper's technique made multi-pod: per-shard work is exactly the
single-machine algorithm, and the only cross-device traffic is the k-sized
merge — O(B * k) per query batch, independent of n.

``rfann_serve_step`` is the paper-system dry-run cell: it lowers under the
production mesh with vectors/neighbors sharded on the leading axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import build as build_mod
from repro.core import search as search_mod
from repro.core.index import RangeGraphIndex

__all__ = ["ShardedRangeIndex", "build_sharded", "rfann_serve_step"]


class ShardedRangeIndex:
    """Host-side container for the per-shard artifacts (stacked arrays)."""

    def __init__(self, vectors, neighbors, bounds, logn, m):
        # vectors: [S, n_shard, d]; neighbors: [S, n_shard, layers, m]
        # bounds:  [S, 2] global rank range per shard
        self.vectors = vectors
        self.neighbors = neighbors
        self.bounds = bounds
        self.logn = logn
        self.m = m

    @property
    def n_shards(self):
        return self.vectors.shape[0]


def build_sharded(
    vectors: np.ndarray, attrs: np.ndarray, n_shards: int,
    cfg: build_mod.BuildConfig | None = None,
) -> ShardedRangeIndex:
    """Sort globally by attribute, chunk into contiguous rank ranges, build
    one index per shard (embarrassingly parallel across hosts in a real
    deployment)."""
    cfg = cfg or build_mod.BuildConfig()
    n = vectors.shape[0]
    order = np.argsort(attrs, kind="stable")
    vs = np.asarray(vectors, np.float32)[order]
    per = n // n_shards
    assert per * n_shards == n, "shard count must divide n"
    vlist, nlist, bounds = [], [], []
    logn = None
    for s in range(n_shards):
        lo, hi = s * per, (s + 1) * per - 1
        tbl = build_mod.build_neighbor_table(vs[lo : hi + 1], cfg)
        vlist.append(vs[lo : hi + 1])
        nlist.append(tbl)
        bounds.append((lo, hi))
        logn = tbl.shape[1] - 1
    return ShardedRangeIndex(
        np.stack(vlist), np.stack(nlist), np.asarray(bounds, np.int32),
        logn, cfg.m,
    )


def rfann_serve_step(
    shard_vectors,    # f32[S, n_shard, d]   sharded: ("data", None, None)
    shard_neighbors,  # i32[S, n_shard, layers, m]  sharded likewise
    shard_bounds,     # i32[S, 2]
    queries,          # f32[B, d]            sharded: ("model", None)
    L, R,             # i32[B] global rank ranges
    *,
    mesh: Mesh,
    logn: int,
    m: int,
    ef: int,
    k: int,
    expand_width: int = 4,
    dist_impl: str = "auto",
    edge_impl: str = "auto",
):
    """Batched distributed RFANN query under shard_map."""

    have_pod = "pod" in mesh.shape
    query_spec = P(("pod", "model")) if have_pod else P("model")

    def local(vec, nbr, bnd, q, Lq, Rq):
        vec = vec[0]          # [n_shard, d] (leading shard dim is mapped)
        nbr = nbr[0]
        if nbr.dtype != jnp.int32:
            # compact storage (u/int16) uses dtype-max as the absent marker
            sentinel = jnp.iinfo(nbr.dtype).max
            nbr = jnp.where(nbr == sentinel, -1, nbr.astype(jnp.int32))
        lo, hi = bnd[0, 0], bnd[0, 1]
        # clip the global range to this shard's rank range, local coords
        Ll = jnp.clip(Lq - lo, 0, vec.shape[0] - 1).astype(jnp.int32)
        Rl = (jnp.minimum(Rq, hi) - lo).astype(jnp.int32)
        empty = (Rq < lo) | (Lq > hi)
        # an empty clip becomes the L > R range, which yields no entry
        # points and therefore no results
        Ll = jnp.where(empty, 1, Ll)
        Rl = jnp.where(empty, 0, Rl)
        res = search_mod.search_improvised(
            vec, nbr, q, Ll, Rl,
            logn=logn, m_out=m, ef=ef, k=k, expand_width=expand_width,
            dist_impl=dist_impl, edge_impl=edge_impl,
        )
        ids = jnp.where(
            (res.ids >= 0) & ~empty[:, None], res.ids + lo, -1
        )
        dists = jnp.where(ids >= 0, res.dists, jnp.inf)
        # merge across the data axis: gather all shards' top-k
        all_ids = jax.lax.all_gather(ids, "data", axis=0)      # [S, B, k]
        all_d = jax.lax.all_gather(dists, "data", axis=0)
        S = all_ids.shape[0]
        B = ids.shape[0]
        flat_i = jnp.moveaxis(all_ids, 0, 1).reshape(B, S * k)
        flat_d = jnp.moveaxis(all_d, 0, 1).reshape(B, S * k)
        _, take = jax.lax.top_k(-flat_d, k)
        out_i = jnp.take_along_axis(flat_i, take, 1)
        out_d = jnp.take_along_axis(flat_d, take, 1)
        return out_i, out_d

    fn = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P("data"), P("data"), P("data"),
            query_spec, query_spec, query_spec,
        ),
        out_specs=(query_spec, query_spec),
        check_vma=False,
    )
    return fn(shard_vectors, shard_neighbors, shard_bounds, queries, L, R)


def make_serve_jit(mesh: Mesh, *, logn, m, ef, k, expand_width=4,
                   dist_impl="auto", edge_impl="auto"):
    """jit wrapper with shardings bound — what the dry-run lowers."""

    @functools.partial(jax.jit, static_argnums=())
    def step(shard_vectors, shard_neighbors, shard_bounds, queries, L, R):
        return rfann_serve_step(
            shard_vectors, shard_neighbors, shard_bounds, queries, L, R,
            mesh=mesh, logn=logn, m=m, ef=ef, k=k, expand_width=expand_width,
            dist_impl=dist_impl, edge_impl=edge_impl,
        )

    return step
