"""Distributed RFANN: iRangeGraph sharded over the ``data`` mesh axis.

Sharding scheme (DESIGN.md §2): objects are split into *contiguous
attribute-rank chunks*, one per data-parallel device group. Each shard holds
its slice of vectors plus a full iRangeGraph (segment tree + elemental
graphs) built on the slice. A query range [L, R] then intersects a
contiguous run of shards; each shard improvises its dedicated graph for the
clipped local range and the per-shard top-k are merged with one all-gather
over the ``data`` axis. The ``model`` axis replicates the index and splits
the query batch (so both axes contribute to serving throughput).

This is the paper's technique made multi-pod: per-shard work is exactly the
single-machine algorithm, and the only cross-device traffic is the k-sized
merge — O(B * k) per query batch, independent of n.

``rfann_serve_step`` is the paper-system dry-run cell: it lowers under the
production mesh with vectors/neighbors sharded on the leading axis. Shards
may be ragged (``build_sharded`` pads the tail, bounds mask the padding)
and may store compact dtypes (bf16 vectors / int16 neighbor ids,
``core/storage.py``); ``shard_topk`` is the per-shard body shared by the
shard_map path and mesh-free hosts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import build as build_mod
from repro.core import config as config_mod
from repro.core import search as search_mod
from repro.core import storage as storage_mod
from repro.core.config import SearchConfig
from repro.core.index import RangeGraphIndex

__all__ = [
    "ShardedRangeIndex", "build_sharded", "shard_topk", "merge_topk",
    "rfann_serve_step",
]


class ShardedRangeIndex:
    """Host-side container for the per-shard artifacts (stacked arrays)."""

    def __init__(self, vectors, neighbors, bounds, logn, m, storage=None):
        # vectors: [S, n_shard, d] in storage.vector_dtype
        # neighbors: [S, n_shard, layers, m] in the neighbor codec dtype
        # bounds:  [S, 2] global rank range per shard (inclusive; masks any
        #          padded tail rows out of every query)
        self.vectors = vectors
        self.neighbors = neighbors
        self.bounds = bounds
        self.logn = logn
        self.m = m
        # introspection only: default-derive from the arrays so the field
        # can never contradict what is actually stored
        self.storage = storage or storage_mod.StorageConfig(
            vector_dtype=str(vectors.dtype),
            neighbor_dtype=str(neighbors.dtype),
        )

    @property
    def n_shards(self):
        return self.vectors.shape[0]

    @property
    def nbytes(self) -> int:
        """Real stored footprint of the stacked per-shard tables."""
        return (self.vectors.nbytes + self.neighbors.nbytes
                + self.bounds.nbytes)


def build_sharded(
    vectors: np.ndarray, attrs: np.ndarray, n_shards: int,
    cfg: build_mod.BuildConfig | None = None,
    storage: storage_mod.StorageConfig | None = None,
) -> ShardedRangeIndex:
    """Sort globally by attribute, chunk into contiguous rank ranges, build
    one index per shard (embarrassingly parallel across hosts in a real
    deployment).

    ``n_shards`` need not divide ``n``: shards are ``ceil(n / n_shards)``
    wide and a ragged tail is padded by repeating its last vector row, with
    ``bounds`` holding only the real rank range — the serve path clips every
    query to ``[lo, hi]``, so padded rows are never entered, traversed into,
    or returned. Every shard therefore shares one ``logn``/table shape.
    """
    cfg = cfg or build_mod.BuildConfig()
    storage = storage or storage_mod.default_config()
    if (storage.vector_dtype in ("int8", "pq")
            or storage.neighbor_dtype == "split"):
        # codec structs don't stack into the [S, ...] shard-major arrays
        # this layer shards over; quantized sharded serving is future work
        raise ValueError(
            "build_sharded does not support codec storage "
            f"(vector_dtype={storage.vector_dtype!r}, "
            f"neighbor_dtype={storage.neighbor_dtype!r}); use a plain "
            "float/compact StorageConfig"
        )
    n = vectors.shape[0]
    if not 1 <= n_shards <= n:
        raise ValueError(f"need 1 <= n_shards <= n, got S={n_shards} n={n}")
    order = np.argsort(attrs, kind="stable")
    vs = np.asarray(vectors, np.float32)[order]
    per = -(-n // n_shards)  # ceil: the last shard may be ragged
    vlist, nlist, bounds = [], [], []
    logn = None
    for s in range(n_shards):
        lo = s * per
        hi = min(lo + per, n) - 1  # hi < lo marks an all-padding shard
        sl = vs[lo : hi + 1] if hi >= lo else vs[:0]
        if sl.shape[0] < per:
            fill = sl[-1] if sl.shape[0] else vs[-1]
            sl = np.concatenate(
                [sl, np.broadcast_to(fill, (per - sl.shape[0], vs.shape[1]))]
            )
        tbl = build_mod.build_neighbor_table(sl, cfg, storage=storage)
        vlist.append(storage_mod.encode_vectors(sl, storage))
        nlist.append(tbl)
        bounds.append((lo, hi))
        logn = tbl.shape[1] - 1
    return ShardedRangeIndex(
        np.stack(vlist), np.stack(nlist), np.asarray(bounds, np.int32),
        logn, cfg.m, storage,
    )


def shard_topk(
    vec, nbr, bnd, q, Lq, Rq, *,
    logn, m, k, config: SearchConfig | None = None, ef=None,
    expand_width=None, dist_impl=None, edge_impl=None,
):
    """One shard's clipped local search -> global-id top-k candidates.

    The per-shard body of ``rfann_serve_step``, factored out so the same
    code path — including the compact-storage decode and the padded-tail /
    empty-clip masking — runs under shard_map on a ``data`` mesh axis and
    plain per-shard on hosts (tests, single-process serving).

    vec [n_shard, d] (any storage dtype); nbr [n_shard, layers, m] (any
    neighbor codec); bnd i32[2] the shard's real global rank range; q
    [B, d]; Lq/Rq i32[B] global rank ranges. Engine knobs come from
    ``config`` (loose kwargs = deprecation shim). Returns (ids, dists)
    [B, k] with ids global (-1 padded) and dists inf-padded.
    """
    config = config_mod.merge(
        config, ef=ef, expand_width=expand_width, dist_impl=dist_impl,
        edge_impl=edge_impl, _warn_where="shard_topk",
    )
    # compact storage: ids widen through the one -1-preserving decode
    # (core/storage.py); vectors stay bf16/f16 down to the kernels
    nbr = storage_mod.decode_neighbors(nbr)
    lo, hi = bnd[0], bnd[1]
    # clip the global range to this shard's rank range, local coords;
    # hi is the REAL range end, so any padded tail rows stay > Rl and are
    # never entered, traversed into, or returned
    Ll = jnp.clip(Lq - lo, 0, vec.shape[0] - 1).astype(jnp.int32)
    Rl = (jnp.minimum(Rq, hi) - lo).astype(jnp.int32)
    empty = (Rq < lo) | (Lq > hi)
    # an empty clip becomes the L > R range, which yields no entry
    # points and therefore no results
    Ll = jnp.where(empty, 1, Ll)
    Rl = jnp.where(empty, 0, Rl)
    res = search_mod.search_improvised(
        vec, nbr, q, Ll, Rl, logn=logn, m_out=m, k=k, config=config,
    )
    ids = jnp.where(
        (res.ids >= 0) & ~empty[:, None], res.ids + lo, -1
    )
    dists = jnp.where(ids >= 0, res.dists, jnp.inf)
    return ids, dists


def merge_topk(all_ids, all_d, k):
    """Merge stacked per-shard candidates [S, B, k] -> global top-k [B, k].

    The one merge both the all-gather path and host-side consumers use.
    """
    S, B = all_ids.shape[0], all_ids.shape[1]
    flat_i = jnp.moveaxis(all_ids, 0, 1).reshape(B, S * k)
    flat_d = jnp.moveaxis(all_d, 0, 1).reshape(B, S * k)
    _, take = jax.lax.top_k(-flat_d, k)
    out_i = jnp.take_along_axis(flat_i, take, 1)
    out_d = jnp.take_along_axis(flat_d, take, 1)
    return out_i, out_d


def rfann_serve_step(
    shard_vectors,    # f32/bf16[S, n_shard, d]   sharded: ("data", None, None)
    shard_neighbors,  # i32/i16[S, n_shard, layers, m]  sharded likewise
    shard_bounds,     # i32[S, 2]
    queries,          # f32[B, d]            sharded: ("model", None)
    L, R,             # i32[B] global rank ranges
    *,
    mesh: Mesh,
    logn: int,
    m: int,
    k: int,
    config: SearchConfig | None = None,
    ef: int | None = None,
    expand_width: int | None = None,
    dist_impl: str | None = None,
    edge_impl: str | None = None,
):
    """Batched distributed RFANN query under shard_map. Engine knobs come
    from ``config`` (loose kwargs = deprecation shim)."""
    config = config_mod.merge(
        config, ef=ef, expand_width=expand_width, dist_impl=dist_impl,
        edge_impl=edge_impl, _warn_where="rfann_serve_step",
    )

    have_pod = "pod" in mesh.shape
    query_spec = P(("pod", "model")) if have_pod else P("model")

    def local(vec, nbr, bnd, q, Lq, Rq):
        # leading shard dim is mapped over the data axis
        ids, dists = shard_topk(
            vec[0], nbr[0], bnd[0], q, Lq, Rq,
            logn=logn, m=m, k=k, config=config,
        )
        # merge across the data axis: gather all shards' top-k
        all_ids = jax.lax.all_gather(ids, "data", axis=0)      # [S, B, k]
        all_d = jax.lax.all_gather(dists, "data", axis=0)
        return merge_topk(all_ids, all_d, k)

    fn = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P("data"), P("data"), P("data"),
            query_spec, query_spec, query_spec,
        ),
        out_specs=(query_spec, query_spec),
        check_vma=False,
    )
    return fn(shard_vectors, shard_neighbors, shard_bounds, queries, L, R)


def make_serve_jit(mesh: Mesh, *, logn, m, k, config=None, ef=None,
                   expand_width=None, dist_impl=None, edge_impl=None):
    """jit wrapper with shardings bound — what the dry-run lowers."""
    config = config_mod.merge(
        config, ef=ef, expand_width=expand_width, dist_impl=dist_impl,
        edge_impl=edge_impl, _warn_where="make_serve_jit",
    )

    @functools.partial(jax.jit, static_argnums=())
    def step(shard_vectors, shard_neighbors, shard_bounds, queries, L, R):
        return rfann_serve_step(
            shard_vectors, shard_neighbors, shard_bounds, queries, L, R,
            mesh=mesh, logn=logn, m=m, k=k, config=config,
        )

    return step
