"""Batched greedy beam search engines.

The paper's query phase is greedy beam search (HNSW-style dynamic list of
size ``ef``) over a graph whose edges are improvised per query range
(Algorithm 1). On TPU the priority-queue formulation becomes a fixed-shape
lockstep loop:

  * per-query state: candidate list ``(ids, dists, visited)`` of size ``ef``
    holding the best-so-far, a visited bitmap over the dataset, an active
    flag;
  * each iteration expands the best unvisited candidate of every active query
    simultaneously, gathers its (improvised) out-edges, computes distances in
    one batched op (the Pallas distance kernel on TPU), and merges with a
    single ``top_k``;
  * termination (best unvisited worse than the worst of a full list) becomes
    a mask; finished queries coast.

``beam_search`` is generic over a ``nbr_fn`` so the same engine serves the
improvised graph, single elemental graphs (index construction, BasicSearch,
SuperPostfiltering), the root graph with post-/in-filtering, and the
multi-attribute variant.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import edge_select

__all__ = [
    "SearchResult",
    "beam_search",
    "search_improvised",
    "search_fixed_layer",
    "search_filtered",
]

_INF = jnp.float32(jnp.inf)


class SearchResult(NamedTuple):
    ids: jnp.ndarray      # int32[B, k] (-1 padded)
    dists: jnp.ndarray    # float32[B, k]
    n_hops: jnp.ndarray   # int32[B]   nodes expanded
    n_dists: jnp.ndarray  # int32[B]   distance computations


def _pairdist(q, x, metric):
    """Distance between queries q[B, d] and points x[B, M, d] -> [B, M].

    Inputs may be bf16 (the storage-dtype hillclimb); math is f32.
    """
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    if metric == "l2":
        # ||x||^2 - 2 x.q + ||q||^2 ; keep ||q||^2 for exactness of ordering
        xx = jnp.sum(x * x, axis=-1)
        qq = jnp.sum(q * q, axis=-1, keepdims=True)
        xq = jnp.einsum("bd,bmd->bm", q, x)
        return xx - 2.0 * xq + qq
    if metric == "ip":
        return -jnp.einsum("bd,bmd->bm", q, x)
    raise ValueError(f"unknown metric {metric!r}")


def beam_search(
    vectors: jnp.ndarray,          # f32[n, d]
    queries: jnp.ndarray,          # f32[B, d]
    entry_ids: jnp.ndarray,        # int32[B, E] (-1 for unused)
    nbr_fn: Callable,              # int32[B] -> int32[B, M]
    *,
    ef: int,
    k: int,
    max_iters: int | None = None,
    metric: str = "l2",
    result_filter_fn: Callable | None = None,
    visit_prob_fn: Callable | None = None,
    rng: jax.Array | None = None,
) -> SearchResult:
    """Generic batched beam search. See module docstring.

    result_filter_fn: optional ``ids[B,M] -> bool[B,M]``; when given, the
      navigation list accepts everything but the *result* list only accepts
      ids passing the filter (multi-attribute post-filtering semantics).
    visit_prob_fn: optional ``(ids[B,M], t[B]) -> p[B,M]`` probability of
      visiting an id that fails the result filter (the paper's §4
      generalization; p=1 is post-filtering, p=0 in-filtering). Requires rng.
    """
    n, d = vectors.shape
    B = queries.shape[0]
    if max_iters is None:
        max_iters = 4 * ef + 32

    two_lists = result_filter_fn is not None

    def init_state():
        e = entry_ids
        valid = e >= 0
        ex = vectors[jnp.maximum(e, 0)]
        dists = jnp.where(valid, _pairdist(queries, ex, metric), _INF)
        E = e.shape[1]
        pad = ef - E
        cand_ids = jnp.concatenate(
            [jnp.where(valid, e, -1), jnp.full((B, pad), -1, jnp.int32)], axis=1
        )
        cand_dists = jnp.concatenate([dists, jnp.full((B, pad), _INF)], axis=1)
        cand_vis = jnp.zeros((B, ef), bool)
        visited = jnp.zeros((B, n), bool)
        visited = _mark(visited, e, valid)
        if two_lists:
            ok = result_filter_fn(jnp.maximum(e, 0)) & valid
            res_ids = jnp.concatenate(
                [jnp.where(ok, e, -1), jnp.full((B, pad), -1, jnp.int32)], 1
            )
            res_dists = jnp.concatenate(
                [jnp.where(ok, dists, _INF), jnp.full((B, pad), _INF)], 1
            )
        else:
            res_ids = cand_ids
            res_dists = cand_dists
        t = jnp.zeros((B,), jnp.int32)  # consecutive out-of-range counter
        stats = (jnp.zeros((B,), jnp.int32), jnp.sum(valid, 1, dtype=jnp.int32))
        key = rng if rng is not None else jax.random.PRNGKey(0)
        return (
            cand_ids, cand_dists, cand_vis, visited,
            res_ids, res_dists, t, jnp.ones((B,), bool), stats, key,
            jnp.int32(0),
        )

    def _mark(visited, ids, valid):
        b = jnp.arange(B)[:, None]
        return visited.at[b, jnp.maximum(ids, 0)].max(valid)

    def cond(state):
        *_, active, _stats, _key, it = state
        return jnp.any(active) & (it < max_iters)

    def body(state):
        (cand_ids, cand_dists, cand_vis, visited,
         res_ids, res_dists, t, active, stats, key, it) = state
        n_hops, n_dists = stats

        unvisited = jnp.where(
            cand_vis | (cand_ids < 0), _INF, cand_dists
        )
        best_slot = jnp.argmin(unvisited, axis=1)
        best_dist = jnp.take_along_axis(unvisited, best_slot[:, None], 1)[:, 0]
        worst = jnp.max(jnp.where(cand_ids >= 0, cand_dists, -_INF), axis=1)
        full = jnp.all(cand_ids >= 0, axis=1)
        progress = jnp.isfinite(best_dist) & (~full | (best_dist <= worst))
        active = active & progress

        u = jnp.take_along_axis(cand_ids, best_slot[:, None], 1)[:, 0]
        u = jnp.where(active, u, -1)
        cand_vis = jnp.where(
            active[:, None]
            & (jnp.arange(ef)[None, :] == best_slot[:, None]),
            True,
            cand_vis,
        )
        n_hops = n_hops + active.astype(jnp.int32)

        nbr = nbr_fn(u)                       # [B, M]
        M = nbr.shape[1]
        nvalid = (nbr >= 0) & active[:, None]
        b = jnp.arange(B)[:, None]
        seen = visited[b, jnp.maximum(nbr, 0)]
        nvalid &= ~seen

        if two_lists:
            in_rng = result_filter_fn(jnp.maximum(nbr, 0))
            if visit_prob_fn is not None:
                key, sub = jax.random.split(key)
                p = visit_prob_fn(jnp.maximum(nbr, 0), t)
                coin = jax.random.uniform(sub, (B, M))
                visit_out = coin < p
            else:
                visit_out = jnp.ones((B, M), bool)  # post-filtering
            nvalid &= in_rng | visit_out
            # consecutive out-of-range counter follows the expanded node u
            u_in = result_filter_fn(jnp.maximum(u, 0)[:, None])[:, 0]
            u_out = ~u_in & (u >= 0)
            t = jnp.where(active, jnp.where(u_out, t + 1, 0), t)

        visited = _mark(visited, nbr, nvalid)
        nx = vectors[jnp.maximum(nbr, 0)]
        ndist = jnp.where(nvalid, _pairdist(queries, nx, metric), _INF)
        n_dists = n_dists + jnp.sum(nvalid, axis=1, dtype=jnp.int32)

        # merge into navigation list
        all_ids = jnp.concatenate([cand_ids, jnp.where(nvalid, nbr, -1)], 1)
        all_dists = jnp.concatenate([cand_dists, ndist], 1)
        all_vis = jnp.concatenate([cand_vis, jnp.zeros((B, M), bool)], 1)
        _, idx = jax.lax.top_k(-all_dists, ef)
        cand_ids = jnp.take_along_axis(all_ids, idx, 1)
        cand_dists = jnp.take_along_axis(all_dists, idx, 1)
        cand_vis = jnp.take_along_axis(all_vis, idx, 1)

        if two_lists:
            rvalid = nvalid & in_rng
            r_ids = jnp.concatenate([res_ids, jnp.where(rvalid, nbr, -1)], 1)
            r_dists = jnp.concatenate(
                [res_dists, jnp.where(rvalid, ndist, _INF)], 1
            )
            _, ridx = jax.lax.top_k(-r_dists, ef)
            res_ids = jnp.take_along_axis(r_ids, ridx, 1)
            res_dists = jnp.take_along_axis(r_dists, ridx, 1)
        else:
            res_ids, res_dists = cand_ids, cand_dists

        return (cand_ids, cand_dists, cand_vis, visited,
                res_ids, res_dists, t, active, (n_hops, n_dists), key,
                it + 1)

    state = init_state()
    state = jax.lax.while_loop(cond, body, state)
    (_, _, _, _, res_ids, res_dists, _, _, stats, _, _) = state
    _, idx = jax.lax.top_k(-res_dists, k)
    out_ids = jnp.take_along_axis(res_ids, idx, 1)
    out_dists = jnp.take_along_axis(res_dists, idx, 1)
    out_ids = jnp.where(jnp.isfinite(out_dists), out_ids, -1)
    return SearchResult(out_ids, out_dists, stats[0], stats[1])


# ---------------------------------------------------------------------------
# Entry-point helpers
# ---------------------------------------------------------------------------

def range_entry_ids(L, R, n, num_entries=3):
    """Deterministic in-range entry points: midpoint + quartiles of [L, R]."""
    fracs = jnp.array([0.5, 0.25, 0.75, 0.0, 1.0][:num_entries])
    span = (R - L).astype(jnp.float32)[..., None]
    ids = L[..., None] + jnp.round(span * fracs[None, :]).astype(jnp.int32)
    ids = jnp.clip(ids, 0, n - 1)
    # dedupe within the row: later duplicates -> -1
    sortd = jnp.sort(ids, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(sortd[..., :1], bool), sortd[..., 1:] == sortd[..., :-1]],
        axis=-1,
    )
    return jnp.where(dup, -1, sortd)


# ---------------------------------------------------------------------------
# Concrete searches
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("logn", "m_out", "ef", "k", "skip_layers", "metric",
                     "max_iters"),
)
def search_improvised(
    vectors, nbrs, queries, L, R, *, logn, m_out, ef, k,
    skip_layers=True, metric="l2", max_iters=None,
):
    """The paper's query path: beam search on the improvised dedicated graph.

    L, R: int32[B] per-query inclusive rank ranges.
    """
    n = vectors.shape[0]
    entries = range_entry_ids(L, jnp.minimum(R, n - 1), n)
    ok = (entries >= L[:, None]) & (entries <= R[:, None])
    entries = jnp.where(ok, entries, -1)

    def nbr_fn(u):
        return edge_select.select_edges_batch(
            nbrs, u, L, R, logn=logn, m_out=m_out, skip_layers=skip_layers
        )

    return beam_search(
        vectors, queries, entries, nbr_fn, ef=ef, k=k, metric=metric,
        max_iters=max_iters,
    )


@functools.partial(
    jax.jit,
    static_argnames=("layer", "ef", "k", "metric", "max_iters"),
)
def search_fixed_layer(
    vectors, nbrs, queries, seg_lo, seg_hi, *, layer, ef, k,
    metric="l2", max_iters=None,
):
    """Beam search on one elemental graph (segment ``[seg_lo, seg_hi]`` at
    ``layer``). Used during construction, and by BasicSearch /
    SuperPostfiltering baselines."""
    n = vectors.shape[0]
    hi_real = jnp.minimum(seg_hi, n - 1)
    entries = range_entry_ids(seg_lo, hi_real, n)
    # guard: empty / padded-away segments contribute no entry points, and an
    # entry must actually lie inside its segment
    ok = (
        (seg_lo[:, None] <= hi_real[:, None])
        & (entries >= seg_lo[:, None])
        & (entries <= hi_real[:, None])
    )
    entries = jnp.where(ok, entries, -1)

    def nbr_fn(u):
        row = nbrs[jnp.maximum(u, 0), layer, :]
        ok = (row >= 0) & (row >= seg_lo[:, None]) & (row <= seg_hi[:, None])
        return jnp.where(ok & (u >= 0)[:, None], row, -1)

    return beam_search(
        vectors, queries, entries, nbr_fn, ef=ef, k=k, metric=metric,
        max_iters=max_iters,
    )


@functools.partial(
    jax.jit,
    static_argnames=("mode", "ef", "k", "metric", "max_iters"),
)
def search_filtered(
    vectors, nbrs, queries, L, R, *, mode, ef, k, metric="l2",
    max_iters=None, rng=None,
):
    """Post-/In-filtering baselines on the root elemental graph (layer 0).

    mode: "post" visits everything, keeps in-range results;
          "in"   only traverses in-range neighbors.
    """
    n = vectors.shape[0]
    mid = jnp.clip((L + R) // 2, 0, n - 1)
    entries = jnp.stack([mid, jnp.zeros_like(mid) + n // 2], axis=1)

    def filt(ids):
        return (ids >= L[:, None]) & (ids <= R[:, None])

    def nbr_fn(u):
        row = nbrs[jnp.maximum(u, 0), 0, :]
        ok = (row >= 0) & (u >= 0)[:, None]
        if mode == "in":
            ok &= filt(row)
        return jnp.where(ok, row, -1)

    return beam_search(
        vectors, queries, entries, nbr_fn, ef=ef, k=k, metric=metric,
        max_iters=max_iters,
        result_filter_fn=filt,
        rng=rng,
    )
