"""Batched greedy beam search engines (fused hot path).

The paper's query phase is greedy beam search (HNSW-style dynamic list of
size ``ef``) over a graph whose edges are improvised per query range
(Algorithm 1). On TPU the priority-queue formulation becomes a fixed-shape
lockstep loop; this module is the performance-tuned engine (DESIGN.md §3):

  * per-query state: candidate list ``(ids, dists, visited)`` of size ``ef``
    holding the best-so-far, a *packed* ``uint32[B, ceil(n/32)]`` visited
    bitset (``core/bitset.py``), an active flag;
  * each iteration expands the top ``expand_width`` unvisited candidates of
    every active query simultaneously; their edge selections run as ONE
    batched call of shape ``[B*W]``, so per-iteration fixed costs (edge
    selection, top-k merge) amortize over W expansions;
  * neighbor distances come from the fused gather-distance kernel
    (``kernels/gather_distance.py``) on TPU — no ``[B, M, d]`` HBM
    intermediate — and from the XLA gather+einsum reference elsewhere;
  * edge improvisation dispatches through ``kernels/ops.py::select_edges``
    (``edge_impl`` knob): the Pallas edge-selection kernel on TPU, the
    sort-free jnp formulation elsewhere — bit-identical ids either way;
  * termination (best unvisited worse than the worst of a full list) becomes
    a mask; finished queries coast.

``beam_search`` is generic over a ``nbr_fn`` so the same engine serves the
improvised graph, single elemental graphs (index construction, BasicSearch,
SuperPostfiltering), the root graph with post-/in-filtering, and the
multi-attribute variant. **nbr_fn contract**: it receives the *flattened*
expansion frontier ``int32[B*W]`` (row ``b*W + w`` is query b's w-th
expansion, ``-1`` for inactive slots) and must return ``int32[B*W, M]``.

Alternatively a caller may bind the *whole* hop: with ``hop_fn`` given, the
edge-selection + visited-test-and-set + gather-distance middle of the loop
body runs as one call (``kernels/ops.py::hop`` — on TPU the fused Pallas
megakernel, one launch per beam iteration with the frontier resident in
VMEM). **hop_fn contract**: ``(u int32[B, W], exp_ok bool[B, W],
visited uint32[B, words]) -> (nbr int32[B, W*M], ndist f32[B, W*M],
nvalid bool[B, W*M], visited')`` with the same semantics as the composed
path (``kernels/ref.py::hop``) — integer outputs bit-identical, distances
f32. The two-list filtered searches keep the composed body (their
range-filter hook lives between edge selection and the visited update).

Engine knobs arrive as ONE frozen ``core/config.py::SearchConfig`` (a
static arg of the jitted searches, so equal configs share one compiled
program — the contract ``serve/executor.py`` builds its compile cache on).
The historical loose kwargs (``ef=``, ``expand_width=``, ...) remain as a
deprecation shim resolved by ``config.merge``; ``k`` stays per-call.

With ``expand_width=1`` the engine is bit-identical (ids and dists) to the
reference implementation in ``core/search_ref.py``; tests enforce this.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bitset
from repro.core import config as config_mod
from repro.core import storage as storage_mod
from repro.core.config import DEFAULT_EXPAND_WIDTH, SearchConfig
from repro.kernels import ops

__all__ = [
    "SearchResult",
    "SearchConfig",
    "beam_search",
    "effective_expand_width",
    "search_improvised",
    "search_fixed_layer",
    "search_filtered",
]

_INF = jnp.float32(jnp.inf)


def effective_expand_width(expand_width: int, ef: int) -> int:
    """The W beam_search will actually run: clamped to the ef-sized
    candidate list. Every caller that tiles per-query state into a [B*W]
    frontier for its nbr_fn MUST use this same value."""
    w = int(expand_width)
    if w < 1:
        raise ValueError(f"expand_width must be >= 1, got {w}")
    return min(w, ef)


class SearchResult(NamedTuple):
    ids: jnp.ndarray      # int32[B, k] (-1 padded)
    dists: jnp.ndarray    # float32[B, k]
    n_hops: jnp.ndarray   # int32[B]   nodes expanded
    n_dists: jnp.ndarray  # int32[B]   distance computations


def _pairdist(q, x, metric):
    """Distance between queries q[B, d] and points x[B, M, d] -> [B, M].

    Inputs may be bf16 (the storage-dtype hillclimb); math is f32. Kept for
    benchmarks/tests; the engine itself uses ``ops.gather_dist``.
    """
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    if metric == "l2":
        # ||x||^2 - 2 x.q + ||q||^2 ; keep ||q||^2 for exactness of ordering
        xx = jnp.sum(x * x, axis=-1)
        qq = jnp.sum(q * q, axis=-1, keepdims=True)
        xq = jnp.einsum("bd,bmd->bm", q, x)
        return xx - 2.0 * xq + qq
    if metric == "ip":
        return -jnp.einsum("bd,bmd->bm", q, x)
    raise ValueError(f"unknown metric {metric!r}")


def beam_search(
    vectors: jnp.ndarray,          # f32[n, d]
    queries: jnp.ndarray,          # f32[B, d]
    entry_ids: jnp.ndarray,        # int32[B, E] (-1 for unused)
    nbr_fn: Callable,              # int32[B*W] -> int32[B*W, M]
    *,
    k: int,
    config: SearchConfig | None = None,
    ef: int | None = None,
    expand_width: int | None = None,
    max_iters: int | None = None,
    metric: str | None = None,
    result_filter_fn: Callable | None = None,
    visit_prob_fn: Callable | None = None,
    rng: jax.Array | None = None,
    dist_impl: str | None = None,
    edge_impl: str | None = None,
    hop_impl: str | None = None,
    hop_fn: Callable | None = None,
) -> SearchResult:
    """Generic batched beam search. See module docstring.

    config: the engine knobs as ONE frozen ``SearchConfig``; ``k`` stays
      per-call. The loose kwargs below are the deprecation shim (resolved
      onto the config by ``config.merge``; non-None values win).
    config.expand_width: number of unvisited candidates expanded per query
      per iteration (static). 1 reproduces the reference engine bit-for-bit.
    result_filter_fn: optional ``ids[B,K] -> bool[B,K]``; when given, the
      navigation list accepts everything but the *result* list only accepts
      ids passing the filter (multi-attribute post-filtering semantics).
    visit_prob_fn: optional ``(ids[B,K], t[B]) -> p[B,K]`` probability of
      visiting an id that fails the result filter (the paper's §4
      generalization; p=1 is post-filtering, p=0 in-filtering). Requires rng.
    config.dist_impl: "auto" | "pallas" | "xla" distance backend (see
      kernels/ops).
    config.edge_impl: edge-selection backend, same value set plus "argsort".
      The generic engine performs no edge selection itself (``nbr_fn``
      arrives pre-bound), but the knob lives in the config so every wrapper
      forwards one uniform backend set; concrete searches bind it into
      their ``nbr_fn`` via ``ops.select_edges``.
    hop_fn: optional whole-hop closure (see module docstring). Mutually
      exclusive with ``result_filter_fn`` — the two-list searches hook the
      range filter *between* edge selection and the visited update, which
      only the composed body exposes.
    """
    config = config_mod.merge(
        config, ef=ef, expand_width=expand_width, max_iters=max_iters,
        metric=metric, dist_impl=dist_impl, edge_impl=edge_impl,
        hop_impl=hop_impl,
    )
    if hop_fn is not None and result_filter_fn is not None:
        raise ValueError(
            "beam_search: hop_fn is incompatible with result_filter_fn "
            "(filtered searches need the composed hop body)"
        )
    if hop_fn is None and nbr_fn is None:
        raise ValueError("beam_search: need nbr_fn or hop_fn")
    ef = config.ef
    metric = config.metric
    dist_impl = config.dist_impl
    # vectors may be a quantized codec struct (storage.Int8Vectors /
    # storage.PQVectors); the distance kernels decode per-row in VMEM
    n = storage_mod.table_n(vectors)
    B = queries.shape[0]
    W = effective_expand_width(config.expand_width, ef)
    max_iters = config.max_iters
    if max_iters is None:
        max_iters = 4 * ef + 32

    two_lists = result_filter_fn is not None

    def gdist(ids):
        return ops.gather_dist(
            queries, vectors, ids, metric=metric, impl=dist_impl
        )

    def init_state():
        e = entry_ids
        valid = e >= 0
        dists = gdist(jnp.where(valid, e, -1))
        E = e.shape[1]
        pad = ef - E
        cand_ids = jnp.concatenate(
            [jnp.where(valid, e, -1), jnp.full((B, pad), -1, jnp.int32)], axis=1
        )
        cand_dists = jnp.concatenate([dists, jnp.full((B, pad), _INF)], axis=1)
        cand_vis = jnp.zeros((B, ef), bool)
        visited, _ = bitset.test_and_set(bitset.make(B, n), e, valid)
        if two_lists:
            ok = result_filter_fn(jnp.maximum(e, 0)) & valid
            res_ids = jnp.concatenate(
                [jnp.where(ok, e, -1), jnp.full((B, pad), -1, jnp.int32)], 1
            )
            res_dists = jnp.concatenate(
                [jnp.where(ok, dists, _INF), jnp.full((B, pad), _INF)], 1
            )
        else:
            res_ids = cand_ids
            res_dists = cand_dists
        t = jnp.zeros((B,), jnp.int32)  # consecutive out-of-range counter
        stats = (jnp.zeros((B,), jnp.int32), jnp.sum(valid, 1, dtype=jnp.int32))
        key = rng if rng is not None else jax.random.PRNGKey(0)
        return (
            cand_ids, cand_dists, cand_vis, visited,
            res_ids, res_dists, t, jnp.ones((B,), bool), stats, key,
            jnp.int32(0),
        )

    def cond(state):
        *_, active, _stats, _key, it = state
        return jnp.any(active) & (it < max_iters)

    def body(state):
        (cand_ids, cand_dists, cand_vis, visited,
         res_ids, res_dists, t, active, stats, key, it) = state
        n_hops, n_dists = stats

        unvisited = jnp.where(
            cand_vis | (cand_ids < 0), _INF, cand_dists
        )
        # top-W unvisited candidates; slot 0 is the argmin, so the classic
        # termination test reads off the first column
        neg_sel, slots = jax.lax.top_k(-unvisited, W)       # [B, W]
        sel_dists = -neg_sel
        best_dist = sel_dists[:, 0]
        worst = jnp.max(jnp.where(cand_ids >= 0, cand_dists, -_INF), axis=1)
        full = jnp.all(cand_ids >= 0, axis=1)
        progress = jnp.isfinite(best_dist) & (~full | (best_dist <= worst))
        active = active & progress

        exp_ok = active[:, None] & jnp.isfinite(sel_dists)  # [B, W]
        u = jnp.where(
            exp_ok, jnp.take_along_axis(cand_ids, slots, 1), -1
        )                                                   # [B, W]
        rows = jnp.arange(B)[:, None]
        cand_vis = cand_vis.at[rows, slots].max(exp_ok)
        n_hops = n_hops + jnp.sum(exp_ok, axis=1, dtype=jnp.int32)

        if hop_fn is not None:
            # whole hop in one call: edge selection + visited test-and-set
            # + gather-distance (on TPU one fused Pallas launch)
            nbr, ndist, nvalid, visited = hop_fn(u, exp_ok, visited)
        else:
            # ONE batched edge selection for the whole [B, W] frontier
            nbr = nbr_fn(u.reshape(B * W))                  # [B*W, M]
            M = nbr.shape[1]
            nbr = nbr.reshape(B, W * M)
            exp_rep = jnp.repeat(exp_ok, M, axis=1)         # [B, W*M]
            pre_valid = (nbr >= 0) & exp_rep

            if two_lists:
                in_rng = result_filter_fn(jnp.maximum(nbr, 0))
                if visit_prob_fn is not None:
                    key, sub = jax.random.split(key)
                    p = visit_prob_fn(jnp.maximum(nbr, 0), t)
                    coin = jax.random.uniform(sub, (B, W * M))
                    visit_out = coin < p
                else:
                    visit_out = jnp.ones((B, W * M), bool)  # post-filtering
                pre_valid &= in_rng | visit_out
                # consecutive out-of-range counter follows the expanded nodes
                u_in = result_filter_fn(jnp.maximum(u, 0)) & exp_ok
                any_exp = jnp.any(exp_ok, axis=1)
                num_out = jnp.sum(exp_ok & ~u_in, axis=1, dtype=jnp.int32)
                t = jnp.where(
                    any_exp,
                    jnp.where(jnp.any(u_in, axis=1), 0, t + num_out),
                    t,
                )

            # packed visited: one test_and_set both reads and marks, and
            # dedups the same neighbor arriving from two expansions
            visited, seen = bitset.test_and_set(visited, nbr, pre_valid)
            nvalid = pre_valid & ~seen

            # fused gather+distance: no [B, W*M, d] intermediate on TPU
            ndist = gdist(jnp.where(nvalid, nbr, -1))

        WM = nbr.shape[1]
        n_dists = n_dists + jnp.sum(nvalid, axis=1, dtype=jnp.int32)

        # merge into navigation list
        all_ids = jnp.concatenate([cand_ids, jnp.where(nvalid, nbr, -1)], 1)
        all_dists = jnp.concatenate([cand_dists, ndist], 1)
        all_vis = jnp.concatenate([cand_vis, jnp.zeros((B, WM), bool)], 1)
        _, idx = jax.lax.top_k(-all_dists, ef)
        cand_ids = jnp.take_along_axis(all_ids, idx, 1)
        cand_dists = jnp.take_along_axis(all_dists, idx, 1)
        cand_vis = jnp.take_along_axis(all_vis, idx, 1)

        if two_lists:
            rvalid = nvalid & in_rng
            r_ids = jnp.concatenate([res_ids, jnp.where(rvalid, nbr, -1)], 1)
            r_dists = jnp.concatenate(
                [res_dists, jnp.where(rvalid, ndist, _INF)], 1
            )
            _, ridx = jax.lax.top_k(-r_dists, ef)
            res_ids = jnp.take_along_axis(r_ids, ridx, 1)
            res_dists = jnp.take_along_axis(r_dists, ridx, 1)
        else:
            res_ids, res_dists = cand_ids, cand_dists

        return (cand_ids, cand_dists, cand_vis, visited,
                res_ids, res_dists, t, active, (n_hops, n_dists), key,
                it + 1)

    state = init_state()
    state = jax.lax.while_loop(cond, body, state)
    (_, _, _, _, res_ids, res_dists, _, _, stats, _, _) = state
    _, idx = jax.lax.top_k(-res_dists, k)
    out_ids = jnp.take_along_axis(res_ids, idx, 1)
    out_dists = jnp.take_along_axis(res_dists, idx, 1)
    out_ids = jnp.where(jnp.isfinite(out_dists), out_ids, -1)
    return SearchResult(out_ids, out_dists, stats[0], stats[1])


# ---------------------------------------------------------------------------
# Entry-point helpers
# ---------------------------------------------------------------------------

def range_entry_ids(L, R, n, num_entries=3):
    """Deterministic in-range entry points: midpoint + quartiles of [L, R]."""
    fracs = jnp.array([0.5, 0.25, 0.75, 0.0, 1.0][:num_entries])
    span = (R - L).astype(jnp.float32)[..., None]
    ids = L[..., None] + jnp.round(span * fracs[None, :]).astype(jnp.int32)
    ids = jnp.clip(ids, 0, n - 1)
    # dedupe within the row: later duplicates -> -1
    sortd = jnp.sort(ids, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(sortd[..., :1], bool), sortd[..., 1:] == sortd[..., :-1]],
        axis=-1,
    )
    return jnp.where(dup, -1, sortd)


def tile_frontier(x, expand_width):
    """Repeat per-query values to the flattened [B*W] frontier layout."""
    return jnp.repeat(x, expand_width, axis=0)


# ---------------------------------------------------------------------------
# Concrete searches
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("logn", "m_out", "k", "config"))
def _search_improvised_jit(vectors, nbrs, queries, L, R, rerank_store=None,
                           *, logn, m_out, k, config: SearchConfig):
    """The jitted improvised-search program: ONE static ``config`` instead
    of a kwarg pile, so equal configs share a compiled program — the unit
    ``serve/executor.py`` AOT-compiles and caches.

    ``rerank_store`` is the optional exact(er) sidecar table for
    ``config.rerank > 0`` (DESIGN.md §9): the beam returns its top-``r``
    candidates, which are re-scored exactly against the sidecar (falling
    back to the navigation ``vectors`` when None — a no-op refinement for
    exact codecs) and re-cut to ``k`` — all inside this one jit, so the
    executor's compile accounting sees a single program.
    """
    nbrs = storage_mod.decode_neighbors(nbrs)
    n = storage_mod.table_n(vectors)
    expand_width = effective_expand_width(config.expand_width, config.ef)
    entries = range_entry_ids(L, jnp.minimum(R, n - 1), n)
    ok = (entries >= L[:, None]) & (entries <= R[:, None])
    entries = jnp.where(ok, entries, -1)
    Lw = tile_frontier(L, expand_width)
    Rw = tile_frontier(R, expand_width)

    # the whole hop dispatches as one unit: config.hop_impl picks the fused
    # megakernel (pallas/xla) or the composed three-op path, inside which
    # the per-op edge_impl/dist_impl knobs still apply
    def hop_fn(u, exp_ok, visited):
        return ops.hop(
            queries, vectors, nbrs, u, Lw, Rw, visited, exp_ok,
            logn=logn, m_out=m_out, skip_layers=config.skip_layers,
            metric=config.metric, impl=config.hop_impl,
            edge_impl=config.edge_impl, dist_impl=config.dist_impl,
        )

    r = max(k, min(config.rerank, config.ef)) if config.rerank else 0
    res = beam_search(
        vectors, queries, entries, None, k=r or k, config=config,
        hop_fn=hop_fn,
    )
    if not r:
        return res
    store = vectors if rerank_store is None else rerank_store
    ids = res.ids                                          # [B, r]
    x = storage_mod.decode_rows(store, jnp.maximum(ids, 0))  # [B, r, d] f32
    qf = queries.astype(jnp.float32)
    if config.metric == "ip":
        dd = -jnp.einsum("bd,brd->br", qf, x)
    else:
        dd = ((x - qf[:, None, :]) ** 2).sum(-1)
    dd = jnp.where(ids < 0, jnp.inf, dd)
    _, take = jax.lax.top_k(-dd, k)
    out_ids = jnp.take_along_axis(ids, take, 1)
    out_dists = jnp.take_along_axis(dd, take, 1)
    out_ids = jnp.where(jnp.isfinite(out_dists), out_ids, -1)
    return SearchResult(out_ids, out_dists, res.n_hops, res.n_dists)


def search_improvised(
    vectors, nbrs, queries, L, R, *, logn, m_out, k,
    config: SearchConfig | None = None, rerank_store=None, ef=None,
    skip_layers=None, metric=None, max_iters=None, expand_width=None,
    dist_impl=None, edge_impl=None, hop_impl=None,
):
    """The paper's query path: beam search on the improvised dedicated graph.

    L, R: int32[B] per-query inclusive rank ranges. ``vectors``/``nbrs`` may
    arrive in any storage codec (bf16/f16/int8/PQ vectors, int16/split ids):
    the neighbor table decodes once here, outside the hop loop; vectors stay
    encoded end-to-end (the distance kernels decode in-register, DESIGN.md
    §9). ``rerank_store`` + ``config.rerank`` enable the in-jit exact
    refinement pass over the sidecar table.

    Knobs come from ``config`` (one frozen ``SearchConfig``); the loose
    kwargs are the deprecation shim.
    """
    config = config_mod.merge(
        config, ef=ef, skip_layers=skip_layers, metric=metric,
        max_iters=max_iters, expand_width=expand_width, dist_impl=dist_impl,
        edge_impl=edge_impl, hop_impl=hop_impl,
        _warn_where="search_improvised",
    )
    return _search_improvised_jit(
        vectors, nbrs, queries, L, R, rerank_store, logn=logn, m_out=m_out,
        k=k, config=config,
    )


@functools.partial(jax.jit, static_argnames=("layer", "k", "config"))
def _search_fixed_layer_jit(vectors, nbrs, queries, seg_lo, seg_hi, *,
                            layer, k, config: SearchConfig):
    nbrs = storage_mod.decode_neighbors(nbrs)
    n = storage_mod.table_n(vectors)
    hi_real = jnp.minimum(seg_hi, n - 1)
    entries = range_entry_ids(seg_lo, hi_real, n)
    # guard: empty / padded-away segments contribute no entry points, and an
    # entry must actually lie inside its segment
    ok = (
        (seg_lo[:, None] <= hi_real[:, None])
        & (entries >= seg_lo[:, None])
        & (entries <= hi_real[:, None])
    )
    entries = jnp.where(ok, entries, -1)
    expand_width = effective_expand_width(config.expand_width, config.ef)
    low = tile_frontier(seg_lo, expand_width)
    hiw = tile_frontier(seg_hi, expand_width)

    def nbr_fn(u):
        row = nbrs[jnp.maximum(u, 0), layer, :]
        ok = (row >= 0) & (row >= low[:, None]) & (row <= hiw[:, None])
        return jnp.where(ok & (u >= 0)[:, None], row, -1)

    return beam_search(vectors, queries, entries, nbr_fn, k=k, config=config)


def search_fixed_layer(
    vectors, nbrs, queries, seg_lo, seg_hi, *, layer, k,
    config: SearchConfig | None = None, ef=None, metric=None, max_iters=None,
    expand_width=None, dist_impl=None, edge_impl=None,
):
    """Beam search on one elemental graph (segment ``[seg_lo, seg_hi]`` at
    ``layer``). Used during construction, and by BasicSearch /
    SuperPostfiltering baselines. ``config.edge_impl`` is accepted for knob
    symmetry; this search's nbr_fn is a plain row gather (no
    improvisation)."""
    config = config_mod.merge(
        config, ef=ef, metric=metric, max_iters=max_iters,
        expand_width=expand_width, dist_impl=dist_impl, edge_impl=edge_impl,
        _warn_where="search_fixed_layer",
    )
    return _search_fixed_layer_jit(
        vectors, nbrs, queries, seg_lo, seg_hi, layer=layer, k=k,
        config=config,
    )


@functools.partial(jax.jit, static_argnames=("mode", "k", "config"))
def _search_filtered_jit(vectors, nbrs, queries, L, R, rng, *, mode, k,
                         config: SearchConfig):
    nbrs = storage_mod.decode_neighbors(nbrs)
    n = storage_mod.table_n(vectors)
    mid = jnp.clip((L + R) // 2, 0, n - 1)
    entries = jnp.stack([mid, jnp.zeros_like(mid) + n // 2], axis=1)

    def filt(ids):
        return (ids >= L[:, None]) & (ids <= R[:, None])

    expand_width = effective_expand_width(config.expand_width, config.ef)
    Lw = tile_frontier(L, expand_width)
    Rw = tile_frontier(R, expand_width)

    def nbr_fn(u):
        row = nbrs[jnp.maximum(u, 0), 0, :]
        ok = (row >= 0) & (u >= 0)[:, None]
        if mode == "in":
            ok &= (row >= Lw[:, None]) & (row <= Rw[:, None])
        return jnp.where(ok, row, -1)

    return beam_search(
        vectors, queries, entries, nbr_fn, k=k, config=config,
        result_filter_fn=filt, rng=rng,
    )


def search_filtered(
    vectors, nbrs, queries, L, R, *, mode, k,
    config: SearchConfig | None = None, ef=None, metric=None, max_iters=None,
    rng=None, expand_width=None, dist_impl=None, edge_impl=None,
):
    """Post-/In-filtering baselines on the root elemental graph (layer 0).

    mode: "post" visits everything, keeps in-range results;
          "in"   only traverses in-range neighbors.
    ``config.edge_impl`` is accepted for knob symmetry (layer-0 row gather,
    no improvisation).
    """
    config = config_mod.merge(
        config, ef=ef, metric=metric, max_iters=max_iters,
        expand_width=expand_width, dist_impl=dist_impl, edge_impl=edge_impl,
        _warn_where="search_filtered",
    )
    return _search_filtered_jit(
        vectors, nbrs, queries, L, R, rng, mode=mode, k=k, config=config,
    )
