"""Typed registry for every ``REPRO_*`` environment knob.

Every env knob the repo reads is declared here once — name, type, default,
accepted values, one-line doc, and which layer consumes it — and read
through the typed accessors (:func:`get_str` / :func:`get_int` /
:func:`get_float` / :func:`get_bool` / :func:`get_list`). Two contracts
hang off the registry, both machine-checked by the repo linter
(``python -m repro.lint``, DESIGN.md §10):

  * **R1 knob-registry**: no ``os.environ`` / ``os.getenv`` access with a
    ``REPRO_*`` key exists anywhere outside this module — reading an
    unregistered knob raises ``KeyError`` here, so a knob cannot exist
    without a declared type, default and doc line;
  * **KNOBS.md generation**: ``docs/KNOBS.md`` is generated from
    :func:`generate_markdown` (``python -m repro.lint --write-knobs``) and
    R1 fails when the committed file drifts from the registry.

Accessors read the environment *at call time* (no import-time caching), so
tests and CI legs that monkeypatch ``os.environ`` keep working; pass an
explicit ``env`` mapping to resolve against something else.
"""
from __future__ import annotations

import dataclasses
import os

__all__ = [
    "Knob", "REGISTRY", "get", "raw", "get_str", "get_int", "get_float",
    "get_bool", "get_list", "generate_markdown",
]


@dataclasses.dataclass(frozen=True)
class Knob:
    """One registered environment knob (a row of docs/KNOBS.md)."""

    name: str            # the REPRO_* variable
    type: str            # "str" | "int" | "float" | "bool" | "list"
    default: object      # value the typed accessor returns when unset
    values: str          # human-readable accepted values (doc table cell)
    doc: str             # one-line effect description (doc table cell)
    section: str         # doc section key (see _SECTIONS)
    consumed_by: str = ""  # which layer reads it (dispatch table only)

    def __post_init__(self):
        if not self.name.startswith("REPRO_"):
            raise ValueError(f"knob {self.name!r} must start with REPRO_")
        if self.type not in ("str", "int", "float", "bool", "list"):
            raise ValueError(f"knob {self.name}: unknown type {self.type!r}")


REGISTRY: tuple[Knob, ...] = (
    # -- kernel dispatch (DESIGN.md §3/§4; consumed in kernels/ops.py) ------
    Knob("REPRO_IMPL", "str", None, "`xla`, `pallas`",
         "every `auto` dispatch at once", "dispatch",
         "`ops.py::default_impl` (DESIGN.md §3/§4)"),
    Knob("REPRO_DIST_IMPL", "str", None, "`xla`, `pallas`",
         "gather+distance only", "dispatch", "`ops.gather_dist`"),
    Knob("REPRO_EDGE_IMPL", "str", None, "`xla`, `argsort`, `pallas`",
         "edge selection only", "dispatch", "`ops.select_edges` (§2)"),
    Knob("REPRO_PRUNE_IMPL", "str", None, "`xla`, `pallas`, `legacy`",
         "construction prune only", "dispatch", "`ops.prune` (§4)"),
    Knob("REPRO_HOP_IMPL", "str", None, "`pallas`, `xla`, `composed`",
         "the whole-hop megakernel", "dispatch", "`ops.hop` (§3)"),
    Knob("REPRO_FLASH_IMPL", "str", None, "`xla`, `pallas`",
         "flash attention only", "dispatch", "`ops.flash_attention`"),
    # -- storage codecs (core/storage.py::default_config) -------------------
    Knob("REPRO_STORAGE", "str", None,
         "`f32` (default), `compact`, `f16`, `int8`, `pq`",
         "moves `storage_mod.default_config()`, i.e. the `StorageConfig` "
         "every build uses when the caller passes `storage=None`",
         "storage"),
    # -- serving (serve/executor.py, serve/faults.py) -----------------------
    Knob("REPRO_SERVE_WARMUP", "bool", False, "unset / `1`",
         "every newly built `SearchExecutor` / `ServingEngine` AOT-compiles "
         "its full `configs × batch_buckets × k_buckets` grid at "
         "construction (DESIGN.md §7); after warmup, a compile is a test "
         "failure", "serve"),
    Knob("REPRO_FAULTS", "list", (),
         "comma list of `latency`, `flush_error`, `queue_full`",
         "activates fault injection in `AsyncServingEngine` "
         "(`serve/loop.py` picks env faults up by default; the sync "
         "engine/executor only with explicit opt-in) — the CI chaos leg "
         "(§8)", "serve"),
    Knob("REPRO_FAULT_LATENCY_S", "float", 0.02, "float, default `0.02`",
         "injected latency spike duration", "serve"),
    Knob("REPRO_FAULT_LATENCY_RATE", "float", 0.25, "float, default `0.25`",
         "fraction of flushes hit by a latency spike", "serve"),
    Knob("REPRO_FAULT_FLUSH_ERROR_RATE", "float", 0.25, "float",
         "fraction of flushes that raise", "serve"),
    Knob("REPRO_FAULT_QUEUE_FULL_RATE", "float", 0.25, "float",
         "fraction of admissions rejected as queue-full", "serve"),
    Knob("REPRO_FAULT_SEED", "int", 0, "int",
         "deterministic fault schedule", "serve"),
    # -- build (core/build.py) ----------------------------------------------
    Knob("REPRO_CHUNK_BUDGET_MB", "int", 16, "int, default `16`",
         "cache-residency budget the construction-prune chunk auto-tuner "
         "sizes its `[chunk, C, d]` candidate block against "
         "(`core/build.py`; clamped to [256, 8192] rows). "
         "`BuildConfig.chunk` overrides per build", "build"),
    # -- io / harness -------------------------------------------------------
    Knob("REPRO_COMPRESS_LEVEL", "int", 3, "int, default `3`",
         "compression level for checkpoint / serialized-index blobs "
         "(`compressio.py`; zstd when available, zlib fallback). Callers "
         "passing an explicit `level=` win", "io"),
    Knob("REPRO_DRYRUN_DEVICES", "int", 512, "int, default `512`",
         "host-platform placeholder device count the multi-pod dry-run "
         "(`launch/dryrun.py`) forces via `XLA_FLAGS` before jax "
         "initializes — enough for the 2x16x16 mesh by default", "io"),
)

_BY_NAME = {k.name: k for k in REGISTRY}

_TRUE_FALSE = {"0": False, "false": False, "no": False, "off": False}


def get(name: str) -> Knob:
    """The registered :class:`Knob`, or ``KeyError`` naming the contract."""
    try:
        return _BY_NAME[name]
    except KeyError:
        raise KeyError(
            f"{name!r} is not a registered knob: declare it in "
            f"repro.core.knobs.REGISTRY (the R1 knob-registry contract, "
            f"DESIGN.md §10) and regenerate docs/KNOBS.md"
        ) from None


def raw(name: str, env=None) -> str | None:
    """The raw env string for a *registered* knob (``None`` when unset)."""
    knob = get(name)
    source = os.environ if env is None else env
    return source.get(knob.name)


def get_str(name: str, env=None) -> str | None:
    """Raw string value, or the registered default when unset.

    Deliberately does NOT strip/normalize — token validation (and the
    empty-string-means-unset convention for CI matrix legs) belongs to the
    consumer, exactly as with a raw ``os.environ.get``.
    """
    v = raw(name, env)
    return _BY_NAME[name].default if v is None else v


def get_int(name: str, env=None) -> int:
    v = raw(name, env)
    if v is None or not v.strip():
        return int(_BY_NAME[name].default)
    return int(v)


def get_float(name: str, env=None) -> float:
    v = raw(name, env)
    if v is None or not v.strip():
        return float(_BY_NAME[name].default)
    return float(v)


def get_bool(name: str, env=None) -> bool:
    """Unset / empty -> default; `0`/`false`/`no`/`off` -> False; else True."""
    v = raw(name, env)
    if v is None or not v.strip():
        return bool(_BY_NAME[name].default)
    return _TRUE_FALSE.get(v.strip().lower(), True)


def get_list(name: str, env=None) -> tuple[str, ...]:
    """Comma-separated list knob -> tuple of stripped non-empty tokens."""
    v = raw(name, env)
    if v is None:
        return tuple(_BY_NAME[name].default)
    return tuple(t.strip() for t in v.split(",") if t.strip())


# ---------------------------------------------------------------------------
# docs/KNOBS.md generation
# ---------------------------------------------------------------------------

_HEADER = """\
<!-- GENERATED FILE — do not edit by hand.
     Source of truth: src/repro/core/knobs.py::REGISTRY.
     Regenerate with: PYTHONPATH=src python -m repro.lint --write-knobs
     (R1 of `python -m repro.lint` fails when this file drifts.) -->

# KNOBS — every `REPRO_*` environment variable

One page for every environment knob the repo reads, what values it takes,
and which layer consumes it. These are *deployment/CI* hooks — the
programmatic way to set the same things is `SearchConfig` /
`StorageConfig` / `BuildConfig` arguments, which always win where both
exist (see precedence below). Every knob flows through the typed registry
`src/repro/core/knobs.py` (name, type, default, doc — this file is
generated from it). Cross-references point into [DESIGN.md](../DESIGN.md).
"""

_SECTIONS: tuple[tuple[str, str, str], ...] = (
    ("dispatch", "Kernel dispatch", """\
Every hot-path op in `src/repro/kernels/ops.py` takes an `impl` argument
that defaults to `"auto"` (pallas on TPU, xla elsewhere). The env knobs
force a backend without touching call sites — the hook the CI
kernel-backends matrix uses.
"""),
    ("storage", "Storage codecs", ""),
    ("serve", "Serving", ""),
    ("build", "Build", ""),
    ("io", "IO / harness", ""),
)

_DISPATCH_FOOTER = """\
**Precedence.** Per-call `impl=` argument (when not `"auto"`) beats
`REPRO_<OP>_IMPL`, which beats the global `REPRO_IMPL`, which beats the
platform auto. Unknown tokens raise (never a silent fallback), and a
token that only exists for one op — e.g. `legacy` (prune), `argsort`
(edge selection) — is rejected by the others even via the global knob.

**The hop → composed resolution.** `ops.hop` is deliberately asymmetric:
the global `REPRO_IMPL` does *not* engage the fused hop megakernel.
`REPRO_IMPL=pallas` resolves the hop to `composed` — the three-op chain
(select_edges → gather_dist → beam merge) with each inner op's `auto`
forced to pallas — so the per-op CI legs still exercise the individual
kernels. Only an explicit `REPRO_HOP_IMPL=pallas` (or TPU auto) runs the
single-launch megakernel; it also wins over `REPRO_IMPL`. A hop with
non-default per-op impls likewise routes through `composed` so those
knobs keep meaning something.
"""

_STORAGE_FOOTER = """\
`compact` = bf16 vectors + auto-narrow (int16/int32) neighbor ids
(DESIGN.md §storage). `f16` = same with float16 vectors (faster on CPU
hosts where bf16 is emulated). `int8` = per-vector scaled int8 + split
segment-offset neighbor ids (§9, ~0.33 of f32). `pq` = product-quantized
navigation vectors + split offsets + an int8 rerank sidecar (§9, ~0.27
nav / ~0.4 total) — pair with `SearchConfig(rerank=...)` to hold recall.
An explicit `storage=StorageConfig(...)` argument always wins over the
env. Unknown tokens raise.
"""

_CI_FOOTER = """\
## Where CI sets these

The kernel-backends matrix (`.github/workflows/ci.yml`) runs the
kernel-touching suites under: `REPRO_IMPL=xla`, `REPRO_IMPL=pallas`,
`REPRO_IMPL=xla REPRO_STORAGE=compact`, `REPRO_IMPL=xla
REPRO_STORAGE=int8`, `REPRO_IMPL=pallas REPRO_STORAGE=compact
REPRO_SERVE_WARMUP=1`, and `REPRO_IMPL=xla
REPRO_FAULTS=latency,flush_error`; a separate job runs
`REPRO_HOP_IMPL=pallas` on a narrower suite (the interpreted megakernel
is slow), `lint` runs `python -m repro.lint --strict` (R1 pins this file
to the registry), and `bench-gate` replays the benchmark smokes against
the committed artifacts (`benchmarks/ci_gate.py`).
"""


def generate_markdown() -> str:
    """The exact content of ``docs/KNOBS.md`` (R1 pins the file to this)."""
    out = [_HEADER]
    for key, title, preamble in _SECTIONS:
        knobs = [k for k in REGISTRY if k.section == key]
        if not knobs:
            continue
        out.append(f"\n## {title}\n")
        if preamble:
            out.append("\n" + preamble)
        if key == "dispatch":
            out.append("\n| Variable | Values | Forces | Consumed by |\n"
                       "|---|---|---|---|\n")
            for k in knobs:
                out.append(
                    f"| `{k.name}` | {k.values} | {k.doc} "
                    f"| {k.consumed_by} |\n"
                )
            out.append("\n" + _DISPATCH_FOOTER)
        else:
            out.append("\n| Variable | Values | Effect |\n|---|---|---|\n")
            for k in knobs:
                out.append(f"| `{k.name}` | {k.values} | {k.doc} |\n")
            if key == "storage":
                out.append("\n" + _STORAGE_FOOTER)
    out.append("\n" + _CI_FOOTER)
    return "".join(out)
