"""Multi-attribute RFANN (paper §4).

The index is built on attribute A1; a conjunctive query carries a rank range
[L, R] on A1 plus value ranges on the other attributes. Search runs on the
improvised dedicated graph for [L, R]; neighbors failing the *other*
predicates are visited with probability ``p``:

  * ``p = 0``        -> In-filtering
  * ``p = 1``        -> Post-filtering
  * ``p = exp(-t)``  -> the paper's adaptive rule (iRangeGraph+), where ``t``
    is the number of consecutive out-of-range objects expanded on the search
    path — §5.2.5 reports ~70% qps gain at 0.9 recall from this.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import config as config_mod
from repro.core import search as search_mod
from repro.core import storage as storage_mod
from repro.core.config import SearchConfig
from repro.kernels import ops
from repro.core.index import RangeGraphIndex

__all__ = ["search_multiattr"]


@functools.partial(
    jax.jit, static_argnames=("logn", "m_out", "k", "mode", "config"),
)
def _search_multiattr_jit(
    vectors, nbrs, attr2, queries, L, R, lo2, hi2, rng, *,
    logn, m_out, k, mode, config: SearchConfig,
):
    nbrs = storage_mod.decode_neighbors(nbrs)
    n = storage_mod.table_n(vectors)
    entries = search_mod.range_entry_ids(L, jnp.minimum(R, n - 1), n)
    ok = (entries >= L[:, None]) & (entries <= R[:, None])
    entries = jnp.where(ok, entries, -1)
    expand_width = search_mod.effective_expand_width(
        config.expand_width, config.ef
    )
    Lw = search_mod.tile_frontier(L, expand_width)
    Rw = search_mod.tile_frontier(R, expand_width)

    def nbr_fn(u):
        return ops.select_edges(
            nbrs, u, Lw, Rw, logn=logn, m_out=m_out,
            skip_layers=config.skip_layers, impl=config.edge_impl,
        )

    def filt(ids):
        a = attr2[ids]
        return (a >= lo2[:, None]) & (a <= hi2[:, None])

    if mode == "post":
        visit_prob_fn = None
    elif mode == "in":
        def visit_prob_fn(ids, t):
            return jnp.zeros(ids.shape, jnp.float32)
    elif mode == "adaptive":
        def visit_prob_fn(ids, t):
            p = jnp.exp(-t.astype(jnp.float32))
            return jnp.broadcast_to(p[:, None], ids.shape)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    return search_mod.beam_search(
        vectors, queries, entries, nbr_fn, k=k, config=config,
        result_filter_fn=filt, visit_prob_fn=visit_prob_fn, rng=rng,
    )


def search_multiattr(
    index: RangeGraphIndex, attr2, queries, L, R, lo2, hi2, *,
    k=10, mode="adaptive", seed=0, config=None, ef=None,
    expand_width=None, dist_impl=None, edge_impl=None,
):
    """Conjunctive RFANN query.

    attr2: second attribute values in RANK-of-A1 order (i.e. aligned with
      ``index.vectors``); lo2/hi2: per-query inclusive value ranges on attr2.
    mode: "post" | "in" | "adaptive" (= iRangeGraph+'s p = exp(-t)).
    config: one frozen ``SearchConfig`` (kernel backends, ef, ...); the
      loose kwargs are the deprecation shim.
    """
    config = config_mod.merge(
        config, ef=ef, expand_width=expand_width, dist_impl=dist_impl,
        edge_impl=edge_impl, _warn_where="search_multiattr",
    )
    return _search_multiattr_jit(
        storage_mod.as_device(index.vectors),
        storage_mod.as_device(index.neighbors),
        jnp.asarray(attr2, jnp.float32),
        jnp.asarray(queries, jnp.float32),
        jnp.asarray(L, jnp.int32),
        jnp.asarray(R, jnp.int32),
        jnp.asarray(lo2, jnp.float32),
        jnp.asarray(hi2, jnp.float32),
        jax.random.PRNGKey(seed),
        logn=index.logn,
        m_out=index.m,
        k=k,
        mode=mode,
        config=config,
    )


def brute_force_multiattr(index, attr2, queries, L, R, lo2, hi2, *, k=10):
    """Exact conjunctive top-k (ground truth)."""
    import numpy as np

    q = np.asarray(queries, np.float32)
    a2 = np.asarray(attr2)
    vecs = storage_mod.decode_vectors(index.vectors)  # numpy edge: f32
    B = q.shape[0]
    ids = np.full((B, k), -1, np.int64)
    dists = np.full((B, k), np.inf, np.float32)
    L = np.asarray(L); R = np.asarray(R)
    lo2 = np.asarray(lo2); hi2 = np.asarray(hi2)
    for i in range(B):
        lo, hi = int(L[i]), int(R[i])
        if hi < lo:
            continue
        sel = np.arange(lo, hi + 1)
        sel = sel[(a2[sel] >= lo2[i]) & (a2[sel] <= hi2[i])]
        if sel.size == 0:
            continue
        d = ((vecs[sel] - q[i]) ** 2).sum(1)
        kk = min(k, d.shape[0])
        part = np.argpartition(d, kk - 1)[:kk]
        part = part[np.argsort(d[part], kind="stable")]
        ids[i, :kk] = sel[part]
        dists[i, :kk] = d[part]
    return ids, dists
