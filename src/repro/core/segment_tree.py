"""Implicit segment-tree math for iRangeGraph.

The tree is a perfect binary tree over the padded rank domain ``[0, 2**logn)``.
Objects carry ids equal to their attribute rank (``0..n-1``); ids in
``[n, 2**logn)`` do not exist but keep the closed forms branch-free.

Layer numbering follows the paper: layer ``0`` is the root (one segment of
length ``2**logn``); layer ``logn`` is the leaves (segments of length 1).
Everything here is pure integer math on jnp arrays so it vmaps/jits cleanly —
this is the TPU replacement for the paper's branchy per-node traversal.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "num_layers",
    "seg_bounds",
    "scan_mask",
    "decompose_range",
    "covering_segment",
]


def num_layers(n: int) -> int:
    """Number of layers (= logn + 1) for a dataset of n objects."""
    return int(np.ceil(np.log2(max(int(n), 2)))) + 1


def seg_bounds(u, lay, logn):
    """Inclusive [lo, hi] of the segment containing object ``u`` at ``lay``.

    Works elementwise on arrays (broadcasting u against lay).
    """
    s = logn - lay
    lo = (u >> s) << s
    hi = lo + (1 << s) - 1
    return lo, hi


def scan_mask(u, L, R, logn, *, skip_layers: bool = True):
    """Vectorized layer-scan mask of Algorithm 1 for one object.

    Returns a bool vector of length ``logn + 1``: ``mask[lay]`` is True iff the
    edges of ``u`` stored at layer ``lay`` are scanned when improvising the
    dedicated graph for query range ``[L, R]``.

    ``skip_layers=True`` is the paper's efficient algorithm (a layer is skipped
    when the child segment's intersection with [L, R] equals the current
    segment's). ``skip_layers=False`` is the naive O(m log n) variant
    (``iRangeGraph-`` in the ablation) that scans every layer until the first
    segment fully covered by the query range.

    All of u, L, R are scalars (ints or 0-d arrays); vmap for batches.
    """
    lays = jnp.arange(logn + 1)
    lo, hi = seg_bounds(u, lays, logn)

    inter_lo = jnp.maximum(lo, L)
    inter_hi = jnp.minimum(hi, R)

    terminal = (lo >= L) & (hi <= R)
    # Leaf is always terminal when u is in range, so argmax finds the first
    # fully-covered layer; scanning stops there (Algorithm 1 line 9).
    first_term = jnp.argmax(terminal)
    reachable = lays <= first_term

    if not skip_layers:
        return reachable

    # skip[lay] == intersection(child(lay), [L,R]) == intersection(lay, [L,R])
    # child intersections are the next layer's intersections shifted up.
    child_inter_lo = jnp.roll(inter_lo, -1)
    child_inter_hi = jnp.roll(inter_hi, -1)
    skip = (child_inter_lo == inter_lo) & (child_inter_hi == inter_hi)
    skip = skip.at[logn].set(False)  # leaves have no child
    return reachable & ~skip


def decompose_range(L: int, R: int, logn: int):
    """Classic segment-tree decomposition of [L, R] (inclusive).

    Host-side helper for the BasicSearch ablation baseline: returns a list of
    ``(lay, lo, hi)`` disjoint segments whose union is exactly [L, R]. At most
    ``2 * logn`` segments.
    """
    out = []

    def rec(lay, lo, hi):
        if hi < L or lo > R:
            return
        if L <= lo and hi <= R:
            out.append((lay, lo, hi))
            return
        mid = (lo + hi) // 2
        rec(lay + 1, lo, mid)
        rec(lay + 1, mid + 1, hi)

    rec(0, 0, (1 << logn) - 1)
    return out


def covering_segment(L: int, R: int, logn: int):
    """Smallest single segment covering [L, R] (SuperPostfiltering-style).

    Returns ``(lay, lo, hi)``. This is the deepest tree node whose segment
    contains the whole query range.
    """
    lay, lo, hi = 0, 0, (1 << logn) - 1
    while lay < logn:
        mid = (lo + hi) // 2
        if R <= mid:
            lay, hi = lay + 1, mid
        elif L > mid:
            lay, lo = lay + 1, mid + 1
        else:
            break
    return lay, lo, hi
