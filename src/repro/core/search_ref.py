"""Reference beam-search engine (the pre-fusion implementation).

This is the original dense-state engine kept verbatim as a correctness
oracle for ``core/search.py``: dense ``bool[B, n]`` visited map, exactly one
node expanded per query per iteration, XLA gather + einsum distances. The
fused engine with ``expand_width=1`` must reproduce its results bit-for-bit
(ids and dists); tests/test_hotpath.py enforces that on real indexes.

Do not use in production paths — it exists to pin semantics.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.search import SearchResult

__all__ = ["beam_search_reference"]

_INF = jnp.float32(jnp.inf)


def _pairdist(q, x, metric):
    """Distance between queries q[B, d] and points x[B, M, d] -> [B, M]."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    if metric == "l2":
        xx = jnp.sum(x * x, axis=-1)
        qq = jnp.sum(q * q, axis=-1, keepdims=True)
        xq = jnp.einsum("bd,bmd->bm", q, x)
        return xx - 2.0 * xq + qq
    if metric == "ip":
        return -jnp.einsum("bd,bmd->bm", q, x)
    raise ValueError(f"unknown metric {metric!r}")


def beam_search_reference(
    vectors: jnp.ndarray,          # f32[n, d]
    queries: jnp.ndarray,          # f32[B, d]
    entry_ids: jnp.ndarray,        # int32[B, E] (-1 for unused)
    nbr_fn: Callable,              # int32[B] -> int32[B, M]
    *,
    ef: int,
    k: int,
    max_iters: int | None = None,
    metric: str = "l2",
    result_filter_fn: Callable | None = None,
    visit_prob_fn: Callable | None = None,
    rng: jax.Array | None = None,
) -> SearchResult:
    """Single-expansion dense-visited beam search (seed semantics)."""
    n, d = vectors.shape
    B = queries.shape[0]
    if max_iters is None:
        max_iters = 4 * ef + 32

    two_lists = result_filter_fn is not None

    def _mark(visited, ids, valid):
        b = jnp.arange(B)[:, None]
        return visited.at[b, jnp.maximum(ids, 0)].max(valid)

    def init_state():
        e = entry_ids
        valid = e >= 0
        ex = vectors[jnp.maximum(e, 0)]
        dists = jnp.where(valid, _pairdist(queries, ex, metric), _INF)
        E = e.shape[1]
        pad = ef - E
        cand_ids = jnp.concatenate(
            [jnp.where(valid, e, -1), jnp.full((B, pad), -1, jnp.int32)], axis=1
        )
        cand_dists = jnp.concatenate([dists, jnp.full((B, pad), _INF)], axis=1)
        cand_vis = jnp.zeros((B, ef), bool)
        visited = jnp.zeros((B, n), bool)
        visited = _mark(visited, e, valid)
        if two_lists:
            ok = result_filter_fn(jnp.maximum(e, 0)) & valid
            res_ids = jnp.concatenate(
                [jnp.where(ok, e, -1), jnp.full((B, pad), -1, jnp.int32)], 1
            )
            res_dists = jnp.concatenate(
                [jnp.where(ok, dists, _INF), jnp.full((B, pad), _INF)], 1
            )
        else:
            res_ids = cand_ids
            res_dists = cand_dists
        t = jnp.zeros((B,), jnp.int32)  # consecutive out-of-range counter
        stats = (jnp.zeros((B,), jnp.int32), jnp.sum(valid, 1, dtype=jnp.int32))
        key = rng if rng is not None else jax.random.PRNGKey(0)
        return (
            cand_ids, cand_dists, cand_vis, visited,
            res_ids, res_dists, t, jnp.ones((B,), bool), stats, key,
            jnp.int32(0),
        )

    def cond(state):
        *_, active, _stats, _key, it = state
        return jnp.any(active) & (it < max_iters)

    def body(state):
        (cand_ids, cand_dists, cand_vis, visited,
         res_ids, res_dists, t, active, stats, key, it) = state
        n_hops, n_dists = stats

        unvisited = jnp.where(
            cand_vis | (cand_ids < 0), _INF, cand_dists
        )
        best_slot = jnp.argmin(unvisited, axis=1)
        best_dist = jnp.take_along_axis(unvisited, best_slot[:, None], 1)[:, 0]
        worst = jnp.max(jnp.where(cand_ids >= 0, cand_dists, -_INF), axis=1)
        full = jnp.all(cand_ids >= 0, axis=1)
        progress = jnp.isfinite(best_dist) & (~full | (best_dist <= worst))
        active = active & progress

        u = jnp.take_along_axis(cand_ids, best_slot[:, None], 1)[:, 0]
        u = jnp.where(active, u, -1)
        cand_vis = jnp.where(
            active[:, None]
            & (jnp.arange(ef)[None, :] == best_slot[:, None]),
            True,
            cand_vis,
        )
        n_hops = n_hops + active.astype(jnp.int32)

        nbr = nbr_fn(u)                       # [B, M]
        M = nbr.shape[1]
        nvalid = (nbr >= 0) & active[:, None]
        b = jnp.arange(B)[:, None]
        seen = visited[b, jnp.maximum(nbr, 0)]
        nvalid &= ~seen

        if two_lists:
            in_rng = result_filter_fn(jnp.maximum(nbr, 0))
            if visit_prob_fn is not None:
                key, sub = jax.random.split(key)
                p = visit_prob_fn(jnp.maximum(nbr, 0), t)
                coin = jax.random.uniform(sub, (B, M))
                visit_out = coin < p
            else:
                visit_out = jnp.ones((B, M), bool)  # post-filtering
            nvalid &= in_rng | visit_out
            # consecutive out-of-range counter follows the expanded node u
            u_in = result_filter_fn(jnp.maximum(u, 0)[:, None])[:, 0]
            u_out = ~u_in & (u >= 0)
            t = jnp.where(active, jnp.where(u_out, t + 1, 0), t)

        visited = _mark(visited, nbr, nvalid)
        nx = vectors[jnp.maximum(nbr, 0)]
        ndist = jnp.where(nvalid, _pairdist(queries, nx, metric), _INF)
        n_dists = n_dists + jnp.sum(nvalid, axis=1, dtype=jnp.int32)

        # merge into navigation list
        all_ids = jnp.concatenate([cand_ids, jnp.where(nvalid, nbr, -1)], 1)
        all_dists = jnp.concatenate([cand_dists, ndist], 1)
        all_vis = jnp.concatenate([cand_vis, jnp.zeros((B, M), bool)], 1)
        _, idx = jax.lax.top_k(-all_dists, ef)
        cand_ids = jnp.take_along_axis(all_ids, idx, 1)
        cand_dists = jnp.take_along_axis(all_dists, idx, 1)
        cand_vis = jnp.take_along_axis(all_vis, idx, 1)

        if two_lists:
            rvalid = nvalid & in_rng
            r_ids = jnp.concatenate([res_ids, jnp.where(rvalid, nbr, -1)], 1)
            r_dists = jnp.concatenate(
                [res_dists, jnp.where(rvalid, ndist, _INF)], 1
            )
            _, ridx = jax.lax.top_k(-r_dists, ef)
            res_ids = jnp.take_along_axis(r_ids, ridx, 1)
            res_dists = jnp.take_along_axis(r_dists, ridx, 1)
        else:
            res_ids, res_dists = cand_ids, cand_dists

        return (cand_ids, cand_dists, cand_vis, visited,
                res_ids, res_dists, t, active, (n_hops, n_dists), key,
                it + 1)

    state = init_state()
    state = jax.lax.while_loop(cond, body, state)
    (_, _, _, _, res_ids, res_dists, _, _, stats, _, _) = state
    _, idx = jax.lax.top_k(-res_dists, k)
    out_ids = jnp.take_along_axis(res_ids, idx, 1)
    out_dists = jnp.take_along_axis(res_dists, idx, 1)
    out_ids = jnp.where(jnp.isfinite(out_dists), out_ids, -1)
    return SearchResult(out_ids, out_dists, stats[0], stats[1])
