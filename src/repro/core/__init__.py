"""iRangeGraph core: the paper's contribution as a composable JAX module."""
from repro.core.build import BuildConfig, build_flat_graph, build_neighbor_table
from repro.core.config import SearchConfig, ServeConfig
from repro.core.index import IndexCorruptionError, RangeGraphIndex, recall
from repro.core.search import SearchResult, search_improvised
from repro.core.storage import StorageConfig

__all__ = [
    "BuildConfig",
    "IndexCorruptionError",
    "RangeGraphIndex",
    "SearchConfig",
    "SearchResult",
    "ServeConfig",
    "StorageConfig",
    "build_flat_graph",
    "build_neighbor_table",
    "recall",
    "search_improvised",
]
