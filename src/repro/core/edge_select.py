"""On-the-fly edge selection (paper Algorithm 1), vectorized for TPU.

Given the packed elemental-graph table ``nbrs[n, layers, m]`` (int32, ``-1``
padding), select for one object ``u`` up to ``m`` out-edges of the improvised
dedicated graph for query range ``[L, R]``:

  * layers are scanned top-down; upper layers (larger intersection with the
    query range) have priority — their edges are more robust against pruning
    by in-range objects;
  * a layer is skipped when the child segment's intersection with [L, R]
    equals the current one (``skip_layers=True``);
  * scanning terminates at the first segment fully covered by [L, R];
  * only in-range neighbors are kept, duplicates keep their highest-priority
    occurrence (the paper's set union).

The CPU algorithm is a branchy O(m + log n) walk; here it becomes a gather of
all candidate edges, a closed-form scan mask (``segment_tree.scan_mask``), a
single duplicate-suppressing stable sort, and one top-m — branch-free and
vmappable over the whole beam/batch.

This module is the *historical argsort formulation*, kept as (a) the
regression baseline for ``benchmarks/hotpath.py`` and (b) — together with
``select_edges_reference``, the literal Algorithm 1 transcription — the
correctness oracle for the production sort-free paths. The hot path now
dispatches through ``kernels/ops.py::select_edges`` (Pallas kernel on TPU,
sort-free jnp elsewhere); all formulations return bit-identical ids. See
DESIGN.md §2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import segment_tree

__all__ = ["select_edges", "select_edges_batch", "select_edges_reference"]

# plain int so importing this module inside a jit trace can never capture a
# tracer in module state; jnp ops promote it to int32
_BIG = 2**30


@functools.partial(jax.jit, static_argnames=("logn", "m_out", "skip_layers"))
def select_edges(nbrs_u, u, L, R, *, logn, m_out, skip_layers=True):
    """Select edges for one object.

    Args:
      nbrs_u: int32[layers, m] — the packed neighbor rows of ``u``.
      u, L, R: scalars (ranks, inclusive range).
      logn, m_out: static ints.
      skip_layers: paper's efficient variant (True) vs naive (False).

    Returns:
      int32[m_out] neighbor ids, -1 padded.
    """
    layers, m = nbrs_u.shape
    mask = segment_tree.scan_mask(u, L, R, logn, skip_layers=skip_layers)

    # compact (int16) rows widen here: -1 is the sentinel in every storage
    # dtype, and _BIG below must not wrap in a narrow dtype
    flat = nbrs_u.reshape(-1).astype(jnp.int32)
    lay_of = jnp.repeat(jnp.arange(layers, dtype=jnp.int32), m)
    valid = (
        (flat >= 0)
        & (flat >= L)
        & (flat <= R)
        & mask[lay_of]
        & (flat != u)
    )
    # Priority: earlier (upper) layer first, then slot order within the layer.
    prio = jnp.where(valid, jnp.arange(flat.shape[0], dtype=jnp.int32), _BIG)

    # Deduplicate, keeping the best priority per neighbor id, with ONE stable
    # argsort: priority equals the flat position, so the array is already in
    # priority order — a stable sort on (id, invalids->BIG) therefore orders
    # equal ids by priority for free. Invalidate entries equal to their
    # predecessor's key (all-BIG invalid runs self-suppress harmlessly).
    key = jnp.where(valid, flat, _BIG)
    order_i = jnp.argsort(key, stable=True)
    key_i, prio_i = key[order_i], prio[order_i]
    ids_i = flat[order_i]
    dup = jnp.concatenate([jnp.array([False]), key_i[1:] == key_i[:-1]])
    prio_i = jnp.where(dup, _BIG, prio_i)

    # Top-m_out by priority.
    neg = -prio_i
    _, take = jax.lax.top_k(neg, m_out)
    out = ids_i[take]
    return jnp.where(prio_i[take] == _BIG, jnp.int32(-1), out)


@functools.partial(jax.jit, static_argnames=("logn", "m_out", "skip_layers"))
def select_edges_batch(nbrs, us, L, R, *, logn, m_out, skip_layers=True):
    """vmap of ``select_edges`` over a batch of objects.

    Args:
      nbrs: int32[n, layers, m] full table.
      us: int32[B] object ids (may contain -1 for inactive slots).
      L, R: scalars or int32[B].
    Returns: int32[B, m_out].
    """
    us_safe = jnp.maximum(us, 0)
    rows = nbrs[us_safe]
    L = jnp.broadcast_to(L, us.shape)
    R = jnp.broadcast_to(R, us.shape)
    fn = functools.partial(
        select_edges, logn=logn, m_out=m_out, skip_layers=skip_layers
    )
    out = jax.vmap(fn)(rows, us_safe, L, R)
    return jnp.where(us[:, None] < 0, jnp.int32(-1), out)


def select_edges_reference(nbrs_u, u, L, R, *, logn, m_out, skip_layers=True):
    """Pure-Python Algorithm 1, literal transcription — test oracle.

    ``nbrs_u`` is an int array [layers, m]; returns a python list (<= m_out).
    """
    lo, hi = 0, (1 << logn) - 1
    lay = 0
    S: list[int] = []
    seen = set()
    while len(S) < m_out:
        if lay < logn:
            mid = (lo + hi) // 2
            if u <= mid:
                lc, rc = lo, mid
            else:
                lc, rc = mid + 1, hi
            same = (
                max(lc, L) == max(lo, L) and min(rc, R) == min(hi, R)
            )
            if skip_layers and same and not (lo >= L and hi <= R):
                lo, hi, lay = lc, rc, lay + 1
                continue
        for v in nbrs_u[lay]:
            v = int(v)
            if v >= 0 and L <= v <= R and v != u and v not in seen:
                seen.add(v)
                S.append(v)
        S = S[:m_out]
        if lo >= L and hi <= R:
            break
        if lay >= logn:
            break
        mid = (lo + hi) // 2
        if u <= mid:
            lo, hi = lo, mid
        else:
            lo, hi = mid + 1, hi
        lay += 1
    return S
