"""Public iRangeGraph index API.

``RangeGraphIndex.build(vectors, attrs)`` sorts objects by attribute value
(stable, so duplicates keep insertion order — paper §3.4's duplicate
discussion), builds the packed elemental-graph table, and exposes:

  * ``search(queries, ranges, ...)`` — RFANN in attribute-VALUE space;
  * ``search_ranks(queries, L, R, ...)`` — RFANN in rank space;
  * value<->rank mapping via binary search (paper §2.2);
  * serialization (msgpack + zstd, content-checksummed);
  * compact storage (``core/storage.py``): vectors in bf16/f16 and neighbor
    ids in int16 when they fit, decoded at the consumption edges —
    ``nbytes`` reports the real footprint either way.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import math
import warnings
import zlib

import jax.numpy as jnp
import msgpack
import numpy as np

from repro import compressio

from repro.core import build as build_mod
from repro.core import config as config_mod
from repro.core import search as search_mod
from repro.core import storage as storage_mod

__all__ = ["IndexCorruptionError", "RangeGraphIndex"]


class IndexCorruptionError(IOError):
    """A saved index failed an integrity check on load.

    ``field`` names the offending array (``"vectors"``, ``"neighbors"``,
    ...) or ``"envelope"`` for whole-file damage, so operators see *what*
    rotted instead of an opaque unpack/reshape error. Subclasses
    ``IOError`` so historical ``except IOError`` call sites keep working.
    """

    def __init__(self, field: str, message: str):
        super().__init__(f"corrupt index [{field}]: {message}")
        self.field = field


def _pack_array(a: np.ndarray) -> dict:
    data = a.tobytes()
    # per-array checksum: the envelope sha256 says "this file rotted",
    # crc32 here says *which field* — and survives partial/streamed writes
    return {"dtype": str(a.dtype), "shape": list(a.shape), "data": data,
            "crc32": zlib.crc32(data)}


def _unpack_array(d: dict, field: str) -> np.ndarray:
    data = d["data"]
    dtype = storage_mod.np_dtype(d["dtype"])
    want = int(np.prod(d["shape"], dtype=np.int64)) * dtype.itemsize
    if len(data) != want:
        raise IndexCorruptionError(
            field, f"truncated: {len(data)} bytes, expected {want} "
            f"for shape {d['shape']} {d['dtype']}"
        )
    crc = d.get("crc32")
    if crc is None:
        warnings.warn(
            f"index file predates per-array checksums ({field} unchecked); "
            "re-save to add them", stacklevel=3,
        )
    elif zlib.crc32(data) != crc:
        raise IndexCorruptionError(field, "checksum mismatch (bit flip?)")
    # frombuffer views the msgpack bytes read-only; copy so a loaded index
    # is equivalent to a built one (in-place consumers must not raise)
    a = np.frombuffer(data, dtype=dtype)
    return a.reshape(d["shape"]).copy()


@dataclasses.dataclass
class RangeGraphIndex:
    vectors: np.ndarray        # [n, d] table or codec struct, rank order
    attrs: np.ndarray          # f64[n], sorted attribute values
    perm: np.ndarray           # original index of rank i
    neighbors: np.ndarray      # [n, layers, m] or SplitNeighbors struct
    m: int
    logn: int
    build_cfg: build_mod.BuildConfig
    storage: storage_mod.StorageConfig = dataclasses.field(
        default_factory=storage_mod.StorageConfig
    )
    # higher-fidelity rerank sidecar (storage.rerank_dtype): None, an
    # [n, d] array, or Int8Vectors — feeds SearchConfig.rerank refinement
    rerank: object = None

    # -- construction -------------------------------------------------------
    @classmethod
    def build(
        cls,
        vectors: np.ndarray,
        attrs: np.ndarray,
        cfg: build_mod.BuildConfig | None = None,
        *,
        verbose: bool = False,
        prune_impl: str | None = None,
        storage: storage_mod.StorageConfig | None = None,
    ) -> "RangeGraphIndex":
        """``prune_impl`` overrides ``cfg.prune_impl`` (the construction-prune
        backend: "auto" | "pallas" | "xla" | "legacy", see kernels/ops).
        ``storage`` picks the stored dtypes (default ``REPRO_STORAGE`` env or
        f32); construction math always runs in f32."""
        cfg = cfg or build_mod.BuildConfig()
        if prune_impl is not None:
            cfg = dataclasses.replace(cfg, prune_impl=prune_impl)
        storage = storage or storage_mod.default_config()
        vectors = np.asarray(vectors, np.float32)
        attrs = np.asarray(attrs, np.float64)
        n = vectors.shape[0]
        perm = np.argsort(attrs, kind="stable").astype(np.int64)
        vectors = np.ascontiguousarray(vectors[perm])
        attrs = attrs[perm]
        nbrs = build_mod.build_neighbor_table(
            vectors, cfg, verbose=verbose, storage=storage
        )
        logn = int(math.ceil(math.log2(max(n, 2))))
        rerank = storage_mod.encode_rerank(vectors, storage)
        vectors = storage_mod.encode_vectors(vectors, storage)
        return cls(vectors, attrs, perm, nbrs, cfg.m, logn, cfg,
                   storage=storage, rerank=rerank)

    def astype_storage(
        self, storage: storage_mod.StorageConfig
    ) -> "RangeGraphIndex":
        """Re-encode the stored arrays under ``storage`` — no rebuild.

        The graph is unchanged, so neighbor ids are bit-identical across
        codecs and only vector precision changes (bf16/f16/int8/pq round
        once; going back to f32 does not restore already-rounded values).
        Re-encoding starts from the highest-fidelity source available: the
        rerank sidecar when present, else the stored vectors."""
        src = (storage_mod.decode_vectors(self.rerank)
               if self.rerank is not None
               else storage_mod.decode_vectors(self.vectors))
        return dataclasses.replace(
            self,
            vectors=storage_mod.encode_vectors(src, storage),
            neighbors=storage_mod.encode_neighbors(
                storage_mod.decode_neighbors(self.neighbors), self.n, storage
            ),
            rerank=storage_mod.encode_rerank(src, storage),
            storage=storage,
        )

    @property
    def n(self) -> int:
        return storage_mod.table_n(self.vectors)

    @property
    def dim(self) -> int:
        return storage_mod.table_dim(self.vectors)

    @property
    def nbytes(self) -> int:
        """Real stored footprint — sums codec-struct leaves (the two
        hot-path tables dominate; ``attrs`` stays f64 for rank fidelity)."""
        return (storage_mod.table_nbytes(self.vectors)
                + storage_mod.table_nbytes(self.neighbors)
                + storage_mod.table_nbytes(self.rerank)
                + self.attrs.nbytes)

    # -- range mapping -------------------------------------------------------
    def ranks_of(self, lo_val, hi_val):
        """Map inclusive attribute-value ranges to inclusive rank ranges."""
        L = np.searchsorted(self.attrs, np.asarray(lo_val), side="left")
        R = np.searchsorted(self.attrs, np.asarray(hi_val), side="right") - 1
        return L.astype(np.int32), R.astype(np.int32)

    # -- query ---------------------------------------------------------------
    def search_ranks(
        self, queries, L, R, *, k=10, config=None, ef=None, skip_layers=None,
        metric=None, expand_width=None, dist_impl=None, edge_impl=None,
    ) -> search_mod.SearchResult:
        """RFANN in rank space: per-query inclusive rank ranges [L, R].

        config: one frozen ``SearchConfig`` holding every engine knob
        (``k`` stays per-call); the loose kwargs are the deprecation shim —
        non-None values override the config. For repeated serving traffic
        prefer ``serve/executor.py::SearchExecutor`` (compile cache +
        batch/k buckets + AOT warmup) over calling this in a loop.
        """
        config = config_mod.merge(
            config, ef=ef, skip_layers=skip_layers, metric=metric,
            expand_width=expand_width, dist_impl=dist_impl,
            edge_impl=edge_impl, _warn_where="RangeGraphIndex.search_ranks",
        )
        return search_mod.search_improvised(
            storage_mod.as_device(self.vectors),
            storage_mod.as_device(self.neighbors),
            jnp.asarray(queries, jnp.float32),
            jnp.asarray(L, jnp.int32),
            jnp.asarray(R, jnp.int32),
            logn=self.logn,
            m_out=self.m,
            k=k,
            config=config,
            rerank_store=storage_mod.as_device(self.rerank),
        )

    def search(self, queries, lo_val, hi_val, **kw) -> search_mod.SearchResult:
        L, R = self.ranks_of(lo_val, hi_val)
        return self.search_ranks(queries, L, R, **kw)

    def original_ids(self, rank_ids):
        """Map rank-space result ids back to the caller's original ids."""
        rank_ids = np.asarray(rank_ids)
        out = np.where(rank_ids >= 0, self.perm[np.maximum(rank_ids, 0)], -1)
        return out

    # -- ground truth ---------------------------------------------------------
    def brute_force(self, queries, L, R, *, k=10, metric="l2"):
        """Exact in-range top-k (== the Pre-filtering strategy). numpy."""
        q = np.asarray(queries, np.float32)
        L = np.asarray(L)
        R = np.asarray(R)
        vecs = storage_mod.decode_vectors(self.vectors)  # numpy edge: f32
        ids = np.full((q.shape[0], k), -1, np.int64)
        dists = np.full((q.shape[0], k), np.inf, np.float32)
        for i in range(q.shape[0]):
            lo, hi = int(L[i]), int(R[i])
            if hi < lo:
                continue
            x = vecs[lo : hi + 1]
            if metric == "l2":
                d = ((x - q[i]) ** 2).sum(1)
            else:
                d = -(x @ q[i])
            kk = min(k, d.shape[0])
            part = np.argpartition(d, kk - 1)[:kk]
            part = part[np.argsort(d[part], kind="stable")]
            ids[i, :kk] = part + lo
            dists[i, :kk] = d[part]
        return ids, dists

    # -- serialization ---------------------------------------------------------
    def save(self, path: str):
        """Codec structs flatten to one crc32-checked field per leaf
        (``vectors``/``vec_scales``/``vec_codebook``, ``neighbors``/
        ``neighbors_lo``, ``rerank``/``rerank_scales``) so a bit flip in a
        scale or codebook array is named on load, not just "vectors"."""
        payload = {
            "attrs": _pack_array(self.attrs),
            "perm": _pack_array(self.perm),
            "m": self.m,
            "logn": self.logn,
            "cfg": dataclasses.asdict(self.build_cfg),
            "storage": dataclasses.asdict(self.storage),
        }
        if isinstance(self.vectors, storage_mod.Int8Vectors):
            payload["vectors"] = _pack_array(self.vectors.codes)
            payload["vec_scales"] = _pack_array(self.vectors.scales)
        elif isinstance(self.vectors, storage_mod.PQVectors):
            payload["vectors"] = _pack_array(self.vectors.codes)
            payload["vec_codebook"] = _pack_array(self.vectors.codebook)
        else:
            payload["vectors"] = _pack_array(self.vectors)
        if isinstance(self.neighbors, storage_mod.SplitNeighbors):
            payload["neighbors"] = _pack_array(self.neighbors.hi)
            payload["neighbors_lo"] = _pack_array(self.neighbors.lo)
        else:
            payload["neighbors"] = _pack_array(self.neighbors)
        if isinstance(self.rerank, storage_mod.Int8Vectors):
            payload["rerank"] = _pack_array(self.rerank.codes)
            payload["rerank_scales"] = _pack_array(self.rerank.scales)
        elif self.rerank is not None:
            payload["rerank"] = _pack_array(self.rerank)
        raw = msgpack.packb(payload)
        digest = hashlib.sha256(raw).hexdigest()
        blob = msgpack.packb({"sha256": digest, "payload": raw})
        with open(path, "wb") as f:
            f.write(compressio.compress(blob, level=3))

    @classmethod
    def load(cls, path: str) -> "RangeGraphIndex":
        """Load with integrity checking: whole-file (envelope sha256) and
        per-array (crc32 + size) — any mismatch raises
        :class:`IndexCorruptionError` naming the offending field.
        Pre-checksum files (no per-array crc32) still load, with a
        warning."""
        with open(path, "rb") as f:
            blob = f.read()
        try:
            blob = compressio.decompress(blob)
            outer = msgpack.unpackb(blob)
            raw = outer["payload"]
            digest = outer["sha256"]
        except IndexCorruptionError:
            raise
        except Exception as e:  # zlib/zstd/msgpack: the file is not ours
            raise IndexCorruptionError(
                "envelope", f"unreadable file {path}: {e}"
            ) from e
        if hashlib.sha256(raw).hexdigest() != digest:
            raise IndexCorruptionError(
                "envelope", f"payload checksum mismatch loading {path}"
            )
        try:
            p = msgpack.unpackb(raw)
        except Exception as e:
            raise IndexCorruptionError(
                "envelope", f"payload unpack failed loading {path}: {e}"
            ) from e
        vectors = _unpack_array(p["vectors"], "vectors")
        if "vec_scales" in p:
            vectors = storage_mod.Int8Vectors(
                vectors, _unpack_array(p["vec_scales"], "vec_scales")
            )
        elif "vec_codebook" in p:
            vectors = storage_mod.PQVectors(
                vectors, _unpack_array(p["vec_codebook"], "vec_codebook")
            )
        neighbors = _unpack_array(p["neighbors"], "neighbors")
        if "neighbors_lo" in p:
            neighbors = storage_mod.SplitNeighbors(
                neighbors, _unpack_array(p["neighbors_lo"], "neighbors_lo")
            )
        rerank = None
        if "rerank" in p:
            rerank = _unpack_array(p["rerank"], "rerank")
            if "rerank_scales" in p:
                rerank = storage_mod.Int8Vectors(
                    rerank, _unpack_array(p["rerank_scales"], "rerank_scales")
                )
        st = p.get("storage")
        if st is None:  # pre-storage files: the stored dtypes ARE the config
            st = {"vector_dtype": str(vectors.dtype),
                  "neighbor_dtype": str(neighbors.dtype)}
        return cls(
            vectors=vectors,
            attrs=_unpack_array(p["attrs"], "attrs"),
            perm=_unpack_array(p["perm"], "perm"),
            neighbors=neighbors,
            m=p["m"],
            logn=p["logn"],
            build_cfg=build_mod.BuildConfig(**p["cfg"]),
            storage=storage_mod.StorageConfig(**st),
            rerank=rerank,
        )


def recall(result_ids, gt_ids) -> float:
    """Mean recall@k of result ids vs ground-truth ids (both [B, k])."""
    result_ids = np.asarray(result_ids)
    gt_ids = np.asarray(gt_ids)
    hits = 0
    total = 0
    for r, g in zip(result_ids, gt_ids):
        gset = set(int(x) for x in g if x >= 0)
        if not gset:
            continue
        hits += len(gset & set(int(x) for x in r if x >= 0))
        total += len(gset)
    return hits / max(total, 1)
