"""Packed per-query visited sets for beam search.

The lockstep beam-search loop needs "have I visited id v" per query. A dense
``bool[B, n]`` map costs n bytes of HBM traffic per query per hop and stops
fitting at production scale (n=10M, B=64 -> 640 MB of state). Packing into
``uint32[B, ceil(n/32)]`` is 8x less traffic and 32x smaller than an f32 row
of the same length; membership becomes shift/mask arithmetic that the VPU
eats for free.

``test_and_set`` is the workhorse: one call both reads the old bits and sets
the new ones, and additionally suppresses duplicate ids *within* a row (the
same neighbor surfacing from two expanded nodes in the same hop), so callers
get exactly-once semantics per id. The scatter uses ``.at[].add``: after
dedup every updated (row, word, bit) triple is unique, so addition of
distinct single-bit masks is exactly bitwise OR.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["make", "lookup", "test_and_set", "num_words"]


def num_words(n: int) -> int:
    """Words per query for a dataset of n ids."""
    return (int(n) + 31) // 32


def make(B: int, n: int) -> jnp.ndarray:
    """Empty bitset: uint32[B, ceil(n/32)]."""
    return jnp.zeros((B, num_words(n)), jnp.uint32)


def lookup(bits: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """bits[B, W], ids int32[B, K] (-1 allowed) -> bool[B, K] membership."""
    safe = jnp.maximum(ids, 0)
    word = jnp.take_along_axis(bits, safe >> 5, axis=1)
    bit = (word >> (safe & 31).astype(jnp.uint32)) & jnp.uint32(1)
    return (bit == 1) & (ids >= 0)


def test_and_set(bits, ids, valid):
    """Set bit ids[b, j] for every valid slot; report what was already set.

    Args:
      bits: uint32[B, W] packed visited state.
      ids: int32[B, K], -1 allowed (treated as invalid).
      valid: bool[B, K] slots to consider.

    Returns ``(bits', seen)``: ``seen[b, j]`` is True when the id was already
    present *or* appeared earlier (lower j) in the same row, so
    ``valid & ~seen`` is the exactly-once "newly visited" mask.
    """
    valid = valid & (ids >= 0)
    safe = jnp.maximum(ids, 0)
    seen = lookup(bits, jnp.where(valid, ids, -1))

    # first occurrence wins within a row: dup[b, j] <=> exists i<j, id_i==id_j
    K = ids.shape[1]
    eq = (safe[:, :, None] == safe[:, None, :]) \
        & valid[:, :, None] & valid[:, None, :]
    earlier = jnp.tril(jnp.ones((K, K), bool), -1)  # [j, i], i < j
    dup = jnp.any(eq & earlier[None], axis=2)

    new = valid & ~seen & ~dup
    mask = jnp.where(
        new, jnp.uint32(1) << (safe & 31).astype(jnp.uint32), jnp.uint32(0)
    )
    rows = jnp.arange(bits.shape[0])[:, None]
    bits = bits.at[rows, safe >> 5].add(mask)
    return bits, seen | dup
