"""Bulk-synchronous bottom-up construction of the iRangeGraph index.

Paper §3.2.2, adapted for accelerators (DESIGN.md §2): instead of inserting
nodes one at a time, every segment-tree level is built in one batched pass.
For the segment ``[l, r]`` with children ``[l, mid]`` / ``[mid+1, r]`` and a
node ``u`` in the left child:

  * candidates inside the *own* child are copied from the child graph (an
    edge pruned in the subset is pruned in the superset — paper's first case);
  * candidates from the *sibling* child come from a beam search over the
    sibling's already-built elemental graph — this is one
    ``search_fixed_layer`` call for *all* n nodes of the level at once, each
    query carrying its own sibling-segment bounds;
  * the merged candidate set is RNG-pruned (``kernels/ops.py::prune`` — the
    fused lazy-column formulation / Pallas kernel, dispatched by
    ``cfg.prune_impl``, with ``core/rng.py`` kept as the eager oracle).

Levels whose segments are small (``<= brute_threshold``) skip the search and
take the whole segment as candidates (exact RNG up to the degree cap).

A reverse-edge pass (optional, on by default) mirrors HNSW's bidirectional
insertion: each directed edge contributes its reverse as a candidate and the
target re-prunes. This measurably improves connectivity of elemental graphs.
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import knobs as knobs_mod
from repro.core import search as search_mod
from repro.core import storage as storage_mod
from repro.core.config import SearchConfig
from repro.kernels import ops

__all__ = [
    "BuildConfig", "auto_chunk", "resolve_chunk", "build_neighbor_table",
    "build_flat_graph",
]


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    m: int = 16                    # max out-degree per elemental graph
    ef_construction: int = 64      # beam/candidates for sibling search (EF)
    alpha: float = 1.0             # RNG alpha (1.0 == paper's rule)
    brute_threshold: int = 128     # segments this small use exact candidates
    add_reverse: bool = True       # bidirectional pass per level
    fill_pruned: bool = True       # keepPrunedConnections
    chunk: int | None = None       # nodes per batched prune call; None = auto
    prune_impl: str = "auto"       # "auto" | "pallas" | "xla" | "legacy"


# The gathered candidate block a chunked prune re-reads once per keep sweep
# is [chunk, C, d] f32; past cache residency the lazy-column win decays
# (2.3x -> 1.8x on the dev host, BENCH_build.json chunk sweep), so the
# auto-tuner sizes the chunk against this budget. The REPRO_CHUNK_BUDGET_MB
# knob (default 16, core/knobs.py registry) overrides for hosts with
# different cache hierarchies.
_CHUNK_MIN, _CHUNK_MAX = 256, 8192
# Search levels interleave the prune with a batched sibling beam search
# (one search_fixed_layer call per chunk) whose cost amortizes with batch
# size, so their chunk never auto-tunes below this floor — the residency
# budget only governs the prune-only passes (brute levels, reverse pass).
_SEARCH_CHUNK_FLOOR = 2048


def auto_chunk(C: int, d: int, *, budget_bytes: int | None = None) -> int:
    """Per-level build chunk: the largest power of two keeping the gathered
    ``[chunk, C, d]`` f32 candidate block inside the cache budget, clamped
    to [256, 8192]. ``BuildConfig.chunk`` overrides (see resolve_chunk)."""
    if budget_bytes is None:
        budget_bytes = knobs_mod.get_int("REPRO_CHUNK_BUDGET_MB") << 20
    per_row = max(int(C) * int(d) * 4, 1)
    target = max(budget_bytes // per_row, 1)
    p = 1
    while p * 2 <= target:
        p <<= 1
    return max(_CHUNK_MIN, min(_CHUNK_MAX, p))


def resolve_chunk(cfg: BuildConfig, C: int, d: int, *,
                  floor: int | None = None) -> int:
    """The chunk a level actually uses: the explicit ``cfg.chunk`` when set,
    else :func:`auto_chunk` keyed on that level's candidate width ``C``
    (raised to ``floor`` for passes whose cost amortizes with batch size,
    e.g. the search levels' sibling beam search)."""
    if cfg.chunk is not None:
        return int(cfg.chunk)
    chunk = auto_chunk(C, d)
    return max(chunk, floor) if floor else chunk


def _level_sizes(n: int) -> tuple[int, int]:
    logn = int(math.ceil(math.log2(max(n, 2))))
    return logn, logn + 1


def _reverse_pass(
    nbrs_lay: np.ndarray, vectors, vec_j, seg_of, cfg: BuildConfig,
    chunk: int | None = None,
):
    """Add reverse edges then re-prune each node's list. numpy + fused prune.

    nbrs_lay: int32[n, m] this level's edges. vec_j: the jnp vector table
    (``ops.prune`` gathers candidate vectors from it). seg_of: int32[n]
    segment id of each node at this level (reverse edges only ever connect
    nodes of the same segment, but we keep the check for safety).
    ``chunk``: nodes per prune call; defaults to the auto-tuned chunk for
    this pass's candidate width C = 3m.
    """
    n, m = nbrs_lay.shape
    if chunk is None:
        chunk = resolve_chunk(cfg, 3 * m, np.asarray(vectors).shape[1])
    # collect reverse candidates: for edge (u, v) add u to v's pool (capped)
    us = np.repeat(np.arange(n, dtype=np.int32), m)
    vs = nbrs_lay.reshape(-1)
    ok = (vs >= 0) & (seg_of[us] == seg_of[np.maximum(vs, 0)])
    us, vs = us[ok], vs[ok]
    if us.size == 0:
        return nbrs_lay
    order = np.argsort(vs, kind="stable")
    vs, us = vs[order], us[order]
    counts = np.bincount(vs, minlength=n)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]]).astype(np.int64)
    pos = np.arange(vs.size, dtype=np.int64) - starts[vs]
    rev_cap = 2 * m
    keep = pos < rev_cap
    C = m + rev_cap
    cand = np.full((n, C), -1, np.int32)
    cand[:, :m] = nbrs_lay
    cand[vs[keep], m + pos[keep]] = us[keep]
    out = np.empty((n, m), np.int32)
    vecs = np.asarray(vectors)
    for s in range(0, n, chunk):
        e = min(n, s + chunk)
        ids = jnp.asarray(cand[s:e])
        cvec = jnp.asarray(vecs[np.maximum(cand[s:e], 0)])
        u_vec = jnp.asarray(vecs[s:e])
        d = jnp.sum((cvec - u_vec[:, None, :]) ** 2, axis=-1)
        d = jnp.where(ids >= 0, d, jnp.inf)
        out[s:e] = np.asarray(
            ops.prune(
                ids, d, vec_j, m=m, alpha=cfg.alpha, fill=cfg.fill_pruned,
                impl=cfg.prune_impl, cand_vecs=cvec,
            )
        )
    return out


def build_neighbor_table(
    vectors: np.ndarray, cfg: BuildConfig | None = None, *, verbose=False,
    level_times: list | None = None,
    storage: storage_mod.StorageConfig | None = None,
) -> np.ndarray:
    """Build the packed elemental-graph table ``[n, layers, m]``.

    ``vectors`` must already be in attribute-rank order (see index.py).
    ``level_times``, if given a list, collects per-level wall-clock dicts
    (layer, segment size, kind, chunk sizes, seconds) — the
    build-throughput record ``benchmarks/buildpath.py`` emits. With
    ``cfg.chunk=None`` each level's prune chunk is auto-tuned per its
    candidate width (see :func:`auto_chunk`); chunking never changes the
    built table (chunk-invariance is tested), only throughput.

    Construction scratch is int32; with ``storage`` the finished table is
    emitted directly in the compact neighbor codec (int16 when ids fit,
    ``-1`` sentinel unchanged — see ``core/storage.py``), otherwise int32.
    """
    cfg = cfg or BuildConfig()
    vectors = np.asarray(vectors, np.float32)
    n, d = vectors.shape
    logn, layers = _level_sizes(n)
    m = cfg.m
    nbrs = np.full((n, layers, m), -1, np.int32)
    vec_j = jnp.asarray(vectors)

    ids_all = np.arange(n, dtype=np.int32)
    for lay in range(logn - 1, -1, -1):  # leaves (logn) have no edges
        size = 1 << (logn - lay)
        seg_of = ids_all >> (logn - lay)
        t0 = time.perf_counter()
        if size <= cfg.brute_threshold:
            chunk = resolve_chunk(cfg, size, d)
            edges = _build_brute_level(vec_j, n, lay, logn, size, cfg, chunk)
        else:
            chunk = resolve_chunk(cfg, m + cfg.ef_construction, d,
                                  floor=_SEARCH_CHUNK_FLOOR)
            edges = _build_search_level(
                vec_j, nbrs, n, lay, logn, size, cfg, chunk
            )
        rev_chunk = None
        if cfg.add_reverse:
            rev_chunk = resolve_chunk(cfg, 3 * m, d)
            edges = _reverse_pass(edges, vectors, vec_j, seg_of, cfg,
                                  rev_chunk)
        nbrs[:, lay, :] = edges
        if level_times is not None:
            level_times.append({
                "layer": int(lay), "seg_size": int(size),
                "kind": "brute" if size <= cfg.brute_threshold else "search",
                "chunk": int(chunk),
                "chunk_reverse": rev_chunk if rev_chunk is None
                else int(rev_chunk),
                "seconds": time.perf_counter() - t0,
            })
        if verbose:
            deg = float((edges >= 0).sum(1).mean())
            print(f"  layer {lay:2d} seg_size {size:7d} mean_deg {deg:.1f}")
    if storage is not None:
        return storage_mod.encode_neighbors(nbrs, n, storage)
    return nbrs


def _build_brute_level(vec_j, n, lay, logn, size, cfg: BuildConfig, chunk):
    """Exact candidates = whole segment. One batched prune per chunk."""
    m = cfg.m
    out = np.empty((n, m), np.int32)
    step = max(1, chunk // max(size, 1)) * size  # chunk on segment bounds
    for s in range(0, n, step):
        e = min(n, s + step)
        u = jnp.arange(s, e, dtype=jnp.int32)
        lo = (u >> (logn - lay)) << (logn - lay)
        cand = lo[:, None] + jnp.arange(size, dtype=jnp.int32)[None, :]
        valid = (cand < n) & (cand != u[:, None])
        cand = jnp.where(valid, cand, -1)
        cvec = vec_j[jnp.maximum(cand, 0)]
        uvec = vec_j[u]
        dist = jnp.sum((cvec - uvec[:, None, :]) ** 2, -1)
        dist = jnp.where(valid, dist, jnp.inf)
        out[s:e] = np.asarray(
            ops.prune(
                cand, dist, vec_j, m=m, alpha=cfg.alpha,
                fill=cfg.fill_pruned, impl=cfg.prune_impl, cand_vecs=cvec,
            )
        )
    return out


def _build_search_level(vec_j, nbrs, n, lay, logn, size, cfg: BuildConfig,
                        chunk):
    """Own-child copy + sibling beam search, then prune. Paper §3.2.2."""
    m, efc = cfg.m, cfg.ef_construction
    child_lay = lay + 1
    nbrs_j = jnp.asarray(nbrs)  # children of this level are already built
    out = np.empty((n, m), np.int32)
    half = size // 2
    search_cfg = SearchConfig(ef=efc)
    for s in range(0, n, chunk):
        e = min(n, s + chunk)
        u = jnp.arange(s, e, dtype=jnp.int32)
        lo = (u >> (logn - lay)) << (logn - lay)
        mid = lo + half - 1
        in_left = u <= mid
        sib_lo = jnp.where(in_left, mid + 1, lo)
        sib_hi = jnp.where(in_left, lo + size - 1, mid)
        res = search_mod.search_fixed_layer(
            vec_j, nbrs_j, vec_j[u], sib_lo, sib_hi,
            layer=child_lay, k=efc, config=search_cfg,
        )
        own = nbrs_j[u, child_lay, :]                   # int32[B, m]
        cand = jnp.concatenate([own, res.ids], axis=1)  # [B, m + efc]
        valid = (cand >= 0) & (cand != u[:, None]) & (cand < n)
        cand = jnp.where(valid, cand, -1)
        cvec = vec_j[jnp.maximum(cand, 0)]
        dist = jnp.sum((cvec - vec_j[u][:, None, :]) ** 2, -1)
        dist = jnp.where(valid, dist, jnp.inf)
        out[s:e] = np.asarray(
            ops.prune(
                cand, dist, vec_j, m=m, alpha=cfg.alpha,
                fill=cfg.fill_pruned, impl=cfg.prune_impl, cand_vecs=cvec,
            )
        )
    return out


def build_flat_graph(
    vectors: np.ndarray, cfg: BuildConfig | None = None
) -> np.ndarray:
    """From-scratch single RNG graph over ``vectors`` (Oracle baseline,
    paper §5.2.4). Returns int32[n, 1, m] so it plugs into the same search
    code at layer 0. Built by the same bottom-up machinery on the slice."""
    tbl = build_neighbor_table(vectors, cfg)
    return tbl[:, :1, :]
