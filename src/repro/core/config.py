"""One frozen config for the whole query pipeline.

Every search entry point used to thread the same kwarg pile (``ef``,
``expand_width``, ``dist_impl``, ``edge_impl``, ``metric``, ...) through
``beam_search`` -> ``search_*`` -> ``RangeGraphIndex`` -> ``ServingEngine``
-> distributed/benchmarks. :class:`SearchConfig` collapses that pile into a
single frozen, hashable value (DESIGN.md §7):

  * **hashable** so it can be a static argument of the jitted searches and a
    compile-cache key of ``serve/executor.py::SearchExecutor`` — two equal
    configs share one compiled program;
  * **k stays per-call**: the requested top-k is a workload property, not a
    pipeline property. :meth:`SearchConfig.bucket_k` rounds it up to the
    next ``k_bucket`` multiple (clamped to ``ef``) so mixed-k workloads hit
    the bounded program set :meth:`SearchConfig.k_buckets` enumerates;
  * **batch buckets** live here too (:func:`batch_bucket` /
    :func:`batch_buckets`): power-of-two padded batch shapes, so a
    5-request flush pads to 8 rows instead of ``max_batch``.

The loose kwargs survive on every public entry point as a thin deprecation
shim (:func:`merge` resolves them onto a config); they go away one release
after this layer lands.
"""
from __future__ import annotations

import dataclasses
import warnings

__all__ = [
    "SearchConfig",
    "ServeConfig",
    "DEFAULT_EXPAND_WIDTH",
    "merge",
    "batch_bucket",
    "batch_buckets",
    "pick_bucket",
]

DEFAULT_EXPAND_WIDTH = 4

_METRICS = ("l2", "ip")
_DIST_IMPLS = ("auto", "pallas", "xla")
_EDGE_IMPLS = ("auto", "pallas", "xla", "argsort")
_HOP_IMPLS = ("auto", "pallas", "xla", "composed")


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Frozen query-pipeline knobs (hashable: usable as a jit static arg
    and as a compile-cache key).

    ef:           dynamic candidate-list size (beam width).
    k_bucket:     requested k rounds up to the next multiple (clamped to
                  ``ef``) before reaching the jitted search — the one
                  rounding rule shared by ``ServingEngine``,
                  ``SearchExecutor`` and the benchmark harness.
    expand_width: nodes expanded per query per beam iteration (static; the
                  engine clamps it to ``ef``).
    dist_impl:    distance backend ("auto" | "pallas" | "xla").
    edge_impl:    edge-selection backend (same set plus "argsort").
    hop_impl:     whole-hop backend ("auto" | "pallas" | "xla" |
                  "composed"). "pallas"/"xla" run the fused hop (one
                  launch per beam iteration); "composed" chains the three
                  dispatched ops, so ``dist_impl``/``edge_impl`` apply
                  inside it; "auto" = pallas on TPU, composed elsewhere
                  (``REPRO_HOP_IMPL`` / ``REPRO_IMPL`` override).
    metric:       "l2" | "ip".
    skip_layers:  Algorithm 1's skip-layer rule (improvised search only).
    max_iters:    beam iteration cap; None = the engine's ``4*ef + 32``.
    rerank:       top-``r`` exact refinement inside the jitted improvised
                  search (DESIGN.md §9): the beam returns
                  ``max(k, min(rerank, ef))`` candidates, which are
                  re-scored against the index's rerank sidecar (or the
                  navigation vectors when none) and re-cut to ``k``.
                  0 disables. Holds the recall gate for the quantized
                  storage codecs (int8/PQ).
    """

    ef: int = 64
    k_bucket: int = 10
    expand_width: int = DEFAULT_EXPAND_WIDTH
    dist_impl: str = "auto"
    edge_impl: str = "auto"
    hop_impl: str = "auto"
    metric: str = "l2"
    skip_layers: bool = True
    max_iters: int | None = None
    rerank: int = 0

    def __post_init__(self):
        if int(self.ef) < 1:
            raise ValueError(f"ef must be >= 1, got {self.ef}")
        if int(self.k_bucket) < 1:
            raise ValueError(f"k_bucket must be >= 1, got {self.k_bucket}")
        if int(self.expand_width) < 1:
            raise ValueError(
                f"expand_width must be >= 1, got {self.expand_width}"
            )
        if self.metric not in _METRICS:
            raise ValueError(f"metric {self.metric!r} not in {_METRICS}")
        if self.dist_impl not in _DIST_IMPLS:
            raise ValueError(
                f"dist_impl {self.dist_impl!r} not in {_DIST_IMPLS}"
            )
        if self.edge_impl not in _EDGE_IMPLS:
            raise ValueError(
                f"edge_impl {self.edge_impl!r} not in {_EDGE_IMPLS}"
            )
        if self.hop_impl not in _HOP_IMPLS:
            raise ValueError(
                f"hop_impl {self.hop_impl!r} not in {_HOP_IMPLS}"
            )
        if self.max_iters is not None and int(self.max_iters) < 1:
            raise ValueError(f"max_iters must be >= 1, got {self.max_iters}")
        if int(self.rerank) < 0:
            raise ValueError(f"rerank must be >= 0, got {self.rerank}")

    def replace(self, **kw) -> "SearchConfig":
        return dataclasses.replace(self, **kw)

    # -- k bucketing ---------------------------------------------------------
    def bucket_k(self, k_req: int) -> int:
        """Round a requested k up to the next ``k_bucket`` multiple, clamped
        to ``ef`` (the result list only holds ef candidates), so mixed-k
        workloads hit a bounded set of compiled programs instead of one
        retrace per distinct k (k is a static arg of the jitted search)."""
        k_req = int(k_req)
        if k_req < 1:
            raise ValueError(f"k must be >= 1, got {k_req}")
        return min(self.ef, self.k_bucket * -(-k_req // self.k_bucket))

    def k_buckets(self) -> tuple[int, ...]:
        """Every k value :meth:`bucket_k` can emit — the k axis of the
        compile-program grid (``k_bucket`` multiples below ``ef``, plus the
        ``ef`` clamp bucket)."""
        out = list(range(self.k_bucket, self.ef, self.k_bucket))
        out.append(self.ef)
        return tuple(out)


def merge(config: SearchConfig | None, *, _warn_where: str | None = None,
          **overrides) -> SearchConfig:
    """Resolve the legacy kwarg shim onto one :class:`SearchConfig`.

    Starts from ``config`` (or defaults when None) and applies every
    non-None override. With a config given, overrides are per-call
    refinements; with ``config=None`` they are the deprecated loose-kwarg
    path — ``_warn_where`` names the entry point for the once-per-process
    deprecation warning.
    """
    kw = {k: v for k, v in overrides.items() if v is not None}
    if config is None:
        if kw and _warn_where and _warn_where not in _WARNED:
            _WARNED.add(_warn_where)
            warnings.warn(
                f"{_warn_where}: loose search kwargs {sorted(kw)} are "
                "deprecated; pass config=SearchConfig(...) instead",
                DeprecationWarning, stacklevel=3,
            )
        return SearchConfig(**kw)
    return config.replace(**kw) if kw else config


_WARNED: set[str] = set()


# ---------------------------------------------------------------------------
# Serving-loop policy
# ---------------------------------------------------------------------------

_BACKPRESSURE = ("reject", "block")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Frozen policy knobs of the async serving loop (``serve/loop.py``).

    Deadlines and overload semantics are a *deployment* property, distinct
    from the query-pipeline knobs in :class:`SearchConfig` — one index can
    serve interactive traffic (tight deadline, reject) and batch traffic
    (loose deadline, block) with two loops sharing one warmed executor.

    deadline_s:        default per-request deadline budget (submit ->
                       terminal outcome); ``submit(deadline_s=...)``
                       overrides per request.
    max_queue:         admission bound on *queued* (not yet in-flight)
                       requests — the backpressure trigger.
    backpressure:      full-queue policy: ``"reject"`` fails the submit
                       with ``OverloadedError`` immediately; ``"block"``
                       awaits queue space (up to the request's deadline,
                       then ``DeadlineExceededError``).
    max_wait_s:        batch-formation linger cap: a non-full batch flushes
                       once its oldest request has waited this long (under
                       load the batch grows toward the bucket/``max_batch``
                       within the linger window).
    deadline_margin_s: flush early when the oldest queued request is within
                       this margin of its deadline — the headroom reserved
                       for the flush itself.
    shed_expired:      shed already-expired queued requests with
                       ``ShedError`` before they waste a flush (False keeps
                       the per-request timeout — they resolve with
                       ``DeadlineExceededError`` instead — but never sends
                       an expired request to compute either way).
    drain_timeout_s:   ``aclose(drain=True)`` serves pending requests for
                       at most this long before failing the remainder fast
                       with ``ShutdownError``.
    """

    deadline_s: float = 0.5
    max_queue: int = 256
    backpressure: str = "reject"
    max_wait_s: float = 0.01
    deadline_margin_s: float = 0.05
    shed_expired: bool = True
    drain_timeout_s: float = 5.0

    def __post_init__(self):
        if not float(self.deadline_s) > 0.0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")
        if int(self.max_queue) < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.backpressure not in _BACKPRESSURE:
            raise ValueError(
                f"backpressure {self.backpressure!r} not in {_BACKPRESSURE}"
            )
        if float(self.max_wait_s) < 0.0:
            raise ValueError(
                f"max_wait_s must be >= 0, got {self.max_wait_s}"
            )
        if float(self.deadline_margin_s) < 0.0:
            raise ValueError(
                f"deadline_margin_s must be >= 0, got {self.deadline_margin_s}"
            )
        if not float(self.drain_timeout_s) > 0.0:
            raise ValueError(
                f"drain_timeout_s must be > 0, got {self.drain_timeout_s}"
            )

    def replace(self, **kw) -> "ServeConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Batch-shape buckets
# ---------------------------------------------------------------------------

def batch_buckets(max_batch: int) -> tuple[int, ...]:
    """The padded batch shapes a ``max_batch``-sized executor compiles:
    powers of two below ``max_batch`` plus ``max_batch`` itself (which need
    not be a power of two)."""
    max_batch = int(max_batch)
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    out = []
    p = 1
    while p < max_batch:
        out.append(p)
        p <<= 1
    out.append(max_batch)
    return tuple(out)


def pick_bucket(b: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket of an ascending ``buckets`` ladder holding ``b``
    rows — the ONE bucket-selection rule (``SearchExecutor`` applies it to
    its own, possibly custom, ladder)."""
    b = int(b)
    if b < 1:
        raise ValueError(f"batch size must be >= 1, got {b}")
    for bb in buckets:
        if bb >= b:
            return bb
    raise ValueError(f"batch size {b} exceeds max_batch {buckets[-1]}")


def batch_bucket(b: int, max_batch: int) -> int:
    """:func:`pick_bucket` over the default :func:`batch_buckets` ladder —
    the shape a ``b``-request flush actually pads to (a 5-request flush
    pays 8-row compute, not ``max_batch``-row)."""
    return pick_bucket(b, batch_buckets(max_batch))
