"""One frozen config for the whole query pipeline.

Every search entry point used to thread the same kwarg pile (``ef``,
``expand_width``, ``dist_impl``, ``edge_impl``, ``metric``, ...) through
``beam_search`` -> ``search_*`` -> ``RangeGraphIndex`` -> ``ServingEngine``
-> distributed/benchmarks. :class:`SearchConfig` collapses that pile into a
single frozen, hashable value (DESIGN.md §7):

  * **hashable** so it can be a static argument of the jitted searches and a
    compile-cache key of ``serve/executor.py::SearchExecutor`` — two equal
    configs share one compiled program;
  * **k stays per-call**: the requested top-k is a workload property, not a
    pipeline property. :meth:`SearchConfig.bucket_k` rounds it up to the
    next ``k_bucket`` multiple (clamped to ``ef``) so mixed-k workloads hit
    the bounded program set :meth:`SearchConfig.k_buckets` enumerates;
  * **batch buckets** live here too (:func:`batch_bucket` /
    :func:`batch_buckets`): power-of-two padded batch shapes, so a
    5-request flush pads to 8 rows instead of ``max_batch``.

The loose kwargs survive on every public entry point as a thin deprecation
shim (:func:`merge` resolves them onto a config); they go away one release
after this layer lands.
"""
from __future__ import annotations

import dataclasses
import warnings

__all__ = [
    "SearchConfig",
    "DEFAULT_EXPAND_WIDTH",
    "merge",
    "batch_bucket",
    "batch_buckets",
    "pick_bucket",
]

DEFAULT_EXPAND_WIDTH = 4

_METRICS = ("l2", "ip")
_DIST_IMPLS = ("auto", "pallas", "xla")
_EDGE_IMPLS = ("auto", "pallas", "xla", "argsort")


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Frozen query-pipeline knobs (hashable: usable as a jit static arg
    and as a compile-cache key).

    ef:           dynamic candidate-list size (beam width).
    k_bucket:     requested k rounds up to the next multiple (clamped to
                  ``ef``) before reaching the jitted search — the one
                  rounding rule shared by ``ServingEngine``,
                  ``SearchExecutor`` and the benchmark harness.
    expand_width: nodes expanded per query per beam iteration (static; the
                  engine clamps it to ``ef``).
    dist_impl:    distance backend ("auto" | "pallas" | "xla").
    edge_impl:    edge-selection backend (same set plus "argsort").
    metric:       "l2" | "ip".
    skip_layers:  Algorithm 1's skip-layer rule (improvised search only).
    max_iters:    beam iteration cap; None = the engine's ``4*ef + 32``.
    """

    ef: int = 64
    k_bucket: int = 10
    expand_width: int = DEFAULT_EXPAND_WIDTH
    dist_impl: str = "auto"
    edge_impl: str = "auto"
    metric: str = "l2"
    skip_layers: bool = True
    max_iters: int | None = None

    def __post_init__(self):
        if int(self.ef) < 1:
            raise ValueError(f"ef must be >= 1, got {self.ef}")
        if int(self.k_bucket) < 1:
            raise ValueError(f"k_bucket must be >= 1, got {self.k_bucket}")
        if int(self.expand_width) < 1:
            raise ValueError(
                f"expand_width must be >= 1, got {self.expand_width}"
            )
        if self.metric not in _METRICS:
            raise ValueError(f"metric {self.metric!r} not in {_METRICS}")
        if self.dist_impl not in _DIST_IMPLS:
            raise ValueError(
                f"dist_impl {self.dist_impl!r} not in {_DIST_IMPLS}"
            )
        if self.edge_impl not in _EDGE_IMPLS:
            raise ValueError(
                f"edge_impl {self.edge_impl!r} not in {_EDGE_IMPLS}"
            )
        if self.max_iters is not None and int(self.max_iters) < 1:
            raise ValueError(f"max_iters must be >= 1, got {self.max_iters}")

    def replace(self, **kw) -> "SearchConfig":
        return dataclasses.replace(self, **kw)

    # -- k bucketing ---------------------------------------------------------
    def bucket_k(self, k_req: int) -> int:
        """Round a requested k up to the next ``k_bucket`` multiple, clamped
        to ``ef`` (the result list only holds ef candidates), so mixed-k
        workloads hit a bounded set of compiled programs instead of one
        retrace per distinct k (k is a static arg of the jitted search)."""
        k_req = int(k_req)
        if k_req < 1:
            raise ValueError(f"k must be >= 1, got {k_req}")
        return min(self.ef, self.k_bucket * -(-k_req // self.k_bucket))

    def k_buckets(self) -> tuple[int, ...]:
        """Every k value :meth:`bucket_k` can emit — the k axis of the
        compile-program grid (``k_bucket`` multiples below ``ef``, plus the
        ``ef`` clamp bucket)."""
        out = list(range(self.k_bucket, self.ef, self.k_bucket))
        out.append(self.ef)
        return tuple(out)


def merge(config: SearchConfig | None, *, _warn_where: str | None = None,
          **overrides) -> SearchConfig:
    """Resolve the legacy kwarg shim onto one :class:`SearchConfig`.

    Starts from ``config`` (or defaults when None) and applies every
    non-None override. With a config given, overrides are per-call
    refinements; with ``config=None`` they are the deprecated loose-kwarg
    path — ``_warn_where`` names the entry point for the once-per-process
    deprecation warning.
    """
    kw = {k: v for k, v in overrides.items() if v is not None}
    if config is None:
        if kw and _warn_where and _warn_where not in _WARNED:
            _WARNED.add(_warn_where)
            warnings.warn(
                f"{_warn_where}: loose search kwargs {sorted(kw)} are "
                "deprecated; pass config=SearchConfig(...) instead",
                DeprecationWarning, stacklevel=3,
            )
        return SearchConfig(**kw)
    return config.replace(**kw) if kw else config


_WARNED: set[str] = set()


# ---------------------------------------------------------------------------
# Batch-shape buckets
# ---------------------------------------------------------------------------

def batch_buckets(max_batch: int) -> tuple[int, ...]:
    """The padded batch shapes a ``max_batch``-sized executor compiles:
    powers of two below ``max_batch`` plus ``max_batch`` itself (which need
    not be a power of two)."""
    max_batch = int(max_batch)
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    out = []
    p = 1
    while p < max_batch:
        out.append(p)
        p <<= 1
    out.append(max_batch)
    return tuple(out)


def pick_bucket(b: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket of an ascending ``buckets`` ladder holding ``b``
    rows — the ONE bucket-selection rule (``SearchExecutor`` applies it to
    its own, possibly custom, ladder)."""
    b = int(b)
    if b < 1:
        raise ValueError(f"batch size must be >= 1, got {b}")
    for bb in buckets:
        if bb >= b:
            return bb
    raise ValueError(f"batch size {b} exceeds max_batch {buckets[-1]}")


def batch_bucket(b: int, max_batch: int) -> int:
    """:func:`pick_bucket` over the default :func:`batch_buckets` ladder —
    the shape a ``b``-request flush actually pads to (a 5-request flush
    pays 8-row compute, not ``max_batch``-row)."""
    return pick_bucket(b, batch_buckets(max_batch))
