"""Baseline RFANN strategies from the paper (§2.2, §5).

All baselines share the index's stored artifacts so comparisons are
apples-to-apples:

  * Pre-filtering  — exact scan of the in-range slice (index.brute_force).
  * Post-filtering — beam search on the root elemental graph (layer 0 == a
    plain whole-dataset RNG graph), keep in-range results.
  * In-filtering   — same graph, but only in-range neighbors are traversed.
  * BasicSearch    — the §5.2.2 ablation: decompose [L, R] into O(log n)
    disjoint tree segments, search each elemental graph, merge top-k.
  * SuperPostfiltering-style — search the *smallest single segment covering*
    [L, R] with post-filtering (the [29] strategy restricted to the tree's
    preset ranges).
  * Oracle         — a dedicated graph built from scratch on the exact range
    (paper §5.2.4); impractical, used to measure the gap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build as build_mod
from repro.core import config as config_mod
from repro.core import search as search_mod
from repro.core import segment_tree
from repro.core import storage as storage_mod
from repro.core.index import RangeGraphIndex

__all__ = [
    "prefilter",
    "postfilter",
    "infilter",
    "basic_search",
    "super_postfilter",
    "oracle_search",
]


def prefilter(index: RangeGraphIndex, queries, L, R, *, k=10, **_):
    ids, dists = index.brute_force(queries, L, R, k=k)
    B = ids.shape[0]
    z = np.zeros((B,), np.int32)
    nd = np.asarray(R) - np.asarray(L) + 1
    return search_mod.SearchResult(
        jnp.asarray(ids, jnp.int32), jnp.asarray(dists), jnp.asarray(z),
        jnp.asarray(nd, jnp.int32),
    )


def _filtered(index, queries, L, R, mode, k, config, legacy):
    config = config_mod.merge(config, _warn_where=f"{mode}filter", **legacy)
    return search_mod.search_filtered(
        storage_mod.as_device(index.vectors),
        storage_mod.as_device(index.neighbors),
        jnp.asarray(queries, jnp.float32),
        jnp.asarray(L, jnp.int32), jnp.asarray(R, jnp.int32),
        mode=mode, k=k, config=config,
    )


def postfilter(
    index: RangeGraphIndex, queries, L, R, *, k=10, config=None, ef=None,
    expand_width=None, dist_impl=None, edge_impl=None,
):
    return _filtered(
        index, queries, L, R, "post", k, config,
        dict(ef=ef, expand_width=expand_width, dist_impl=dist_impl,
             edge_impl=edge_impl),
    )


def infilter(
    index: RangeGraphIndex, queries, L, R, *, k=10, config=None, ef=None,
    expand_width=None, dist_impl=None, edge_impl=None,
):
    return _filtered(
        index, queries, L, R, "in", k, config,
        dict(ef=ef, expand_width=expand_width, dist_impl=dist_impl,
             edge_impl=edge_impl),
    )


def basic_search(
    index: RangeGraphIndex, queries, L, R, *, k=10, config=None, ef=None,
    expand_width=None, dist_impl=None, edge_impl=None,
):
    """Per query: search every covering segment's elemental graph, merge.

    Queries are grouped by decomposition shape on the host; each segment
    search is a batched ``search_fixed_layer`` call over all queries (a query
    not using a slot gets an empty segment).
    """
    config = config_mod.merge(
        config, ef=ef, expand_width=expand_width, dist_impl=dist_impl,
        edge_impl=edge_impl, _warn_where="basic_search",
    )
    q = jnp.asarray(queries, jnp.float32)
    B = q.shape[0]
    L = np.asarray(L)
    R = np.asarray(R)
    logn = index.logn
    decomps = [segment_tree.decompose_range(int(L[i]), int(R[i]), logn)
               for i in range(B)]
    max_segs = max(len(d) for d in decomps)
    all_ids, all_dists = [], []
    nd_total = jnp.zeros((B,), jnp.int32)
    vec = storage_mod.as_device(index.vectors)
    nbrs = storage_mod.as_device(index.neighbors)
    for s in range(max_segs):
        lay = np.zeros((B,), np.int32)
        lo = np.zeros((B,), np.int32)
        hi = np.full((B,), -1, np.int32)  # empty segment by default
        for i, d in enumerate(decomps):
            if s < len(d):
                lay[i], lo[i], hi[i] = d[s]
        # batched per distinct layer (layer is a static arg)
        ids_s = jnp.full((B, k), -1, jnp.int32)
        dists_s = jnp.full((B, k), jnp.inf)
        for layer in np.unique(lay):
            sel = lay == layer
            use_lo = jnp.asarray(np.where(sel, lo, 0), jnp.int32)
            use_hi = jnp.asarray(np.where(sel, hi, -1), jnp.int32)
            res = search_mod.search_fixed_layer(
                vec, nbrs, q, use_lo, use_hi, layer=int(layer), k=k,
                config=config,
            )
            selj = jnp.asarray(sel)
            ids_s = jnp.where(selj[:, None], res.ids, ids_s)
            dists_s = jnp.where(selj[:, None], res.dists, dists_s)
            nd_total = nd_total + jnp.where(selj, res.n_dists, 0)
        all_ids.append(ids_s)
        all_dists.append(dists_s)
    ids = jnp.concatenate(all_ids, axis=1)
    dists = jnp.concatenate(all_dists, axis=1)
    _, take = jax.lax.top_k(-dists, k)
    out_ids = jnp.take_along_axis(ids, take, 1)
    out_dists = jnp.take_along_axis(dists, take, 1)
    return search_mod.SearchResult(
        out_ids, out_dists, jnp.zeros((B,), jnp.int32), nd_total
    )


def super_postfilter(
    index: RangeGraphIndex, queries, L, R, *, k=10, config=None, ef=None,
    expand_width=None, dist_impl=None, edge_impl=None,
):
    """Smallest covering segment + post-filtering (SuperPostfiltering-style)."""
    config = config_mod.merge(
        config, ef=ef, expand_width=expand_width, dist_impl=dist_impl,
        edge_impl=edge_impl, _warn_where="super_postfilter",
    )
    ef = config.ef
    q = jnp.asarray(queries, jnp.float32)
    B = q.shape[0]
    L = np.asarray(L)
    R = np.asarray(R)
    lay = np.zeros((B,), np.int32)
    lo = np.zeros((B,), np.int32)
    hi = np.zeros((B,), np.int32)
    for i in range(B):
        lay[i], lo[i], hi[i] = segment_tree.covering_segment(
            int(L[i]), int(R[i]), index.logn
        )
    vec = storage_mod.as_device(index.vectors)
    # raw row-gather nbr_fn below: decode the compact codec at this edge
    nbrs = storage_mod.decode_neighbors(
        storage_mod.as_device(index.neighbors))
    Lj = jnp.asarray(L, jnp.int32)
    Rj = jnp.asarray(R, jnp.int32)
    out_ids = jnp.full((B, k), -1, jnp.int32)
    out_dists = jnp.full((B, k), jnp.inf)
    nd = jnp.zeros((B,), jnp.int32)
    for layer in np.unique(lay):
        sel = lay == layer
        # post-filter inside the covering segment: traverse the segment's
        # elemental graph, keep only [L, R] results
        use_lo = jnp.asarray(np.where(sel, lo, 0), jnp.int32)
        use_hi = jnp.asarray(np.where(sel, hi, -1), jnp.int32)

        def filt(ids):
            return (ids >= Lj[:, None]) & (ids <= Rj[:, None])

        # nbr_fn sees the flattened [B*W] expansion frontier; W must match
        # what beam_search derives from the same config
        eff_w = search_mod.effective_expand_width(config.expand_width, ef)
        lo_w = search_mod.tile_frontier(use_lo, eff_w)
        hi_w = search_mod.tile_frontier(use_hi, eff_w)

        def nbr_fn(u, _layer=int(layer), _lo=lo_w, _hi=hi_w):
            row = nbrs[jnp.maximum(u, 0), _layer, :]
            ok = (
                (row >= 0)
                & (row >= _lo[:, None])
                & (row <= _hi[:, None])
                & (u >= 0)[:, None]
            )
            return jnp.where(ok, row, -1)

        n = index.n
        hi_real = jnp.minimum(use_hi, n - 1)
        entries = search_mod.range_entry_ids(use_lo, hi_real, n)
        okent = (
            (use_lo[:, None] <= hi_real[:, None])
            & (entries >= use_lo[:, None])
            & (entries <= hi_real[:, None])
        )
        entries = jnp.where(okent, entries, -1)
        res = search_mod.beam_search(
            vec, q, entries, nbr_fn, k=k, config=config,
            result_filter_fn=filt,
        )
        selj = jnp.asarray(sel)
        out_ids = jnp.where(selj[:, None], res.ids, out_ids)
        out_dists = jnp.where(selj[:, None], res.dists, out_dists)
        nd = nd + jnp.where(selj, res.n_dists, 0)
    return search_mod.SearchResult(
        out_ids, out_dists, jnp.zeros((B,), jnp.int32), nd
    )


def oracle_search(
    index: RangeGraphIndex, queries, L, R, *, k=10, ef=None, config=None,
    cache: dict | None = None,
):
    """Dedicated graph built from scratch per distinct range (§5.2.4).

    ``cache`` maps (L, R) -> flat graph; pass a dict to amortize builds across
    beam-size sweeps as the paper's Fig. 4 experiment does.
    """
    config = config_mod.merge(config, ef=ef, _warn_where="oracle_search")
    q = np.asarray(queries, np.float32)
    B = q.shape[0]
    L = np.asarray(L)
    R = np.asarray(R)
    out_ids = np.full((B, k), -1, np.int32)
    out_dists = np.full((B, k), np.inf, np.float32)
    nd = np.zeros((B,), np.int32)
    cache = cache if cache is not None else {}
    groups: dict[tuple[int, int], list[int]] = {}
    for i in range(B):
        groups.setdefault((int(L[i]), int(R[i])), []).append(i)
    # the oracle graphs must be pruned exactly like the index's own (same
    # alpha/fill/prune backend), so reuse its whole config; codec tables
    # decode once at this numpy edge (oracle quality shouldn't pay twice)
    cfg = index.build_cfg
    vecs = storage_mod.decode_vectors(index.vectors)
    for (lo, hi), idxs in groups.items():
        keyed = (lo, hi)
        if keyed not in cache:
            cache[keyed] = build_mod.build_flat_graph(
                vecs[lo : hi + 1], cfg
            )
        g = cache[keyed]
        sub = jnp.asarray(vecs[lo : hi + 1])
        nn = sub.shape[0]
        qq = jnp.asarray(q[idxs])
        res = search_mod.search_fixed_layer(
            sub, jnp.asarray(g), qq,
            jnp.zeros((len(idxs),), jnp.int32),
            jnp.full((len(idxs),), nn - 1, jnp.int32),
            layer=0, k=k, config=config,
        )
        rids = np.asarray(res.ids)
        out_ids[idxs] = np.where(rids >= 0, rids + lo, -1)
        out_dists[idxs] = np.asarray(res.dists)
        nd[idxs] = np.asarray(res.n_dists)
    return search_mod.SearchResult(
        jnp.asarray(out_ids), jnp.asarray(out_dists),
        jnp.zeros((B,), jnp.int32), jnp.asarray(nd),
    )
