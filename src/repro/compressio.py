"""Blob compression with graceful degradation.

Checkpoints and serialized indexes are zstd-compressed when the ``zstandard``
package is available and fall back to stdlib ``zlib`` otherwise (this
container does not ship zstd bindings). Reads auto-detect the codec from the
frame magic, so artifacts written under one codec load under the other
environment as long as the writer's codec is importable.

The default compression level comes from the ``REPRO_COMPRESS_LEVEL`` knob
(``core/knobs.py`` registry, default 3) so deployments can trade write
latency for blob size without touching call sites; an explicit ``level=``
argument wins.
"""
from __future__ import annotations

import zlib

try:
    import zstandard
except ImportError:  # pragma: no cover - depends on the environment
    zstandard = None

from repro.core import knobs as knobs_mod

__all__ = ["compress", "decompress"]

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def compress(data: bytes, level: int | None = None) -> bytes:
    if level is None:
        level = knobs_mod.get_int("REPRO_COMPRESS_LEVEL")
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=level).compress(data)
    return zlib.compress(data, level)


def decompress(data: bytes) -> bytes:
    if data[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "blob is zstd-compressed but 'zstandard' is not installed"
            )
        return zstandard.ZstdDecompressor().decompress(data)
    return zlib.decompress(data)
