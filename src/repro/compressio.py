"""Blob compression with graceful degradation.

Checkpoints and serialized indexes are zstd-compressed when the ``zstandard``
package is available and fall back to stdlib ``zlib`` otherwise (this
container does not ship zstd bindings). Reads auto-detect the codec from the
frame magic, so artifacts written under one codec load under the other
environment as long as the writer's codec is importable.
"""
from __future__ import annotations

import zlib

try:
    import zstandard
except ImportError:  # pragma: no cover - depends on the environment
    zstandard = None

__all__ = ["compress", "decompress"]

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def compress(data: bytes, level: int = 3) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=level).compress(data)
    return zlib.compress(data, level)


def decompress(data: bytes) -> bytes:
    if data[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "blob is zstd-compressed but 'zstandard' is not installed"
            )
        return zstandard.ZstdDecompressor().decompress(data)
    return zlib.decompress(data)
