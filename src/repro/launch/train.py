"""Training launcher: ``python -m repro.launch.train --arch qwen3-0.6b``.

On this CPU container it runs reduced configs end-to-end (the examples use
it to train a ~100M-param model for a few hundred steps); on a real fleet
the same code path runs the full config — the mesh, shardings, fault
tolerance, and checkpointing are identical, only --reduced changes.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.api import Model, count_params
from repro.runtime.trainer import TrainLoopConfig, run_train_loop
from repro.sharding import partitioning as part
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import build_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--reduced-overrides", default="")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        over = {}
        for kv in filter(None, args.reduced_overrides.split(",")):
            k, v = kv.split("=")
            over[k] = type(getattr(cfg, k))(v) if getattr(cfg, k) is not None \
                else int(v)
        cfg = cfg.reduced(**over)
    cfg = dataclasses.replace(cfg, attention_impl="xla")
    model = Model(cfg)
    print(f"[train] arch={cfg.name} params={count_params(cfg)/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh(1, 1))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20,
                          total_steps=args.steps)
    pipe = TokenPipeline(
        cfg.vocab, batch=args.batch, seq=args.seq, seed=args.seed,
        encdec_dim=cfg.d_model if model.is_encdec else 0,
    )
    batches = {}

    def next_batch(step):  # deterministic replay for crash-restore
        while len(batches) <= step:
            batches[len(batches)] = pipe.next_batch()
        return batches[step]

    with part.use_global_mesh(mesh):
        params = model.init(jax.random.PRNGKey(args.seed))
        opt = init_opt_state(params)
        raw_step = build_train_step(
            model, opt_cfg, microbatches=args.microbatches,
        )
        jit_step = jax.jit(raw_step, donate_argnums=(0, 1))

        def step_fn(state, batch):
            p, o = state
            p, o, m = jit_step(p, o, batch)
            return (p, o), m

        loop_cfg = TrainLoopConfig(
            total_steps=args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every,
        )
        (params, opt), hist = run_train_loop(
            step_fn, (params, opt), next_batch, loop_cfg
        )
    losses = hist["loss"]
    print(f"[train] done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(stragglers={hist['straggler_events']}, "
          f"restarts={hist['restarts']})")
    return losses


if __name__ == "__main__":
    main()
