"""Production mesh construction.

Single pod: (data=16, model=16) == 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) == 512 chips; the ``pod`` axis is an
outer data-parallel axis (gradients cross DCI once per step; serving
replicates indexes per pod and splits query streams).

Defined as functions — importing this module never touches jax device
state, so tests and benches see the single CPU device unless a launcher
sets XLA_FLAGS first (see dryrun.py).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType

__all__ = ["make_production_mesh", "make_local_mesh", "batch_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices this host actually has."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto),
    )


def batch_axes(mesh) -> tuple:
    """Mesh axes that shard the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
