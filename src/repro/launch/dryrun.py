import os

from repro.core import knobs as knobs_mod

# must be set before jax initializes its backends; the placeholder-device
# count comes from the REPRO_DRYRUN_DEVICES knob (core/knobs.py, default
# 512 — enough for the 2x16x16 multi-pod mesh)
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    f"{knobs_mod.get_int('REPRO_DRYRUN_DEVICES')}"
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 host-platform placeholder devices stand in for the chips,
``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed for the
16x16 single-pod mesh AND the 2x16x16 multi-pod mesh, and the compiled
artifact yields the roofline terms (§Roofline):

  * compiled.cost_analysis()  -> HLO FLOPs / bytes
  * compiled.memory_analysis() -> bytes per device (fits-on-chip proof)
  * compiled.as_text() collective sweep -> all-gather/all-reduce/
    reduce-scatter/all-to-all/collective-permute operand bytes

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out artifacts/dryrun
  python -m repro.launch.dryrun --paper-system          # RFANN serve cell
"""
import argparse
import dataclasses
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_arch
from repro.launch import specs as specs_mod
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models.api import Model, count_params
from repro.sharding import partitioning as part
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import build_decode_step, build_train_step

# ---------------------------------------------------------------------------
# hardware model (TPU v5e-class chip)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

# match the op name AFTER '=' (instruction names vary: %all_gather.13 vs
# %all-gather.5); skip async -done halves (the -start carries the shape)
_COLL = re.compile(
    r"=\s*\(?\s*([a-z0-9]+)\[([0-9,]*)\][^\n]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?!-done)[\w-]*\("
)
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the compiled HLO."""
    out = {}
    for m in _COLL.finditer(hlo_text):
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op] = out.get(op, 0) + n * nbytes
        out["count_" + op] = out.get("count_" + op, 0) + 1
    out["total"] = sum(v for k, v in out.items() if not k.startswith("count"))
    return out


def skip_reason(arch: str, shape: str) -> str | None:
    cfg = get_arch(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: 500k dense KV decode is out of scope "
                "per assignment (sub-quadratic archs only)")
    return None


def _prepare(cfg, model, shape, mesh, microbatches=1):
    """Returns (fn, args, in_shardings) for the cell's step kind."""
    ispecs = specs_mod.input_specs(cfg, shape)
    ishards = specs_mod.input_shardings(cfg, shape, mesh)
    aparams = model.abstract()
    pshard = model.param_shardings(mesh)

    if shape.kind == "train":
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.train.optimizer import OptState

        opt_cfg = AdamWConfig()
        step = build_train_step(model, opt_cfg, microbatches=microbatches)
        aopt = jax.eval_shape(init_opt_state, aparams)
        # opt state mirrors param shardings (ZeRO: m/v sharded like params)
        oshard = OptState(NamedSharding(mesh, P()), pshard, pshard)
        args = (aparams, aopt, ispecs["batch"])
        shards = (pshard, oshard, ishards["batch"])
        return step, args, shards, (0, 1)  # donate params + opt state

    if shape.kind == "prefill":
        def step(params, inputs):
            return model.prefill(params, **inputs)

        return (step, (aparams, ispecs["inputs"]),
                (pshard, ishards["inputs"]), ())

    step = build_decode_step(model)
    args = (aparams, ispecs["token"], ispecs["cache"], ispecs["pos"])
    shards = (pshard, ishards["token"], ishards["cache"], ishards["pos"])
    return step, args, shards, (2,)  # donate the KV/state cache


def _compile_cell(cfg, shape, mesh, microbatches=1):
    """lower + compile one step fn; returns (compiled, wall_s)."""
    model = Model(cfg)
    t0 = time.time()
    with part.use_global_mesh(mesh):
        fn, args, shards, donate = _prepare(cfg, model, shape, mesh,
                                            microbatches)
        lowered = jax.jit(
            fn, in_shardings=shards, donate_argnums=donate
        ).lower(*args)
        compiled = lowered.compile()
    return compiled, time.time() - t0


def _cost_of(compiled):
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
    }


def _reduced_plan(cfg):
    """(La, Lb, units) so every cost quantity is affine in units(L).

    HLO cost analysis counts loop bodies once, so the exact per-step cost is
    recovered from two fully-unrolled reduced-depth compiles:
        F(L) = F(Lb) + (units(L) - units(Lb)) * dF / (units(La) - units(Lb))
    xlstm returns None: its layer loop is python-level (already exact once
    the inner chunk scans are unrolled; the sLSTM time scan stays rolled —
    a documented <0.5% undercount).
    """
    if cfg.layer_pattern == "xlstm":
        # units of (3 mLSTM + 1 sLSTM); slstm positions follow range(3,L,4)
        return 8, 4, lambda L: L // 4
    if cfg.layer_pattern == "local_global":
        return 4, 2, lambda L: L // 2
    if cfg.layer_pattern == "hybrid_shared_attn":
        # (1 group + rem) vs (rem only): the delta is exactly one group
        p = cfg.shared_attn_period
        rem = cfg.n_layers % p
        return p + rem, max(rem, 1), lambda L: L // p
    return 3, 1, lambda L: L


def _shrink(cfg, L):
    kw = {"n_layers": L}
    if cfg.family == "encdec":
        kw["enc_layers"] = L
    if cfg.layer_pattern == "xlstm":
        kw["slstm_layers"] = tuple(range(3, L, 4))
    return dataclasses.replace(cfg, **kw)


def _extrapolate(ca, cb, ua, ub, u_full):
    scale = (u_full - ub) / max(ua - ub, 1)

    def aff(a, b):
        # Affine in units; if CPU-XLA optimization noise makes the delta
        # negative (seen on tiny B=1 decode cells where per-layer cost is
        # below the compiler's op-count variance), fall back to proportional
        # scaling from the deeper compile — a monotone, conservative bound.
        if a < b:
            return a * (u_full / max(ua, 1))
        return b + (a - b) * scale

    coll_keys = set(ca["coll"]) | set(cb["coll"])
    coll = {
        k: max(0.0, aff(ca["coll"].get(k, 0), cb["coll"].get(k, 0)))
        for k in coll_keys
    }
    return {
        "flops": aff(ca["flops"], cb["flops"]),
        "bytes": aff(ca["bytes"], cb["bytes"]),
        "coll": coll,
    }


def _costs_at(cfg, shape, mesh, microbatches):
    """L-extrapolated per-step costs at the given shape."""
    plan = _reduced_plan(cfg)
    base = dataclasses.replace(cfg, scan_unroll=True)
    if plan is None:
        compiled, _ = _compile_cell(base, shape, mesh, microbatches)
        return _cost_of(compiled)
    La, Lb, units = plan
    ca = _cost_of(_compile_cell(_shrink(base, La), shape, mesh,
                                microbatches)[0])
    cb = _cost_of(_compile_cell(_shrink(base, Lb), shape, mesh,
                                microbatches)[0])
    return _extrapolate(ca, cb, units(La), units(Lb), units(cfg.n_layers))


def _fit_seq(f1, f2, s1, s2, s_full):
    """Fit f(S) = alpha*S + beta*S^2 through two points; exact for both
    linear-time (SSM/local) and quadratic (causal attention) prefill. A
    negative beta (linear archs + compiler noise) clamps to proportional
    scaling from the larger point."""
    beta = (f2 / s2 - f1 / s1) / (s2 - s1)
    if beta < 0:
        return f2 * (s_full / s2)
    alpha = f1 / s1 - beta * s1
    return max(0.0, alpha * s_full + beta * s_full * s_full)


def exact_costs(cfg, shape, mesh, microbatches=1) -> dict:
    """Per-step HLO costs with loop trip counts accounted for.

    prefill_32k additionally fits over sequence length from two short
    compiles (S in {2048, 4096}) — unrolling the 32k inner chunk scans is
    compile-time intractable on this host, and per-step cost is exactly
    alpha*S + beta*S^2 for every assigned family."""
    if shape.kind == "prefill" and shape.seq_len > 8192:
        s1, s2 = 2048, 4096
        sh1 = dataclasses.replace(shape, seq_len=s1, name=shape.name)
        sh2 = dataclasses.replace(shape, seq_len=s2, name=shape.name)
        c1 = _costs_at(cfg, sh1, mesh, microbatches)
        c2 = _costs_at(cfg, sh2, mesh, microbatches)
        S = shape.seq_len
        coll_keys = set(c1["coll"]) | set(c2["coll"])
        return {
            "flops": _fit_seq(c1["flops"], c2["flops"], s1, s2, S),
            "bytes": _fit_seq(c1["bytes"], c2["bytes"], s1, s2, S),
            "coll": {
                k: _fit_seq(c1["coll"].get(k, 0), c2["coll"].get(k, 0),
                            s1, s2, S)
                for k in coll_keys
            },
        }
    return _costs_at(cfg, shape, mesh, microbatches)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             cost_pass: bool = True, overrides: dict | None = None) -> dict:
    print(f"# cell {arch} {shape_name} multi_pod={multi_pod}",
          file=sys.stderr, flush=True)
    shape = SHAPES[shape_name]
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
    }
    reason = skip_reason(arch, shape_name)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    cfg = dataclasses.replace(get_arch(arch), attention_impl="xla",
                              **(overrides or {}))
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size

    # 1) full-depth compile: the multi-pod shardability + fits-in-HBM proof.
    #    Train cells that exceed HBM retry with microbatch accumulation
    #    (peak activations = one microbatch) — recorded in the cell.
    HBM = 16e9

    def mem_of(compiled):
        m = compiled.memory_analysis()
        return int(
            getattr(m, "temp_size_in_bytes", 0)
            + getattr(m, "argument_size_in_bytes", 0)
            + getattr(m, "output_size_in_bytes", 0)
            - getattr(m, "alias_size_in_bytes", 0)
        )

    # initial microbatch guess from a napkin activation model:
    # saved-resident activations ~ L * B_local * S * d * 2B (remat inputs)
    microbatches = 1
    if shape.kind == "train":
        b_local = shape.global_batch / mesh.shape["data"]
        act = cfg.n_layers * b_local * shape.seq_len * cfg.d_model * 2
        while act / microbatches > 4e9 and microbatches < 16:
            microbatches *= 4
    compiled, wall = _compile_cell(cfg, shape, mesh, microbatches)
    rec["status"] = "ok"
    rec["compile_s"] = round(wall, 1)
    rec["bytes_per_device"] = mem_of(compiled)
    if shape.kind == "train":
        while rec["bytes_per_device"] > HBM and microbatches < 16:
            rec.setdefault("bytes_per_device_mb1", rec["bytes_per_device"])
            microbatches *= 4
            compiled, wall = _compile_cell(cfg, shape, mesh, microbatches)
            rec["bytes_per_device"] = mem_of(compiled)
            rec["compile_s"] += round(wall, 1)
        rec["microbatches"] = microbatches

    # 2) cost pass: exact per-step FLOPs/bytes/collectives via unrolled
    #    reduced-depth extrapolation (single-pod roofline table)
    if not cost_pass:
        return rec
    # cost pass at mb=1: a step with mb=k does the same total arithmetic
    # as mb=1 (same global batch), modulo (k-1) extra param all-gathers —
    # noted analytically below instead of unrolling k model copies.
    cost = exact_costs(cfg, shape, mesh, 1)
    # cost_analysis runs on the SPMD-partitioned module -> PER-DEVICE cost;
    # global = per-device * n_chips. The roofline terms below equal the
    # spec's global/(chips*peak) form.
    flops, bytes_acc, coll = cost["flops"], cost["bytes"], cost["coll"]
    rec["hlo_gflops"] = flops * n_chips / 1e9           # global
    rec["hlo_gbytes"] = bytes_acc * n_chips / 1e9       # global
    rec["collectives"] = {k: int(v) for k, v in coll.items()}  # per device
    rec["t_compute"] = flops / PEAK_FLOPS
    rec["t_memory"] = bytes_acc / HBM_BW
    rec["t_collective"] = coll.get("total", 0) / ICI_BW
    terms = {
        "compute": rec["t_compute"],
        "memory": rec["t_memory"],
        "collective": rec["t_collective"],
    }
    rec["bottleneck"] = max(terms, key=terms.get)

    # model flops (6 N D for train; 2 N D for a decode/prefill token pass)
    n_active = count_params(cfg, active_only=True)
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1
    )
    factor = 6 if shape.kind == "train" else 2
    rec["model_gflops"] = factor * n_active * tokens / 1e9
    rec["useful_flop_frac"] = (
        rec["model_gflops"] / rec["hlo_gflops"] if flops else None
    )
    if microbatches > 1:
        rec["collective_note"] = (
            f"microbatching x{microbatches}: param all-gathers repeat per "
            f"microbatch; collective term upper bound +"
            f"{(microbatches - 1) * coll.get('all-gather', 0) / 1e9:.1f} "
            f"GB/device"
        )
    return rec


def run_paper_system_cell(*, multi_pod: bool, n_per_shard=65536, dim=768,
                          m=16, ef=64, k=10, qbatch=4096,
                          vec_dtype="float32", nbr_dtype="int32") -> dict:
    """The paper's own serve_step on the production mesh (RFANN cell).

    vec_dtype/nbr_dtype: hillclimb knobs — bf16 vectors and int16 local
    neighbor ids halve the two dominant HBM streams of the traversal."""
    import math

    from repro.core import distributed as dist_mod
    from repro.core.config import SearchConfig

    mesh = make_production_mesh(multi_pod=multi_pod)
    from jax.sharding import NamedSharding, PartitionSpec as P

    S = mesh.shape["data"]
    logn = int(math.ceil(math.log2(n_per_shard)))
    layers = logn + 1
    qspec = P(("pod", "model")) if "pod" in mesh.shape else P("model")
    args = (
        jax.ShapeDtypeStruct((S, n_per_shard, dim), jnp.dtype(vec_dtype)),
        jax.ShapeDtypeStruct((S, n_per_shard, layers, m),
                             jnp.dtype(nbr_dtype)),
        jax.ShapeDtypeStruct((S, 2), jnp.int32),
        jax.ShapeDtypeStruct((qbatch, dim), jnp.dtype(vec_dtype)),
        jax.ShapeDtypeStruct((qbatch,), jnp.int32),
        jax.ShapeDtypeStruct((qbatch,), jnp.int32),
    )
    shards = (
        NamedSharding(mesh, P("data")),
        NamedSharding(mesh, P("data")),
        NamedSharding(mesh, P("data")),
        NamedSharding(mesh, qspec),
        NamedSharding(mesh, qspec),
        NamedSharding(mesh, qspec),
    )
    step = dist_mod.make_serve_jit(
        mesh, logn=logn, m=m, k=k, config=SearchConfig(ef=ef))
    t0 = time.time()
    lowered = jax.jit(
        lambda *a: step(*a), in_shardings=shards
    ).lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    n_chips = mesh.size
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    rec = {
        "arch": "iRangeGraph-serve", "shape": f"q{qbatch}_n{S*n_per_shard}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "hlo_gflops": flops * n_chips / 1e9,
        "hlo_gbytes": bytes_acc * n_chips / 1e9,
        "collectives": coll,
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": bytes_acc / HBM_BW,
        "t_collective": coll.get("total", 0) / ICI_BW,
    }
    terms = {k2: rec["t_" + k2] for k2 in ("compute", "memory", "collective")}
    rec["bottleneck"] = max(terms, key=terms.get)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--paper-system", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--override", default="",
                    help="cfg overrides k=v,... (hillclimb variants)")
    ap.add_argument("--skip-archs", default="",
                    help="comma-separated archs to skip (resume support)")
    args = ap.parse_args()

    overrides = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        overrides[k] = {"true": True, "false": False}.get(
            v.lower(), v if not v.replace(".", "").isdigit()
            else (float(v) if "." in v else int(v))
        )

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    records = []
    outf = None
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        outf = open(args.out, "w")

    def emit(rec):
        records.append(rec)
        if outf:
            outf.write(json.dumps(rec) + "\n")
            outf.flush()

    if args.paper_system:
        for mp in meshes:
            rec = run_paper_system_cell(
                multi_pod=mp,
                vec_dtype=str(overrides.get("vec_dtype", "float32")),
                nbr_dtype=str(overrides.get("nbr_dtype", "int32")),
            )
            print(json.dumps(rec))
            emit(rec)
    else:
        cells = []
        if args.all:
            skip = set(filter(None, args.skip_archs.split(",")))
            by_cost = sorted(ARCHS, key=lambda a: count_params(ARCHS[a]))
            for a in by_cost:
                if a in skip:
                    continue
                for s in SHAPES:
                    cells.append((a, s))
        else:
            assert args.arch and args.shape, "--arch/--shape or --all"
            cells = [(args.arch, args.shape)]
        for a, s in cells:
            for mp in meshes:
                try:
                    # roofline cost pass runs on the single-pod mesh only
                    rec = run_cell(a, s, multi_pod=mp, cost_pass=not mp,
                                   overrides=overrides)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    rec = {
                        "arch": a, "shape": s,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                    }
                print(json.dumps(rec))
                sys.stdout.flush()
                emit(rec)

    if outf:
        outf.close()


if __name__ == "__main__":
    main()
