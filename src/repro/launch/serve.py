"""Serving launcher: build an iRangeGraph index over model embeddings and
serve batched RFANN queries.

``python -m repro.launch.serve --arch qwen3-0.6b --n 4096 --queries 256``

This is the end-to-end path of the framework: backbone -> embeddings ->
iRangeGraph build -> batched range-filtered serving with recall probes.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import BuildConfig, RangeGraphIndex, SearchConfig, recall
from repro.models.api import Model
from repro.serve.engine import Request, ServingEngine


def embed_corpus(model, params, n, seq, vocab, seed=0, batch=64):
    rng = np.random.default_rng(seed)
    out = []
    embed = jax.jit(model.embed)
    for s in range(0, n, batch):
        e = min(n, s + batch)
        toks = rng.integers(0, vocab, (e - s, seq)).astype(np.int32)
        out.append(np.asarray(embed(params, toks)))
    return np.concatenate(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--ef", type=int, default=64)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch).reduced()
    cfg = dataclasses.replace(cfg, attention_impl="xla")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    print(f"[serve] embedding {args.n} items with {cfg.name} (reduced)")
    vectors = embed_corpus(model, params, args.n, args.seq, cfg.vocab,
                           args.seed)
    rng = np.random.default_rng(args.seed + 1)
    attrs = rng.uniform(0, 1e6, args.n)

    t0 = time.time()
    index = RangeGraphIndex.build(
        vectors, attrs, BuildConfig(m=args.m, ef_construction=2 * args.ef)
    )
    print(f"[serve] index built in {time.time()-t0:.1f}s "
          f"({index.nbytes/1e6:.1f} MB)")

    engine = ServingEngine(
        index, config=SearchConfig(ef=args.ef, k_bucket=args.k), max_batch=64
    )
    engine.warmup(k_buckets=(args.k,))  # AOT: first flush pays no compiles
    qv = embed_corpus(model, params, args.queries, args.seq, cfg.vocab,
                      args.seed + 2)
    los = rng.uniform(0, 5e5, args.queries)
    his = los + rng.uniform(1e5, 5e5, args.queries)
    for i in range(args.queries):
        engine.submit(Request(qv[i], los[i], his[i], k=args.k))
    results = engine.flush()

    # recall probe on a subsample
    L, R = index.ranks_of(los[:32], his[:32])
    gt, _ = index.brute_force(qv[:32], L, R, k=args.k)
    got = np.stack([
        index.perm.argsort()[r.ids] if False else r.ids
        for r in results[:32]
    ])
    # map gt (rank space) to original ids for comparison
    gt_orig = np.where(gt >= 0, index.perm[np.maximum(gt, 0)], -1)
    rec = recall(got, gt_orig)
    print(f"[serve] served {len(results)} queries at {engine.qps:.0f} qps, "
          f"recall@{args.k}={rec:.3f}")
    return engine.qps, rec


if __name__ == "__main__":
    main()
