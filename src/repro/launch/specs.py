"""ShapeDtypeStruct input specs + shardings for every (arch x shape) cell.

``input_specs(cfg, shape)`` produces weak-type-correct, shardable stand-ins
for every model input — no device allocation — and ``input_shardings`` the
matching NamedShardings for the production mesh. Decode caches get their
shardings from leaf-path heuristics over the cache pytree (attn KV:
[..., B, Hkv, S, Dh] — batch over (pod, data), heads over model, seq over
data for the long-context sequence-parallel path; SSM/xLSTM states: batch +
heads rules).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.mesh import batch_axes
from repro.models.api import Model

__all__ = ["input_specs", "input_shardings", "cache_shardings"]


def _div(n, size):
    return size > 0 and n % size == 0


def _axsize(mesh, axes):
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes if a in mesh.shape]))


def _maybe(mesh, axes, dim):
    """axes if dim divides the product of their sizes, else None."""
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return None
    if _div(dim, _axsize(mesh, axes)):
        return axes if len(axes) > 1 else axes[0]
    return None


def input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """Returns a dict of ShapeDtypeStructs keyed like the step-fn kwargs."""
    model = Model(cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": model.train_batch_specs(B, S)}
    if shape.kind == "prefill":
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        inputs = {"tokens": tok}
        if model.is_encdec:
            inputs["frames"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.dtype(cfg.compute_dtype)
            )
        return {"inputs": inputs}
    # decode: one new token against a cache of seq_len
    seq_shard = shape.name == "long_500k"
    cache = model.cache_specs(B, S, seq_shard=seq_shard)
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "cache": cache,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_shardings(cfg: ArchConfig, cache_spec, mesh: Mesh, batch: int,
                    *, seq_shard: bool):
    """NamedSharding tree for a decode cache via leaf-path heuristics."""
    baxes = _maybe(mesh, batch_axes(mesh), batch)
    seq_ax = "data" if seq_shard else None

    def leaf(path, a):
        names = [str(getattr(k, "key", "")) for k in path]
        shape = a.shape
        rank = len(shape)
        spec = [None] * rank
        # batch dim = first occurrence of the batch size past any layer-stack
        # dims (stack dims come first and never equal the prod batch sizes)
        bidx = next((i for i, s in enumerate(shape) if s == batch), None)
        if bidx is None:
            return NamedSharding(mesh, P(*spec))
        spec[bidx] = baxes
        is_kv = names and names[-1] in ("k", "v")
        if is_kv and rank - bidx >= 4:          # [.., B, Hkv, S, Dh]
            h_ax = _maybe(mesh, "model", shape[bidx + 1])
            spec[bidx + 1] = h_ax
            # sequence sharding: explicit for long-context cells, and as the
            # fallback when GQA kv-heads cannot cover the model axis (the
            # flash-decode pattern: partial scores + all-reduced softmax
            # stats, instead of a replicated multi-GB cache)
            cands = ([seq_ax] if seq_ax else []) + (
                ["model"] if h_ax is None else []
            )
            for cand in cands:
                ax = _maybe(mesh, cand, shape[bidx + 2])
                if ax is not None:
                    spec[bidx + 2] = ax
                    break
        elif rank - bidx >= 2:                   # states: heads/feature next
            spec[bidx + 1] = _maybe(mesh, "model", shape[bidx + 1])
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf, cache_spec)


def input_shardings(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh):
    """Shardings congruent with input_specs(cfg, shape)."""
    B = shape.global_batch
    baxes = _maybe(mesh, batch_axes(mesh), B)
    tok_sh = NamedSharding(mesh, P(baxes, None))
    if shape.kind == "train":
        model = Model(cfg)
        sh = {"tokens": tok_sh, "targets": tok_sh}
        if model.is_encdec:
            sh["frames"] = NamedSharding(mesh, P(baxes, None, None))
        return {"batch": sh}
    if shape.kind == "prefill":
        sh = {"tokens": tok_sh}
        if Model(cfg).is_encdec:
            sh["frames"] = NamedSharding(mesh, P(baxes, None, None))
        return {"inputs": sh}
    seq_shard = shape.name == "long_500k"
    cache_spec = Model(cfg).cache_specs(B, shape.seq_len,
                                        seq_shard=seq_shard)
    return {
        "token": tok_sh,
        "cache": cache_shardings(cfg, cache_spec, mesh, B,
                                 seq_shard=seq_shard),
        "pos": NamedSharding(mesh, P()),
    }
