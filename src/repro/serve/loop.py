"""Deadline-aware async serving loop: the overload-safe front-end.

``ServingEngine`` (serve/engine.py) is a synchronous, caller-driven queue —
fine when the caller owns the clock, wrong for open traffic: one slow flush
stalls everything behind it, nothing bounds the queue, and a request has no
deadline. :class:`AsyncServingEngine` is the JetStream-style loop on top of
the same warmed ``SearchExecutor`` (ROADMAP's "millions of users" item):

  * **One terminal outcome per request.** ``await submit(req)`` resolves
    with exactly one of {``Result``, ``InvalidRequestError``,
    ``OverloadedError``, ``ShedError``, ``DeadlineExceededError``,
    ``ShutdownError``, the flush's own exception} — futures are the source
    of truth and every resolution path checks ``fut.done()`` first, so a
    request can never be lost or resolved twice (the chaos suite pins
    this under injected faults at overload).
  * **Admission control + backpressure.** A bounded queue
    (``ServeConfig.max_queue``); when full, ``"reject"`` fails the submit
    with ``OverloadedError`` immediately and ``"block"`` awaits space up
    to the request's deadline.
  * **Deadline-aware batch formation.** The background flush task lingers
    up to ``max_wait_s`` growing the batch toward the executor's bucket /
    ``max_batch`` under load, but flushes early when the oldest request is
    within ``deadline_margin_s`` of its deadline — and immediately when
    the batch is full.
  * **Load shedding before compute.** Requests whose deadline expired
    while still queued are shed (``ShedError``) at formation/reap time and
    never reach the executor; in-flight requests whose deadline passes
    resolve with ``DeadlineExceededError`` from the reaper task while the
    flush keeps running in a worker thread (``asyncio.to_thread``), so an
    executor latency spike cannot freeze timeout delivery.
  * **Graceful drain.** ``aclose(drain=True)`` serves what it can within
    ``drain_timeout_s`` and fails the rest fast with ``ShutdownError``;
    ``drain=False`` fails everything pending immediately. Nothing is ever
    silently dropped.

Batch formation (``plan_flush``) and the batch runner
(``run_search_batch``, which hosts the fault-injection hooks of
``serve/faults.py``) are shared with the sync engine. ``faults=None``
(default) resolves the ``REPRO_FAULTS`` env — the CI chaos leg drives the
loop's failure paths through the whole test suite.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from collections import deque

import numpy as np

from repro.core.config import SearchConfig, ServeConfig
from repro.serve import faults as faults_mod
from repro.serve.engine import Request, Result, plan_flush, run_search_batch, \
    validate_request
from repro.serve.errors import DeadlineExceededError, OverloadedError, \
    ShedError, ShutdownError
from repro.serve.executor import SearchExecutor

__all__ = ["AsyncServingEngine"]


@dataclasses.dataclass(eq=False)   # identity semantics: lives in a set
class _Pending:
    req: Request
    fut: asyncio.Future
    t_submit: float     # monotonic
    deadline: float     # monotonic


class AsyncServingEngine:
    def __init__(
        self, index, *, config: SearchConfig | None = None,
        serve: ServeConfig | None = None, max_batch: int = 64,
        executor: SearchExecutor | None = None, warmup: bool | None = None,
        faults=None,
    ):
        """config: the query-pipeline ``SearchConfig`` (forwarded to a new
        executor). serve: the loop's ``ServeConfig`` policy (deadlines,
        queue bound, backpressure, linger). executor: share a prebuilt
        warmed ``SearchExecutor`` (its config/max_batch win; it is left
        open on close). faults: see ``serve/faults.py::resolve`` — None
        picks up ``REPRO_FAULTS``, False disables injection."""
        self.index = index
        self.serve = serve or ServeConfig()
        self._owns_executor = executor is None
        if executor is None:
            executor = SearchExecutor(
                index, config or SearchConfig(), max_batch=max_batch,
                warmup=warmup,
            )
        elif warmup:
            executor.warmup()
        self.executor = executor
        self.config = executor.config
        self.faults = faults_mod.resolve(faults)
        self.closed = False
        self._pending: deque[_Pending] = deque()
        self._inflight: set[_Pending] = set()
        self._flusher: asyncio.Task | None = None
        self._reaper: asyncio.Task | None = None
        self._wake = asyncio.Event()        # flusher: new work arrived
        self._reap_wake = asyncio.Event()   # reaper: deadlines changed
        self._space = asyncio.Event()       # blocked submitters: queue shrank
        self._idle = asyncio.Event()        # drain: nothing pending/in flight
        self._latencies: deque[float] = deque(maxlen=8192)
        self._counts = {
            "submitted": 0, "served": 0, "rejected": 0, "shed": 0,
            "timeouts": 0, "failed": 0, "shutdown": 0, "dispatched": 0,
            "flushes": 0, "flush_failures": 0, "late_results": 0,
        }

    # -- lifecycle -----------------------------------------------------------
    def _ensure_started(self):
        if self._flusher is None or self._flusher.done():
            loop = asyncio.get_running_loop()
            self._flusher = loop.create_task(self._flush_loop())
            self._reaper = loop.create_task(self._reap_loop())

    async def __aenter__(self):
        return self

    async def __aexit__(self, *exc):
        await self.aclose()

    async def aclose(self, *, drain: bool = True):
        """Stop accepting requests; resolve every pending one.

        drain=True keeps flushing (and shedding/timing out per deadline)
        for up to ``serve.drain_timeout_s``; whatever is still unresolved
        then — and everything, immediately, under drain=False — fails fast
        with ``ShutdownError``. Exactly one outcome per request holds
        through shutdown."""
        if self.closed:
            return
        self.closed = True
        if self._flusher is not None:
            self._wake.set()
            self._space.set()   # blocked submitters observe closed
            self._maybe_idle()
            if drain:
                try:
                    await asyncio.wait_for(
                        self._idle.wait(), self.serve.drain_timeout_s
                    )
                except asyncio.TimeoutError:
                    pass
            for p in list(self._pending) + list(self._inflight):
                if not p.fut.done():
                    self._counts["shutdown"] += 1
                    p.fut.set_exception(
                        ShutdownError("engine closed before serving request")
                    )
            self._pending.clear()
            for t in (self._flusher, self._reaper):
                t.cancel()
            await asyncio.gather(
                self._flusher, self._reaper, return_exceptions=True
            )
        if self._owns_executor:
            self.executor.close()

    # -- submission ----------------------------------------------------------
    async def submit(self, req: Request, *, deadline_s: float | None = None):
        """Admit, enqueue and await one request's terminal outcome.

        Validation failures, admission rejections and backpressure
        timeouts raise here (the request never queues); everything else
        resolves through the request's future."""
        if self.closed:
            raise ShutdownError("AsyncServingEngine is closed")
        validate_request(req, dim=self.index.dim, ef=self.config.ef)
        self._ensure_started()
        now = time.monotonic()
        budget = self.serve.deadline_s if deadline_s is None \
            else float(deadline_s)
        if not budget > 0.0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        deadline = now + budget
        await self._admit(deadline)
        p = _Pending(
            req, asyncio.get_running_loop().create_future(),
            time.monotonic(), deadline,
        )
        self._pending.append(p)
        self._counts["submitted"] += 1
        self._wake.set()
        self._reap_wake.set()
        return await p.fut

    async def _admit(self, deadline: float):
        """Admission control: bounded queue + the backpressure policy.
        ``queue_full`` faults force the full path for one check (a burst)."""
        while True:
            if self.closed:
                raise ShutdownError("AsyncServingEngine is closed")
            full = len(self._pending) >= self.serve.max_queue
            burst = (not full and self.faults is not None
                     and self.faults.queue_full())
            if not full and not burst:
                return
            if self.serve.backpressure == "reject":
                self._counts["rejected"] += 1
                raise OverloadedError(
                    f"queue full ({len(self._pending)}/"
                    f"{self.serve.max_queue})"
                )
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                self._counts["timeouts"] += 1
                raise DeadlineExceededError(
                    "deadline expired while blocked on backpressure"
                )
            # a fault burst is transient: recheck quickly instead of
            # waiting for real queue space that may never be signalled
            self._space.clear()
            try:
                await asyncio.wait_for(
                    self._space.wait(),
                    min(remaining, 0.005) if burst else remaining,
                )
            except asyncio.TimeoutError:
                pass

    # -- background tasks ----------------------------------------------------
    async def _flush_loop(self):
        while True:
            now = time.monotonic()
            self._compact_queue(now)
            if not self._pending:
                self._maybe_idle()
                if self.closed:
                    return
                self._wake.clear()
                await self._wake.wait()
                continue
            oldest = self._pending[0]
            due = min(
                oldest.t_submit + self.serve.max_wait_s,
                oldest.deadline - self.serve.deadline_margin_s,
            )
            if (len(self._pending) >= self.executor.max_batch
                    or now >= due or self.closed):
                await self._flush_once()
            else:
                # linger: grow the batch toward the bucket under load, but
                # wake on new arrivals (they may fill the batch early)
                self._wake.clear()
                try:
                    await asyncio.wait_for(
                        self._wake.wait(), max(due - now, 0.0)
                    )
                except asyncio.TimeoutError:
                    pass

    async def _flush_once(self):
        take: list[_Pending] = []
        while self._pending and len(take) < self.executor.max_batch:
            p = self._pending.popleft()
            if not p.fut.done():   # shed/timed-out entries never dispatch
                take.append(p)
        self._space.set()
        if not take:
            return
        plans = plan_flush(
            [p.req for p in take], self.config, self.executor.max_batch
        )
        self._inflight.update(take)
        for kb, idxs in plans:
            batch = [take[i] for i in idxs]
            self._counts["flushes"] += 1
            self._counts["dispatched"] += len(batch)
            try:
                orig, dists = await asyncio.to_thread(
                    run_search_batch, self.index, self.executor,
                    [p.req for p in batch], kb, faults=self.faults,
                )
            except Exception as e:  # noqa: BLE001 — isolate to this batch
                self._counts["flush_failures"] += 1
                for p in batch:
                    self._inflight.discard(p)
                    if not p.fut.done():
                        self._counts["failed"] += 1
                        p.fut.set_exception(e)
                continue
            t1 = time.monotonic()
            for row, p in enumerate(batch):
                self._inflight.discard(p)
                if p.fut.done():   # timed out while the flush ran
                    self._counts["late_results"] += 1
                    continue
                lat = t1 - p.t_submit
                self._latencies.append(lat)
                self._counts["served"] += 1
                p.fut.set_result(Result(
                    orig[row, : p.req.k], dists[row, : p.req.k], lat
                ))
        self._maybe_idle()

    async def _reap_loop(self):
        """Deadline watcher: sheds expired queued requests and times out
        expired in-flight ones — independent of the flusher, so a latency
        spike inside a flush cannot delay timeout delivery."""
        while True:
            now = time.monotonic()
            nxt = self._compact_queue(now)
            for p in self._inflight:
                if p.fut.done():
                    continue
                if p.deadline <= now:
                    self._counts["timeouts"] += 1
                    p.fut.set_exception(DeadlineExceededError(
                        "deadline exceeded while request was in flight"
                    ))
                elif nxt is None or p.deadline < nxt:
                    nxt = p.deadline
            self._maybe_idle()
            self._reap_wake.clear()
            try:
                if nxt is None:
                    await self._reap_wake.wait()
                else:
                    await asyncio.wait_for(
                        self._reap_wake.wait(), max(nxt - now, 1e-3)
                    )
            except asyncio.TimeoutError:
                pass

    def _compact_queue(self, now: float):
        """Resolve expired queued entries (shed before compute) and drop
        resolved ones; returns the earliest remaining queued deadline."""
        nxt = None
        keep: deque[_Pending] = deque()
        shrank = False
        while self._pending:
            p = self._pending.popleft()
            if p.fut.done():
                shrank = True
                continue
            if p.deadline <= now:
                shrank = True
                if self.serve.shed_expired:
                    self._counts["shed"] += 1
                    p.fut.set_exception(ShedError(
                        "deadline expired while queued; shed before compute"
                    ))
                else:
                    self._counts["timeouts"] += 1
                    p.fut.set_exception(DeadlineExceededError(
                        "deadline expired while queued"
                    ))
                continue
            keep.append(p)
            if nxt is None or p.deadline < nxt:
                nxt = p.deadline
        self._pending = keep
        if shrank:
            self._space.set()
        return nxt

    def _maybe_idle(self):
        if self.closed and not self._pending and not any(
            not p.fut.done() for p in self._inflight
        ):
            self._idle.set()

    # -- stats ---------------------------------------------------------------
    @property
    def stats(self) -> dict:
        ex = self.executor.stats
        lat = np.fromiter(self._latencies, float) if self._latencies else None
        pct = {
            f"latency_p{p}": float(np.percentile(lat, p)) if lat is not None
            else 0.0
            for p in (50, 95, 99)
        }
        return {
            **self._counts,
            "queue_depth": len(self._pending),
            "compiles": ex["compiles"],
            "warmup_compiles": ex["warmup_compiles"],
            "cache_hits": ex["cache_hits"],
            "index_bytes": ex["index_bytes"],
            **pct,
        }
