"""RFANN serving engine: request batching over a SearchExecutor.

Mirrors a production vector-search frontend: requests (vector + value range
+ k) accumulate in a queue; ``flush`` groups them by k bucket (so one
``k=ef`` straggler stops inflating everyone's top-k), cuts each group into
``max_batch``-sized batches, and hands them to the executor — which pads to
power-of-two batch buckets and serves each (config, batch_bucket, k_bucket)
from its AOT compile cache (``serve/executor.py``). The engine itself is
only queueing + per-request stats:

  * ``Result.latency_s`` is the request's OWN queue+batch time (submit ->
    result), not the whole-batch wall time;
  * ``stats`` exposes latency percentiles (p50/p95/p99 over the last 8192
    requests — a bounded window, so long-running engines stay O(1) memory
    and the numbers track *recent* traffic), executor compile accounting,
    qps, and the served index's real footprint
    (``index_bytes``) — a compact-storage index (``core/storage.py``)
    serves unchanged, decoding at the search edge.

Engine knobs arrive as ONE ``SearchConfig``; the historical loose kwargs
(``ef=``, ``k_bucket=``, ...) remain as a deprecation shim.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.core import config as config_mod
from repro.core.config import SearchConfig
from repro.core.index import RangeGraphIndex
from repro.serve.executor import SearchExecutor

__all__ = ["Request", "Result", "ServingEngine", "bucket_k"]


def bucket_k(k_req: int, k_bucket: int, ef: int) -> int:
    """Round a requested k up to the next ``k_bucket`` multiple, clamped to
    ef. Thin compatibility wrapper over the one rounding rule,
    ``core/config.py::SearchConfig.bucket_k`` (shared by ``ServingEngine``,
    ``SearchExecutor`` and ``benchmarks/common.make_searcher``)."""
    return SearchConfig(ef=ef, k_bucket=k_bucket).bucket_k(k_req)


@dataclasses.dataclass
class Request:
    vector: np.ndarray
    lo: float
    hi: float
    k: int = 10


@dataclasses.dataclass
class Result:
    ids: np.ndarray         # original object ids
    dists: np.ndarray
    latency_s: float        # this request's queue + batch time


class ServingEngine:
    def __init__(
        self, index: RangeGraphIndex, *, config: SearchConfig | None = None,
        max_batch: int = 64, executor: SearchExecutor | None = None,
        warmup: bool | None = None, ef: int | None = None,
        k_bucket: int | None = None, expand_width: int | None = None,
        dist_impl: str | None = None, edge_impl: str | None = None,
    ):
        """config: the engine's ``SearchConfig`` (the loose kwargs are the
        deprecation shim). executor: share a prebuilt ``SearchExecutor``
        (its config/max_batch win). warmup: AOT-compile the executor's
        grid now — forwarded to a newly built executor (None = the
        ``REPRO_SERVE_WARMUP`` env) and, when True, also applied to a
        prebuilt one."""
        config = config_mod.merge(
            config, ef=ef, k_bucket=k_bucket, expand_width=expand_width,
            dist_impl=dist_impl, edge_impl=edge_impl,
            _warn_where="ServingEngine",
        )
        self.index = index
        if executor is None:
            executor = SearchExecutor(
                index, config, max_batch=max_batch, warmup=warmup
            )
        elif warmup:
            executor.warmup()
        self.executor = executor
        self.config = self.executor.config
        self._queue: list[tuple[Request, float]] = []
        # bounded window: percentiles track recent traffic at O(1) memory
        self._latencies: deque[float] = deque(maxlen=8192)
        self._counts = {"served": 0, "batches": 0, "wall_s": 0.0}

    # historical attribute surface, now derived from the one config
    @property
    def ef(self) -> int:
        return self.config.ef

    @property
    def k_bucket(self) -> int:
        return self.config.k_bucket

    @property
    def max_batch(self) -> int:
        return self.executor.max_batch

    @property
    def _k_buckets(self) -> set[int]:
        """k buckets this engine has sent down (compat alias)."""
        return self.executor.seen_k_buckets

    def warmup(self, **kw) -> int:
        """AOT-compile the executor's program grid (see
        ``SearchExecutor.warmup``); afterwards any mixed workload inside
        the grid serves with zero additional compiles."""
        return self.executor.warmup(**kw)

    def submit(self, req: Request):
        """Reject invalid k here, at the request boundary — once a request
        is queued, flush must be able to serve the whole queue."""
        if req.k < 1:
            raise ValueError(f"requested k={req.k} must be >= 1")
        if req.k > self.config.ef:
            raise ValueError(
                f"requested k={req.k} exceeds the engine's "
                f"ef={self.config.ef}; raise ef or lower k"
            )
        self._queue.append((req, time.perf_counter()))

    def flush(self) -> list[Result]:
        """Serve the queue: group by k bucket, batch up to ``max_batch``,
        pad to the executor's batch buckets. Results return in submission
        order; each carries its own queue+batch latency."""
        queue, self._queue = self._queue, []
        out: list[Result | None] = [None] * len(queue)
        groups: dict[int, list[int]] = {}
        for i, (req, _) in enumerate(queue):
            groups.setdefault(self.config.bucket_k(req.k), []).append(i)
        for kb, idxs in groups.items():
            for s in range(0, len(idxs), self.max_batch):
                self._run_batch(queue, idxs[s : s + self.max_batch], kb, out)
        return out  # fully populated: every queue index was in one group

    def _run_batch(self, queue, idxs, kb, out):
        t0 = time.perf_counter()
        reqs = [queue[i][0] for i in idxs]
        q = np.stack([r.vector for r in reqs])
        lo = np.array([r.lo for r in reqs])
        hi = np.array([r.hi for r in reqs])
        L, R = self.index.ranks_of(lo, hi)
        res = self.executor.search_ranks(q, L, R, k=kb)
        ids = np.asarray(res.ids)
        dists = np.asarray(res.dists)
        orig = self.index.original_ids(ids)
        t1 = time.perf_counter()
        self._counts["served"] += len(reqs)
        self._counts["batches"] += 1
        self._counts["wall_s"] += t1 - t0
        for row, i in enumerate(idxs):
            req, t_submit = queue[i]
            lat = t1 - t_submit
            self._latencies.append(lat)
            out[i] = Result(orig[row, : req.k], dists[row, : req.k], lat)

    @property
    def stats(self) -> dict:
        ex = self.executor.stats
        lat = np.fromiter(self._latencies, float) if self._latencies else None
        pct = {
            f"latency_p{p}": float(np.percentile(lat, p)) if lat is not None
            else 0.0
            for p in (50, 95, 99)
        }
        return {
            **self._counts,
            "compiles": ex["compiles"],
            "warmup_compiles": ex["warmup_compiles"],
            "cache_hits": ex["cache_hits"],
            "index_bytes": ex["index_bytes"],
            **pct,
        }

    @property
    def qps(self) -> float:
        return self._counts["served"] / max(self._counts["wall_s"], 1e-9)
