"""RFANN serving engine: request batching over a SearchExecutor.

Mirrors a production vector-search frontend: requests (vector + value range
+ k) accumulate in a queue; ``flush`` groups them by k bucket (so one
``k=ef`` straggler stops inflating everyone's top-k), cuts each group into
``max_batch``-sized batches, and hands them to the executor — which pads to
power-of-two batch buckets and serves each (config, batch_bucket, k_bucket)
from its AOT compile cache (``serve/executor.py``). The engine itself is
only queueing + per-request stats:

  * ``Result.latency_s`` is the request's OWN queue+batch time (submit ->
    result), not the whole-batch wall time;
  * ``stats`` exposes latency percentiles (p50/p95/p99 over the last 8192
    requests — a bounded window, so long-running engines stay O(1) memory
    and the numbers track *recent* traffic), executor compile accounting,
    qps, and the served index's real footprint
    (``index_bytes``) — a compact-storage index (``core/storage.py``)
    serves unchanged, decoding at the search edge.

Robustness contract (DESIGN.md §8):

  * ``submit`` validates at the edge — NaN/Inf vectors, wrong
    dimensionality, ``k <= 0``, ``k > ef``, inverted ranges all raise
    ``InvalidRequestError`` (a ``ValueError``) BEFORE queueing, so one bad
    request can never poison a batch;
  * ``flush`` isolates batch failures: an exception while running one
    batch fails only that batch's requests (their slots in the returned
    list hold the exception instance) and the engine stays serviceable;
  * ``close(drain=...)`` never silently drops pending requests — they are
    served (drain) or failed fast with ``ShutdownError``.

The flush-formation logic (:func:`plan_flush`) and the batch runner
(:func:`run_search_batch`, with the fault-injection hooks of
``serve/faults.py``) are module functions shared with the async serving
loop (``serve/loop.py``), so the two front-ends cannot drift.

Engine knobs arrive as ONE ``SearchConfig``; the historical loose kwargs
(``ef=``, ``k_bucket=``, ...) remain as a deprecation shim.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.core import config as config_mod
from repro.core.config import SearchConfig
from repro.core.index import RangeGraphIndex
from repro.serve import faults as faults_mod
from repro.serve.errors import InvalidRequestError, ShutdownError
from repro.serve.executor import SearchExecutor

__all__ = [
    "Request",
    "Result",
    "ServingEngine",
    "bucket_k",
    "plan_flush",
    "run_search_batch",
    "validate_request",
]


def bucket_k(k_req: int, k_bucket: int, ef: int) -> int:
    """Round a requested k up to the next ``k_bucket`` multiple, clamped to
    ef. Thin compatibility wrapper over the one rounding rule,
    ``core/config.py::SearchConfig.bucket_k`` (shared by ``ServingEngine``,
    ``SearchExecutor`` and ``benchmarks/common.make_searcher``)."""
    return SearchConfig(ef=ef, k_bucket=k_bucket).bucket_k(k_req)


@dataclasses.dataclass
class Request:
    vector: np.ndarray
    lo: float
    hi: float
    k: int = 10


@dataclasses.dataclass
class Result:
    ids: np.ndarray         # original object ids
    dists: np.ndarray
    latency_s: float        # this request's queue + batch time


def validate_request(req: Request, *, dim: int, ef: int):
    """Edge validation (shared by the sync engine and the async loop).

    Raises :class:`InvalidRequestError` (a ``ValueError``) so a malformed
    request fails its own submit instead of poisoning a whole batch. Open
    ranges (``lo=-inf`` / ``hi=+inf``) are legal; NaN bounds and inverted
    ranges are not.
    """
    k = int(req.k)
    if k < 1:
        raise InvalidRequestError(f"requested k={req.k} must be >= 1")
    if k > ef:
        raise InvalidRequestError(
            f"requested k={req.k} exceeds the engine's ef={ef}; "
            f"raise ef or lower k"
        )
    v = np.asarray(req.vector)
    if v.ndim != 1 or v.shape[0] != dim:
        raise InvalidRequestError(
            f"query vector shape {v.shape} does not match index dim ({dim},)"
        )
    if not np.isfinite(v).all():
        raise InvalidRequestError("query vector contains NaN/Inf")
    lo, hi = float(req.lo), float(req.hi)
    if np.isnan(lo) or np.isnan(hi):
        raise InvalidRequestError("range bounds must not be NaN")
    if lo > hi:
        raise InvalidRequestError(f"inverted range: lo={lo} > hi={hi}")


def plan_flush(
    reqs, config: SearchConfig, max_batch: int
) -> list[tuple[int, list[int]]]:
    """Form batches from queued requests: group indices by k bucket, cut
    each group into ``max_batch`` chunks. Returns ``[(k_bucket, indices)]``
    covering every input index exactly once — the ONE batch-formation rule
    shared by ``ServingEngine.flush`` and the async loop."""
    groups: dict[int, list[int]] = {}
    for i, req in enumerate(reqs):
        groups.setdefault(config.bucket_k(req.k), []).append(i)
    out = []
    for kb, idxs in groups.items():
        for s in range(0, len(idxs), max_batch):
            out.append((kb, idxs[s : s + max_batch]))
    return out


def run_search_batch(index, executor, reqs, kb, *, config=None, faults=None):
    """Run one formed batch through the executor: value->rank mapping,
    bucketed compile-cached search, original-id mapping. Returns
    ``(orig_ids [B, kb], dists [B, kb])``.

    The fault-injection hooks fire here — ``latency`` right before the
    executor call (an executor latency spike), ``flush_error`` before any
    compute is spent — so both front-ends inject at the same point."""
    if faults is not None:
        faults.maybe_latency()
        faults.maybe_flush_error()
    q = np.stack([np.asarray(r.vector, np.float32) for r in reqs])
    lo = np.array([r.lo for r in reqs])
    hi = np.array([r.hi for r in reqs])
    L, R = index.ranks_of(lo, hi)
    res = executor.search_ranks(q, L, R, k=kb, config=config)
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    return index.original_ids(ids), dists


class ServingEngine:
    def __init__(
        self, index: RangeGraphIndex, *, config: SearchConfig | None = None,
        max_batch: int = 64, executor: SearchExecutor | None = None,
        warmup: bool | None = None, faults=False, ef: int | None = None,
        k_bucket: int | None = None, expand_width: int | None = None,
        dist_impl: str | None = None, edge_impl: str | None = None,
    ):
        """config: the engine's ``SearchConfig`` (the loose kwargs are the
        deprecation shim). executor: share a prebuilt ``SearchExecutor``
        (its config/max_batch win). warmup: AOT-compile the executor's
        grid now — forwarded to a newly built executor (None = the
        ``REPRO_SERVE_WARMUP`` env) and, when True, also applied to a
        prebuilt one. faults: a ``FaultConfig``/``FaultInjector`` to inject
        failures into flushes (chaos tests); the sync engine never picks
        faults up from the env — only the async loop does (see
        ``serve/faults.py``)."""
        config = config_mod.merge(
            config, ef=ef, k_bucket=k_bucket, expand_width=expand_width,
            dist_impl=dist_impl, edge_impl=edge_impl,
            _warn_where="ServingEngine",
        )
        self.index = index
        self._owns_executor = executor is None
        if executor is None:
            executor = SearchExecutor(
                index, config, max_batch=max_batch, warmup=warmup
            )
        elif warmup:
            executor.warmup()
        self.executor = executor
        self.config = self.executor.config
        self.faults = faults_mod.resolve(faults) if faults else None
        self.closed = False
        self._queue: list[tuple[Request, float]] = []
        # bounded window: percentiles track recent traffic at O(1) memory
        self._latencies: deque[float] = deque(maxlen=8192)
        self._counts = {
            "served": 0, "batches": 0, "wall_s": 0.0,
            "failed": 0, "flush_failures": 0,
        }

    # historical attribute surface, now derived from the one config
    @property
    def ef(self) -> int:
        return self.config.ef

    @property
    def k_bucket(self) -> int:
        return self.config.k_bucket

    @property
    def max_batch(self) -> int:
        return self.executor.max_batch

    @property
    def _k_buckets(self) -> set[int]:
        """k buckets this engine has sent down (compat alias)."""
        return self.executor.seen_k_buckets

    def warmup(self, **kw) -> int:
        """AOT-compile the executor's program grid (see
        ``SearchExecutor.warmup``); afterwards any mixed workload inside
        the grid serves with zero additional compiles."""
        return self.executor.warmup(**kw)

    def submit(self, req: Request):
        """Validate at the request boundary — once a request is queued,
        flush must be able to serve (or individually fail) the whole
        queue. Raises ``InvalidRequestError`` on a malformed request and
        ``ShutdownError`` after ``close()``."""
        if self.closed:
            raise ShutdownError("ServingEngine is closed")
        validate_request(req, dim=self.index.dim, ef=self.config.ef)
        self._queue.append((req, time.perf_counter()))

    def flush(self) -> list:
        """Serve the queue: group by k bucket, batch up to ``max_batch``,
        pad to the executor's batch buckets. Returns one entry per queued
        request in submission order — a ``Result``, or (error isolation)
        the exception that failed its batch: a failing flush takes down
        only its own batch's requests and the engine stays serviceable."""
        queue, self._queue = self._queue, []
        out: list = [None] * len(queue)
        for kb, idxs in plan_flush(
            [req for req, _ in queue], self.config, self.max_batch
        ):
            try:
                self._run_batch(queue, idxs, kb, out)
            except Exception as e:  # noqa: BLE001 — isolate to this batch
                self._counts["flush_failures"] += 1
                self._counts["failed"] += len(idxs)
                for i in idxs:
                    out[i] = e
        return out  # fully populated: every queue index was in one batch

    def _run_batch(self, queue, idxs, kb, out):
        t0 = time.perf_counter()
        reqs = [queue[i][0] for i in idxs]
        orig, dists = run_search_batch(
            self.index, self.executor, reqs, kb, faults=self.faults
        )
        t1 = time.perf_counter()
        self._counts["served"] += len(reqs)
        self._counts["batches"] += 1
        self._counts["wall_s"] += t1 - t0
        for row, i in enumerate(idxs):
            req, t_submit = queue[i]
            lat = t1 - t_submit
            self._latencies.append(lat)
            out[i] = Result(orig[row, : req.k], dists[row, : req.k], lat)

    def close(self, *, drain: bool = True) -> list:
        """Stop accepting requests; never silently drop pending ones.

        drain=True serves the pending queue (one last ``flush``) and
        returns its results; drain=False fails each pending request fast —
        the returned list holds one ``ShutdownError`` per dropped request.
        Idempotent; a shared (caller-provided) executor is left open."""
        if self.closed:
            return []
        self.closed = True
        if drain:
            out = self.flush()
        else:
            pending, self._queue = self._queue, []
            out = [
                ShutdownError("ServingEngine closed before serving request")
                for _ in pending
            ]
            self._counts["failed"] += len(pending)
        if self._owns_executor:
            self.executor.close()
        return out

    @property
    def stats(self) -> dict:
        ex = self.executor.stats
        lat = np.fromiter(self._latencies, float) if self._latencies else None
        pct = {
            f"latency_p{p}": float(np.percentile(lat, p)) if lat is not None
            else 0.0
            for p in (50, 95, 99)
        }
        return {
            **self._counts,
            "compiles": ex["compiles"],
            "warmup_compiles": ex["warmup_compiles"],
            "cache_hits": ex["cache_hits"],
            "index_bytes": ex["index_bytes"],
            **pct,
        }

    @property
    def qps(self) -> float:
        return self._counts["served"] / max(self._counts["wall_s"], 1e-9)
