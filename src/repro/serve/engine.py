"""RFANN serving engine: request batching over the iRangeGraph index.

Mirrors a production vector-search frontend: requests (vector + value range
+ k) accumulate in a queue; the engine pads them to fixed batch shapes
(jit-friendly buckets), runs the improvised-graph search, and returns
per-request results with original ids. Stats track qps / recall probes plus
the served index's real footprint (``index_bytes``) — a compact-storage
index (``core/storage.py``) serves unchanged, decoding at the search edge.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core.index import RangeGraphIndex

__all__ = ["Request", "Result", "ServingEngine", "bucket_k"]


def bucket_k(k_req: int, k_bucket: int, ef: int) -> int:
    """Round a requested k up to the next ``k_bucket`` multiple, clamped to
    ef, so mixed-k workloads hit a bounded set of compiled programs instead
    of one retrace per distinct k (k is a static arg of the jitted search).
    The one rounding rule shared by ``ServingEngine`` and the benchmark
    harness (``benchmarks/common.make_searcher``)."""
    return min(ef, k_bucket * max(1, -(-k_req // k_bucket)))


@dataclasses.dataclass
class Request:
    vector: np.ndarray
    lo: float
    hi: float
    k: int = 10


@dataclasses.dataclass
class Result:
    ids: np.ndarray         # original object ids
    dists: np.ndarray
    latency_s: float


class ServingEngine:
    def __init__(
        self, index: RangeGraphIndex, *, ef: int = 64, max_batch: int = 64,
        k_bucket: int = 10, expand_width: int = 4, dist_impl: str = "auto",
        edge_impl: str = "auto",
    ):
        self.index = index
        self.ef = ef
        self.max_batch = max_batch
        self.k_bucket = k_bucket
        self.expand_width = expand_width
        self.dist_impl = dist_impl
        self.edge_impl = edge_impl
        self._queue: list[Request] = []
        # k is a static arg of the jitted search: every distinct value is a
        # retrace. _k_buckets tracks which bucketed k values this engine has
        # sent down; stats["compiles"] is its size (one trace per bucket).
        self._k_buckets: set[int] = set()
        self.stats = {"served": 0, "batches": 0, "wall_s": 0.0, "compiles": 0,
                      "index_bytes": int(index.nbytes)}

    def _bucket_k(self, k_req: int) -> int:
        """``bucket_k`` with this engine's knobs. Clamped to ef: the result
        list only holds ef candidates (top_k(k > ef) would crash), and
        submit() rejects requests asking for more than ef."""
        return bucket_k(k_req, self.k_bucket, self.ef)

    def submit(self, req: Request):
        if req.k > self.ef:
            raise ValueError(
                f"requested k={req.k} exceeds the engine's ef={self.ef}; "
                f"raise ef or lower k"
            )
        self._queue.append(req)

    def flush(self) -> list[Result]:
        out: list[Result] = []
        while self._queue:
            batch = self._queue[: self.max_batch]
            self._queue = self._queue[self.max_batch :]
            out.extend(self._run_batch(batch))
        return out

    def _run_batch(self, batch: Sequence[Request]) -> list[Result]:
        t0 = time.perf_counter()
        B = len(batch)
        pad = self.max_batch - B  # fixed shapes -> one compile per bucket
        q = np.stack([r.vector for r in batch] + [batch[0].vector] * pad)
        lo = np.array([r.lo for r in batch] + [batch[0].lo] * pad)
        hi = np.array([r.hi for r in batch] + [batch[0].hi] * pad)
        k = self._bucket_k(max(r.k for r in batch))
        self._k_buckets.add(k)
        self.stats["compiles"] = len(self._k_buckets)
        L, R = self.index.ranks_of(lo, hi)
        res = self.index.search_ranks(
            q, L, R, k=k, ef=self.ef, expand_width=self.expand_width,
            dist_impl=self.dist_impl, edge_impl=self.edge_impl,
        )
        ids = np.asarray(res.ids)
        dists = np.asarray(res.dists)
        orig = self.index.original_ids(ids)
        dt = time.perf_counter() - t0
        self.stats["served"] += B
        self.stats["batches"] += 1
        self.stats["wall_s"] += dt
        return [
            Result(orig[i, : batch[i].k], dists[i, : batch[i].k], dt)
            for i in range(B)
        ]

    @property
    def qps(self) -> float:
        return self.stats["served"] / max(self.stats["wall_s"], 1e-9)
