"""Typed terminal outcomes of the serving stack.

Every request submitted to the serving layer resolves with EXACTLY ONE of:

  * a ``Result`` (served);
  * :class:`InvalidRequestError` — rejected at the edge before queueing
    (NaN/Inf vector, wrong dimensionality, ``k <= 0``, ``k > ef``,
    inverted range), so one malformed request can never poison a batch;
  * :class:`OverloadedError` — admission control rejected it (bounded
    queue full under the ``"reject"`` backpressure policy);
  * :class:`ShedError` — its deadline expired while still queued and the
    loop shed it *before* it wasted a flush;
  * :class:`DeadlineExceededError` — its per-request timeout fired (in
    flight, or while blocked on backpressure); subclasses ``TimeoutError``
    so generic timeout handling keeps working;
  * :class:`ShutdownError` — the engine closed before it could be served
    (pending requests are failed fast, never silently dropped);
  * any other exception the flush raised — failing only that flush's
    requests (error isolation; the engine stays serviceable).

``InvalidRequestError`` subclasses ``ValueError`` so historical
``except ValueError`` call sites keep catching edge rejections.
:class:`InjectedFaultError` is what ``serve/faults.py`` raises when a
``flush_error`` fault fires — a regular flush failure as far as the
isolation machinery is concerned.
"""
from __future__ import annotations

__all__ = [
    "ServeError",
    "InvalidRequestError",
    "OverloadedError",
    "RejectedError",
    "ShedError",
    "DeadlineExceededError",
    "ShutdownError",
    "InjectedFaultError",
]


class ServeError(Exception):
    """Base of every typed serving outcome."""


class InvalidRequestError(ServeError, ValueError):
    """Request rejected at the serving edge (validation)."""


class OverloadedError(ServeError):
    """Admission control rejected the request: the bounded queue is full
    under the ``"reject"`` backpressure policy."""


RejectedError = OverloadedError  # the issue-tracker name for the same thing


class ShedError(ServeError):
    """The request's deadline expired while it was still queued; the loop
    shed it before it reached the executor (no compute was spent)."""


class DeadlineExceededError(ServeError, TimeoutError):
    """The request's per-request timeout fired after it left the queue
    (in flight, or blocked on backpressure)."""


class ShutdownError(ServeError):
    """The engine closed; the request was failed fast instead of being
    silently dropped."""


class InjectedFaultError(ServeError, RuntimeError):
    """A fault-injection hook fired (``serve/faults.py``)."""

    def __init__(self, kind: str, message: str | None = None):
        super().__init__(message or f"injected fault: {kind}")
        self.kind = kind
