"""Serving stack: executor (jit state) -> engines (sync queue / async loop).

``SearchExecutor`` owns compiled-program state; ``ServingEngine`` is the
synchronous caller-driven queue; ``AsyncServingEngine`` is the
deadline-aware async loop with admission control, backpressure, shedding
and drain (DESIGN.md §7-§8). ``serve/faults.py`` injects failures into
either front-end; ``serve/errors.py`` names every terminal outcome.
"""
from repro.serve.engine import Request, Result, ServingEngine
from repro.serve.errors import (
    DeadlineExceededError,
    InjectedFaultError,
    InvalidRequestError,
    OverloadedError,
    RejectedError,
    ServeError,
    ShedError,
    ShutdownError,
)
from repro.serve.executor import SearchExecutor
from repro.serve.faults import FaultConfig, FaultInjector
from repro.serve.loop import AsyncServingEngine

__all__ = [
    "AsyncServingEngine",
    "DeadlineExceededError",
    "FaultConfig",
    "FaultInjector",
    "InjectedFaultError",
    "InvalidRequestError",
    "OverloadedError",
    "RejectedError",
    "Request",
    "Result",
    "SearchExecutor",
    "ServeError",
    "ServingEngine",
    "ShedError",
    "ShutdownError",
]
