"""SearchExecutor: the one owner of query-pipeline jit state.

``RangeGraphIndex.search_ranks`` is fine for notebooks; serving traffic
needs compiled-program discipline (DESIGN.md §7). The executor provides it:

  * **Compile cache** keyed on ``(SearchConfig, batch_bucket, k_bucket)``:
    each key is AOT-lowered and compiled exactly once
    (``jax.jit(...).lower(...).compile()``) and the executable is called
    directly afterwards, so ``stats["compiles"]`` is an exact program
    count, not a heuristic.
  * **Batch-shape buckets**: an incoming batch pads up to the smallest
    power-of-two bucket (``core/config.py::batch_bucket``), so a 5-request
    flush pays 8-row compute instead of ``max_batch``-row. Padding repeats
    the last real row; the beam engine is row-independent on this path, so
    padded rows can never change a real row's results (the padding-parity
    test pins this bit-exactly).
  * **k buckets**: the requested k rounds up to ``config.bucket_k(k)``
    before hitting the program grid; results slice back to the caller's k.
  * **AOT warmup**: :meth:`warmup` compiles the declared
    ``configs x batch_buckets x k_buckets`` grid up front so the first
    request pays zero compile latency — a warmed executor serves any
    mixed workload inside the grid with zero post-warmup compiles
    (stats-asserted in tests and gated in ``benchmarks/ci_gate.py``).

``serve/engine.py::ServingEngine`` is queueing + per-request stats over
this layer. ``REPRO_SERVE_WARMUP=1`` makes every newly built executor warm
its full grid (the CI executor-warmup leg's hook).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import config as config_mod
from repro.core import knobs as knobs_mod
from repro.core import search as search_mod
from repro.core import storage as storage_mod
from repro.core.config import SearchConfig

__all__ = ["SearchExecutor"]


class SearchExecutor:
    def __init__(
        self,
        index,
        config: SearchConfig | None = None,
        *,
        max_batch: int = 64,
        batch_buckets: tuple[int, ...] | None = None,
        warmup: bool | None = None,
        faults=False,
    ):
        """index: a ``RangeGraphIndex``. config: the executor's default
        ``SearchConfig`` (per-call configs may differ; each is its own
        cache-key axis). batch_buckets: explicit padded batch shapes
        (sorted ascending, max element = max_batch) — the default is the
        power-of-two ladder; pass ``(max_batch,)`` to reproduce the
        historical always-pad-to-max behavior. warmup: AOT-compile the
        full grid now (None = the ``REPRO_SERVE_WARMUP`` env). faults: an
        explicit ``FaultConfig``/``FaultInjector`` injecting latency
        spikes into ``search_ranks`` (``serve/faults.py``); the executor
        never picks faults up from the env — results stay bit-exact, only
        timing moves."""
        self.index = index
        self.config = config or SearchConfig()
        self.max_batch = int(max_batch)
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if batch_buckets is None:
            self.batch_buckets = config_mod.batch_buckets(self.max_batch)
        else:
            self.batch_buckets = tuple(sorted(int(b) for b in batch_buckets))
            if not self.batch_buckets or \
                    self.batch_buckets[-1] != self.max_batch:
                raise ValueError(
                    f"batch_buckets {batch_buckets} must be non-empty and "
                    f"end at max_batch={self.max_batch}"
                )
        # the hot tables, uploaded once per leaf (possibly codec structs —
        # decode happens inside the jitted search / kernels, at the edge;
        # NamedTuple codecs are pytrees, so their structure sits in the
        # trace signature and the zero-post-warmup-compile guarantee holds)
        self._vec = storage_mod.as_device(index.vectors)
        self._nbrs = storage_mod.as_device(index.neighbors)
        self._rerank = storage_mod.as_device(getattr(index, "rerank", None))
        if faults:
            from repro.serve import faults as faults_mod

            self.faults = faults_mod.resolve(faults)
        else:
            self.faults = None
        self.closed = False
        self._cache: dict = {}   # (config, batch_bucket, k_bucket) -> exe
        self.seen_k_buckets: set[int] = set()
        self.stats = {
            "compiles": 0, "warmup_compiles": 0, "cache_hits": 0,
            "batches": 0, "queries": 0, "index_bytes": int(index.nbytes),
        }
        if warmup is None:
            warmup = knobs_mod.get_bool("REPRO_SERVE_WARMUP")
        if warmup:
            self.warmup()

    # -- bucket math ---------------------------------------------------------
    def batch_bucket(self, b: int) -> int:
        """The padded shape a ``b``-row batch runs at (the one
        ``config.pick_bucket`` rule over this executor's ladder)."""
        return config_mod.pick_bucket(b, self.batch_buckets)

    def program_grid(self, configs=None) -> int:
        """Upper bound on compiled programs for ``configs`` (default: the
        executor's own): ``len(configs) * len(batch_buckets) *
        len(k_buckets)`` — the compile-count gate's denominator."""
        configs = tuple(configs) if configs is not None else (self.config,)
        return sum(
            len(self.batch_buckets) * len(cfg.k_buckets()) for cfg in configs
        )

    # -- compilation ---------------------------------------------------------
    def _compile(self, cfg: SearchConfig, bb: int, kb: int, *,
                 warmup: bool = False):
        key = (cfg, bb, kb)
        exe = self._cache.get(key)
        if exe is not None:
            return exe
        d = self.index.dim
        q = jnp.zeros((bb, d), jnp.float32)
        z = jnp.zeros((bb,), jnp.int32)
        lowered = search_mod._search_improvised_jit.lower(
            self._vec, self._nbrs, q, z, z, self._rerank,
            logn=self.index.logn, m_out=self.index.m, k=kb, config=cfg,
        )
        exe = lowered.compile()
        self._cache[key] = exe
        self.stats["compiles"] += 1
        if warmup:
            self.stats["warmup_compiles"] += 1
        return exe

    def warmup(self, batch_buckets=None, k_buckets=None, configs=None) -> int:
        """AOT-compile the declared (config, batch_bucket, k_bucket) grid.

        Defaults to the executor's full grid — every batch bucket times
        every ``config.k_buckets()`` value of the default config. Returns
        the number of programs compiled by this call (already-cached keys
        cost nothing)."""
        configs = tuple(configs) if configs is not None else (self.config,)
        bbs = tuple(batch_buckets) if batch_buckets is not None \
            else self.batch_buckets
        before = self.stats["compiles"]
        for cfg in configs:
            kbs = tuple(k_buckets) if k_buckets is not None \
                else cfg.k_buckets()
            kbs = sorted({cfg.bucket_k(kb) for kb in kbs})
            for bb in bbs:
                bb = self.batch_bucket(int(bb))
                for kb in kbs:
                    self._compile(cfg, bb, kb, warmup=True)
        return self.stats["compiles"] - before

    # -- execution -----------------------------------------------------------
    def search_ranks(self, queries, L, R, *, k: int,
                     config: SearchConfig | None = None):
        """Bucketed, compile-cached improvised search.

        queries f32[B, d], L/R int32[B] rank ranges, any B >= 1 (batches
        beyond ``max_batch`` split). Returns a ``SearchResult`` sliced back
        to ``[B, k]`` — bit-identical to the direct
        ``search_improvised`` call at the same config (padding and k
        rounding cannot leak into real rows)."""
        if self.closed:
            from repro.serve.errors import ShutdownError

            raise ShutdownError("SearchExecutor is closed")
        if self.faults is not None:
            self.faults.maybe_latency()
        cfg = config or self.config
        if k > cfg.ef:
            raise ValueError(
                f"requested k={k} exceeds the config's ef={cfg.ef}; "
                f"raise ef or lower k"
            )
        kb = cfg.bucket_k(k)
        q = np.asarray(queries, np.float32)
        L = np.asarray(L, np.int32).reshape(-1)
        R = np.asarray(R, np.int32).reshape(-1)
        B = q.shape[0]
        if B < 1:
            raise ValueError("empty query batch")
        parts = [
            self._run(q[s : s + self.max_batch], L[s : s + self.max_batch],
                      R[s : s + self.max_batch], kb, cfg)
            for s in range(0, B, self.max_batch)
        ]
        res = parts[0] if len(parts) == 1 else search_mod.SearchResult(
            *(jnp.concatenate(xs, axis=0) for xs in zip(*parts))
        )
        self.seen_k_buckets.add(kb)
        if kb == k:
            return res
        return res._replace(ids=res.ids[:, :k], dists=res.dists[:, :k])

    def close(self):
        """Release the compile cache and refuse further work
        (``search_ranks`` raises ``ShutdownError``). Idempotent; stats
        survive for post-mortem accounting."""
        self.closed = True
        self._cache.clear()

    def _run(self, q, L, R, kb, cfg):
        B = q.shape[0]
        bb = self.batch_bucket(B)
        if bb != B:
            pad = bb - B
            q = np.concatenate([q, np.repeat(q[-1:], pad, axis=0)])
            L = np.concatenate([L, np.repeat(L[-1:], pad)])
            R = np.concatenate([R, np.repeat(R[-1:], pad)])
        key = (cfg, bb, kb)
        exe = self._cache.get(key)
        if exe is not None:
            self.stats["cache_hits"] += 1
        else:
            exe = self._compile(cfg, bb, kb)
        res = exe(self._vec, self._nbrs, jnp.asarray(q), jnp.asarray(L),
                  jnp.asarray(R), self._rerank)
        self.stats["batches"] += 1
        self.stats["queries"] += B
        if bb == B:
            return res
        return search_mod.SearchResult(*(x[:B] for x in res))
