"""Fault injection for the serving stack (the chaos harness).

Three fault kinds, each a hook the serving layers call at the exact point
the real failure would occur:

  * ``latency``      — ``maybe_latency()`` sleeps ``latency_s`` with
    probability ``latency_rate`` right before the executor call, modeling
    an executor latency spike (GC pause, host contention, a straggler
    device). With the async loop's compute running in a worker thread, the
    event loop keeps admitting, shedding and timing out requests while the
    spike burns — which is the property the chaos tests pin.
  * ``flush_error``  — ``maybe_flush_error()`` raises
    :class:`~repro.serve.errors.InjectedFaultError` with probability
    ``flush_error_rate``, modeling a poisoned batch / transient executor
    failure. Error isolation must fail only that flush's requests.
  * ``queue_full``   — ``queue_full()`` returns True with probability
    ``queue_full_rate``, forcing the admission-control full-queue path
    (a burst arriving faster than the queue drains).

Injection is DETERMINISTIC given ``FaultConfig.seed`` (one
``random.Random`` stream, lock-protected — hooks fire from both the event
loop and the flush worker thread), and every fired fault is counted in
``FaultInjector.counts`` so tests and the SLO benchmark can report what
actually happened.

Env-driven activation (the CI chaos leg): ``REPRO_FAULTS=latency,
flush_error`` enables those kinds for every component that resolves its
``faults`` parameter through :func:`resolve` with the default ``None`` —
the async serving loop does; the synchronous ``ServingEngine`` and
``SearchExecutor`` only inject when handed an injector explicitly, so
deterministic unit tests stay deterministic under the chaos leg. Knobs:
``REPRO_FAULT_LATENCY_S``, ``REPRO_FAULT_LATENCY_RATE``,
``REPRO_FAULT_FLUSH_ERROR_RATE``, ``REPRO_FAULT_QUEUE_FULL_RATE``,
``REPRO_FAULT_SEED``.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time

from repro.core import knobs as knobs_mod
from repro.serve.errors import InjectedFaultError

__all__ = ["FAULT_KINDS", "FaultConfig", "FaultInjector", "resolve"]

FAULT_KINDS = ("latency", "flush_error", "queue_full")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Which faults fire, how often, and how hard (frozen + hashable)."""

    kinds: tuple[str, ...] = ()
    latency_s: float = 0.02
    latency_rate: float = 0.25
    flush_error_rate: float = 0.25
    queue_full_rate: float = 0.25
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "kinds", tuple(self.kinds))
        for k in self.kinds:
            if k not in FAULT_KINDS:
                raise ValueError(
                    f"unknown fault kind {k!r}; valid kinds: {FAULT_KINDS}"
                )
        for name in ("latency_rate", "flush_error_rate", "queue_full_rate"):
            v = getattr(self, name)
            if not 0.0 <= float(v) <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if float(self.latency_s) < 0.0:
            raise ValueError(f"latency_s must be >= 0, got {self.latency_s}")

    @classmethod
    def from_env(cls, env=None) -> "FaultConfig | None":
        """``REPRO_FAULTS`` comma list -> a config, or None when unset.

        All knobs resolve through the typed registry (``core/knobs.py``);
        ``env`` overrides the mapping they read from (tests).
        """
        kinds = knobs_mod.get_list("REPRO_FAULTS", env)
        if not kinds:
            return None
        return cls(
            kinds=kinds,
            latency_s=knobs_mod.get_float("REPRO_FAULT_LATENCY_S", env),
            latency_rate=knobs_mod.get_float("REPRO_FAULT_LATENCY_RATE", env),
            flush_error_rate=knobs_mod.get_float(
                "REPRO_FAULT_FLUSH_ERROR_RATE", env),
            queue_full_rate=knobs_mod.get_float(
                "REPRO_FAULT_QUEUE_FULL_RATE", env),
            seed=knobs_mod.get_int("REPRO_FAULT_SEED", env),
        )


class FaultInjector:
    """Stateful, deterministic, thread-safe fault source.

    ``armed`` can be flipped off (e.g. a chaos test's clean final probe)
    without rebuilding the injector; counts keep accumulating while armed.
    """

    def __init__(self, config: FaultConfig):
        self.config = config
        self.armed = True
        self.counts = {k: 0 for k in FAULT_KINDS}
        self._rng = random.Random(config.seed)
        self._lock = threading.Lock()

    def _fire(self, kind: str, rate: float) -> bool:
        if not self.armed or kind not in self.config.kinds:
            return False
        with self._lock:
            hit = self._rng.random() < rate
            if hit:
                self.counts[kind] += 1
        return hit

    def maybe_latency(self):
        """Executor latency spike: sleep in the calling (worker) thread."""
        if self._fire("latency", self.config.latency_rate):
            time.sleep(self.config.latency_s)

    def maybe_flush_error(self):
        """Poisoned flush: raise before the executor sees the batch."""
        if self._fire("flush_error", self.config.flush_error_rate):
            raise InjectedFaultError(
                "flush_error", "injected flush failure (serve/faults.py)"
            )

    def queue_full(self) -> bool:
        """Admission burst: report the queue as full this one check."""
        return self._fire("queue_full", self.config.queue_full_rate)


def resolve(faults) -> FaultInjector | None:
    """The one ``faults=`` parameter convention:

    ``None``  -> the ``REPRO_FAULTS`` env (an injector, or no injection);
    ``False`` -> injection disabled regardless of env (deterministic tests);
    a ``FaultConfig`` -> a fresh injector for it;
    a ``FaultInjector`` -> used as-is (shared counts).
    """
    if faults is None:
        cfg = FaultConfig.from_env()
        return FaultInjector(cfg) if cfg is not None else None
    if faults is False:
        return None
    if isinstance(faults, FaultConfig):
        return FaultInjector(faults)
    if isinstance(faults, FaultInjector):
        return faults
    raise TypeError(
        f"faults must be None, False, FaultConfig or FaultInjector; "
        f"got {type(faults).__name__}"
    )
