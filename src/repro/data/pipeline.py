"""Deterministic synthetic data pipelines.

Two producers:

  * ``TokenPipeline`` — an infinite, seeded LM token stream with Zipfian
    unigram structure + repeated n-grams so tiny models have signal to
    learn (loss actually decreases in the examples/tests). Batches come out
    already ``device_put`` against the mesh's batch sharding when one is
    supplied (the host->device path a real loader would use).

  * ``vector_dataset`` — clustered Gaussian-mixture vectors + attributes
    with controllable correlation, shaped like the paper's five datasets
    (dims 128..2048). Used by every RFANN benchmark; seeds make each
    benchmark table reproducible.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

__all__ = ["TokenPipeline", "vector_dataset", "PAPER_DATASETS"]


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    encdec_dim: int = 0       # >0: also emit frame embeddings (seamless stub)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        # Zipf-ish unigram distribution + a bank of n-grams to memorize
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._ngrams = self._rng.integers(
            0, self.vocab, size=(64, 8)
        ).astype(np.int32)

    def next_batch(self, shardings=None):
        toks = self._rng.choice(
            self.vocab, size=(self.batch, self.seq + 1), p=self._probs
        ).astype(np.int32)
        # splice in memorizable n-grams
        for b in range(self.batch):
            for _ in range(max(1, self.seq // 64)):
                g = self._ngrams[self._rng.integers(0, len(self._ngrams))]
                pos = self._rng.integers(0, self.seq - len(g))
                toks[b, pos : pos + len(g)] = g
        batch = {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
        }
        if self.encdec_dim:
            batch["frames"] = self._rng.standard_normal(
                (self.batch, self.seq, self.encdec_dim)
            ).astype(np.float32)
        if shardings is not None:
            batch = {
                k: jax.device_put(v, shardings[k]) for k, v in batch.items()
            }
        return batch


# dataset name -> (dim, attr_kind) mirroring the paper's Table 1
PAPER_DATASETS = {
    "wit-like": (2048, "uniform"),        # image, image size attr
    "tripclick-like": (768, "clustered"),  # text, publication date
    "redcaps-like": (512, "clustered"),    # multimodal, timestamp
    "ytrgb-like": (1024, "zipf"),          # video, # likes
    "ytaudio-like": (128, "uniform"),      # audio, publish time
}


def vector_dataset(
    n: int,
    dim: int,
    *,
    seed: int = 0,
    n_clusters: int = 64,
    attr_kind: str = "uniform",
    attr_vector_corr: float = 0.0,
    n_attrs: int = 1,
    queries: int = 0,
):
    """Returns (vectors[n, dim], attrs[n, n_attrs], query_vectors)."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_clusters, dim)).astype(np.float32) * 2.0
    assign = rng.integers(0, n_clusters, n)
    vectors = centers[assign] + rng.standard_normal((n, dim)).astype(
        np.float32
    )
    attrs = np.empty((n, n_attrs))
    for a in range(n_attrs):
        if attr_kind == "uniform":
            base = rng.uniform(0, 1e6, n)
        elif attr_kind == "clustered":
            base = (assign * 1000 + rng.uniform(0, 1000, n))
        elif attr_kind == "zipf":
            base = rng.zipf(1.5, n).astype(np.float64)
        else:
            raise ValueError(attr_kind)
        if attr_vector_corr > 0:
            # attribute correlates with the first principal direction
            proj = vectors @ centers[0] / np.linalg.norm(centers[0])
            base = (1 - attr_vector_corr) * base + attr_vector_corr * (
                (proj - proj.min()) / (np.ptp(proj) + 1e-9) * np.ptp(base)
            )
        attrs[:, a] = base
    qv = None
    if queries:
        qa = rng.integers(0, n_clusters, queries)
        qv = centers[qa] + rng.standard_normal((queries, dim)).astype(
            np.float32
        )
    return vectors, attrs, qv
