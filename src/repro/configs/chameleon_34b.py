"""chameleon-34b [vlm]: 48L d8192 64H (kv=8) d_ff=22016, vocab 65536.
Early fusion: VQ image tokens are ordinary vocab entries, so the frontend
stub is the identity on token ids. [arXiv:2405.09818]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,  # chameleon uses qk-norm for stability
    mlp_kind="swiglu",
    tie_embeddings=False,
)
