"""seamless-m4t-large-v2 [audio]: enc-dec, 24L (24 enc + 24 dec) d1024 16H
(kv=16) d_ff=8192, vocab 256206. Modality frontend is a STUB: the encoder
consumes precomputed frame embeddings. [arXiv:2308.11596]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    mlp_kind="gelu",
    input_kind="frames",
)
