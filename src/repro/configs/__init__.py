"""Architecture registry: ``--arch <id>`` resolves here."""
from repro.configs import (
    chameleon_34b,
    gemma2_9b,
    granite_20b,
    granite_moe_1b_a400m,
    phi3_mini_3_8b,
    phi35_moe_42b_a6_6b,
    qwen3_0_6b,
    seamless_m4t_large_v2,
    xlstm_125m,
    zamba2_1_2b,
)
from repro.configs.base import SHAPES, ArchConfig, ShapeSpec

ARCHS = {
    "granite-moe-1b-a400m": granite_moe_1b_a400m.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b_a6_6b.CONFIG,
    "granite-20b": granite_20b.CONFIG,
    "phi3-mini-3.8b": phi3_mini_3_8b.CONFIG,
    "qwen3-0.6b": qwen3_0_6b.CONFIG,
    "gemma2-9b": gemma2_9b.CONFIG,
    "xlstm-125m": xlstm_125m.CONFIG,
    "seamless-m4t-large-v2": seamless_m4t_large_v2.CONFIG,
    "chameleon-34b": chameleon_34b.CONFIG,
    "zamba2-1.2b": zamba2_1_2b.CONFIG,
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choices: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeSpec", "get_arch"]
