"""zamba2-1.2b [hybrid]: 38L d2048, Mamba2 blocks (state=64) + one SHARED
attention block (32H, MHA) applied every 6 layers. [arXiv:2411.15242]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    layer_pattern="hybrid_shared_attn",
    shared_attn_period=6,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    mlp_kind="swiglu",
    subquadratic=True,
)
