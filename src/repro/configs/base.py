"""Architecture config schema + the assigned input-shape sets.

Every assigned architecture is a frozen ``ArchConfig``; reduced smoke
variants are derived with ``cfg.reduced()``. Input shapes follow the
assignment: ``train_4k``/``prefill_32k`` lower ``train_step``/``prefill``;
``decode_32k``/``long_500k`` lower ``serve_step`` (one token against a KV/
state cache of ``seq_len``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

__all__ = ["ArchConfig", "SHAPES", "ShapeSpec"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Mapping[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    expert_top_k: int = 0
    moe_capacity_factor: float = 1.25
    # --- attention details ---
    qk_norm: bool = False
    attn_softcap: float | None = None
    logit_softcap: float | None = None
    local_window: int | None = None      # sliding window size
    layer_pattern: str = "global"        # global | local_global | ssm |
                                         # xlstm | hybrid_shared_attn
    shared_attn_period: int = 0          # zamba2: shared block every N
    sandwich_norm: bool = False          # gemma2 pre+post norms
    mlp_kind: str = "swiglu"             # swiglu | gelu
    rope_theta: float = 10000.0
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # --- xLSTM ---
    slstm_layers: tuple = ()             # indices using sLSTM blocks
    # --- enc-dec ---
    enc_layers: int = 0                  # seamless: encoder depth
    # --- numerics / system ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    tie_embeddings: bool = True
    remat: str = "full"                  # none | full | dots
    attention_impl: str = "auto"         # auto | xla | pallas
    scan_layers: bool = True
    scan_unroll: bool = False            # dry-run cost pass: unroll scans so
                                         # HLO cost analysis counts every
                                         # iteration (see launch/dryrun.py)
    # --- modality stub ---
    input_kind: str = "tokens"           # tokens | frames (audio stub)
    # --- scope notes ---
    subquadratic: bool = False           # eligible for long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return int(math.ceil(self.vocab / 2048) * 2048)

    def reduced(self, **over) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dict(
            n_layers=(4 if self.layer_pattern == "xlstm" else
                      min(self.n_layers, 2 if not self.shared_attn_period
                          else self.shared_attn_period + 1)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16,
            n_experts=min(self.n_experts, 4),
            expert_top_k=min(self.expert_top_k, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            local_window=16 if self.local_window else None,
            enc_layers=min(self.enc_layers, 2),
            slstm_layers=((3,) if self.layer_pattern == "xlstm"
                          else ()),
            param_dtype="float32",
            compute_dtype="float32",
            remat="none",
            scan_layers=self.scan_layers,
        )
        kw.update(over)
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # accounting (roofline §Perf): parameter counts
    # ------------------------------------------------------------------
    def param_count(self) -> int:
        from repro.models import api  # local import to avoid cycles

        return api.count_params(self)

    def active_param_count(self) -> int:
        from repro.models import api

        return api.count_params(self, active_only=True)
