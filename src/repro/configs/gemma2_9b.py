"""gemma2-9b [dense]: 42L d3584 16H (kv=8) d_ff=14336, vocab 256000.
local(4096)/global alternating, attn+logit softcaps, sandwich norms.
[arXiv:2408.00118]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab=256000,
    head_dim=256,
    layer_pattern="local_global",
    local_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    sandwich_norm=True,
    mlp_kind="swiglu",
)
