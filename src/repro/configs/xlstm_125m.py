"""xlstm-125m [ssm]: 12L d768 4H, vocab 50304; sLSTM + mLSTM blocks
(sLSTM at 1/4 positions), no separate FFN (d_ff=0). [arXiv:2405.04517]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    layer_pattern="xlstm",
    slstm_layers=(3, 7, 11),
    scan_layers=False,
    subquadratic=True,
)
