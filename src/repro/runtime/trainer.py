"""Fault-tolerant training runtime.

Wraps the pure train_step with the operational machinery a 1000+ node job
needs:

  * auto-restore: on start, resume from the newest checkpoint if present;
  * periodic checkpointing (atomic, retention-K) + final checkpoint;
  * step watchdog: per-step wall-time EWMA; a step slower than
    ``straggler_factor`` x EWMA is logged as a straggler event and counted —
    on real fleets this signal feeds the rescheduler; here it feeds metrics
    and (optionally) a hard deadline abort;
  * crash-retry loop: a failing step triggers restore-from-checkpoint and
    replay, up to ``max_restarts`` (covers transient device loss; determinism
    comes from the seeded data pipeline being re-wound to the restored step);
  * preemption hook: SIGTERM sets a flag; the loop checkpoints and exits
    cleanly at the next step boundary.
"""
from __future__ import annotations

import dataclasses
import signal
import time

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt

__all__ = ["TrainLoopConfig", "run_train_loop"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    straggler_deadline_s: float | None = None
    max_restarts: int = 2
    log_every: int = 10


class _Preempt:
    def __init__(self):
        self.flag = False
        try:
            signal.signal(signal.SIGTERM, self._h)
        except ValueError:
            pass  # not on main thread (tests)

    def _h(self, *_):
        self.flag = True


def run_train_loop(
    step_fn,              # (state, batch) -> (state, metrics)
    init_state,           # pytree (params, opt_state, ...)
    next_batch,           # (step:int) -> batch  (deterministic per step!)
    cfg: TrainLoopConfig,
    *,
    log=print,
):
    """Returns (final_state, history dict)."""
    preempt = _Preempt()
    state = init_state
    start_step = 0
    restored = ckpt.latest_step(cfg.ckpt_dir)
    if restored is not None:
        state, start_step, _ = ckpt.restore(cfg.ckpt_dir, init_state)
        log(f"[trainer] restored checkpoint at step {start_step}")

    history = {"loss": [], "straggler_events": 0, "restarts": 0}
    ewma = None
    step = start_step
    restarts = 0
    while step < cfg.total_steps:
        batch = next_batch(step)
        t0 = time.perf_counter()
        try:
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics)
        except Exception as e:  # noqa: BLE001 — transient failure path
            restarts += 1
            history["restarts"] = restarts
            log(f"[trainer] step {step} failed ({type(e).__name__}: {e}); "
                f"restart {restarts}/{cfg.max_restarts}")
            if restarts > cfg.max_restarts:
                raise
            last = ckpt.latest_step(cfg.ckpt_dir)
            if last is not None:
                state, step, _ = ckpt.restore(cfg.ckpt_dir, init_state)
                log(f"[trainer] rolled back to step {step}")
            continue
        dt = time.perf_counter() - t0

        # straggler watchdog
        if ewma is None:
            ewma = dt
        else:
            if dt > cfg.straggler_factor * ewma:
                history["straggler_events"] += 1
                log(f"[trainer] straggler: step {step} took {dt:.3f}s "
                    f"(ewma {ewma:.3f}s)")
            if (cfg.straggler_deadline_s is not None
                    and dt > cfg.straggler_deadline_s):
                raise TimeoutError(
                    f"step {step} exceeded deadline {cfg.straggler_deadline_s}s"
                )
            ewma = 0.9 * ewma + 0.1 * dt

        loss = float(np.asarray(metrics.get("loss", np.nan)))
        history["loss"].append(loss)
        if step % cfg.log_every == 0:
            log(f"[trainer] step {step} loss {loss:.4f} "
                f"({dt*1e3:.0f} ms/step)")
        step += 1

        if step % cfg.ckpt_every == 0 or preempt.flag:
            ckpt.save(cfg.ckpt_dir, step, state, keep=cfg.keep)
            if preempt.flag:
                log("[trainer] preemption: checkpointed and exiting")
                return state, history

    ckpt.save(cfg.ckpt_dir, step, state, keep=cfg.keep)
    return state, history
