"""R2 dispatch-contract: every op registered in ``kernels/ops.py`` keeps
the full contract that makes the backend matrix trustworthy:

* ``_check_impl`` validation — unknown backend tokens raise instead of
  silently running the Pallas interpreter on CPU;
* a ``ref.py`` contract — the op (directly, or through a one-level
  module helper like ``_prune_xla``) references a ``_ref.<fn>`` that
  actually exists in ``kernels/ref.py``;
* an oracle impl token — the allowed-token set contains at least one
  non-``pallas`` backend, so CI can always diff the kernel against a
  reference implementation;
* a registered override knob — the op consults ``REPRO_<KIND>_IMPL``
  (via ``default_impl("<kind>")`` or directly) and that knob is in the
  ``core/knobs.py`` registry;
* a test module naming the op under ``tests/``.

The op roster is ``ops.__all__`` minus ``default_impl`` — exporting an op
without the contract is exactly the drift this rule exists to catch.
"""
from __future__ import annotations

import ast
import os
import re

from repro.lint import astutil
from repro.lint.rules.r1_knob_registry import load_knobs_module

RULE_ID = "R2"
TITLE = "dispatch-contract"
SUMMARY = "every kernels/ops.py op has ref contract, oracle token, _check_impl, knob, test"

_KNOB_RE = re.compile(r"\bREPRO_[A-Z][A-Z0-9_]*_IMPL\b")
_NON_OPS = {"default_impl"}


def _ref_aliases(tree: ast.Module) -> set[str]:
    out = set()
    for node in tree.body:
        if isinstance(node, ast.ImportFrom):
            for a in node.names:
                if a.name == "ref" or a.name.endswith(".ref"):
                    out.add(a.asname or a.name.split(".")[-1])
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name.endswith(".ref") and a.asname:
                    out.add(a.asname)
    return out


def _module_helpers(tree: ast.Module) -> dict[str, ast.AST]:
    """Top-level name -> defining node, for the one-level closure (prune
    reaches _ref.prune through the module-level ``_prune_xla`` assign)."""
    out: dict[str, ast.AST] = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = node
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out[t.id] = node
    return out


def _ref_attrs(node: ast.AST, aliases: set[str]) -> set[str]:
    return {
        n.attr for n in ast.walk(node)
        if isinstance(n, ast.Attribute)
        and isinstance(n.value, ast.Name)
        and n.value.id in aliases
    }


def _allowed_tokens(call: ast.Call, helpers: dict[str, ast.AST]):
    """The ``allowed`` argument of a ``_check_impl`` call as a set of
    string tokens, or None when it isn't statically readable."""
    if len(call.args) < 3:
        return None
    node = call.args[2]
    if isinstance(node, ast.Name) and node.id in helpers:
        helper = helpers[node.id]
        if isinstance(helper, ast.Assign):
            node = helper.value
    if isinstance(node, (ast.Set, ast.Tuple, ast.List)):
        vals = set()
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, str)):
                return None
            vals.add(e.value)
        return vals
    return None


def check(ctx):
    tree = ctx.tree(ctx.ops_path)
    funcs = astutil.top_level_functions(tree)
    helpers = _module_helpers(tree)
    aliases = _ref_aliases(tree)
    ref_funcs = set(astutil.top_level_functions(ctx.tree(ctx.ref_path)))

    try:
        exported = astutil.eval_module_constant(
            tree, "__all__", ctx.ops_path
        )
    except astutil.EvalError:
        yield ctx.finding(
            RULE_ID, ctx.ops_path, 0,
            "ops.py has no statically readable __all__ — the op roster "
            "R2 checks is __all__ minus default_impl",
            "no-all",
        )
        return

    test_texts = {
        p: ctx.source(p) for p in ctx.py_files(ctx.tests_dir)
    }
    registered = {
        k.name for k in load_knobs_module(ctx.knobs_path).REGISTRY
    }

    for op in exported:
        if op in _NON_OPS:
            continue
        fn = funcs.get(op)
        if fn is None:
            yield ctx.finding(
                RULE_ID, ctx.ops_path, 0,
                f"__all__ exports {op!r} but ops.py has no top-level "
                f"function of that name",
                f"{op}:missing-def",
            )
            continue

        # reachable nodes: the op body plus one level of module helpers
        reach = [fn]
        reach += [
            helpers[n] for n in astutil.names_in(fn)
            if n in helpers and helpers[n] is not fn
        ]

        # _check_impl validation + oracle token
        checks = [
            n for node in reach for n in ast.walk(node)
            if isinstance(n, ast.Call)
            and astutil.dotted(n.func) == "_check_impl"
        ]
        if not checks:
            yield ctx.finding(
                RULE_ID, ctx.ops_path, fn,
                f"{op} never calls _check_impl: unknown backend tokens "
                f"(e.g. a typo'd REPRO_IMPL) would fall through silently",
                f"{op}:no-check-impl",
            )
        else:
            tokens = _allowed_tokens(checks[0], helpers)
            if tokens is not None and not (tokens - {"pallas"}):
                yield ctx.finding(
                    RULE_ID, ctx.ops_path, checks[0],
                    f"{op} allows only the pallas backend: every op needs "
                    f"a non-pallas oracle impl token so CI can diff the "
                    f"kernel against a reference",
                    f"{op}:no-oracle",
                )

        # ref.py contract
        attrs = set()
        for node in reach:
            attrs |= _ref_attrs(node, aliases)
        if not attrs:
            yield ctx.finding(
                RULE_ID, ctx.ops_path, fn,
                f"{op} never references a kernels/ref.py contract "
                f"(directly or via a module-level helper): the oracle "
                f"branch is the op's executable spec",
                f"{op}:no-ref-contract",
            )
        for attr in sorted(attrs):
            if attr not in ref_funcs:
                yield ctx.finding(
                    RULE_ID, ctx.ops_path, fn,
                    f"{op} references _ref.{attr} but kernels/ref.py "
                    f"defines no function {attr!r}",
                    f"{op}:ref-missing:{attr}",
                )

        # registered override knob
        knob_names = set()
        for node in reach:
            for n in ast.walk(node):
                if (
                    isinstance(n, ast.Call)
                    and astutil.dotted(n.func) == "default_impl"
                    and n.args
                    and isinstance(n.args[0], ast.Constant)
                    and isinstance(n.args[0].value, str)
                ):
                    knob_names.add(f"REPRO_{n.args[0].value.upper()}_IMPL")
            for text, _line in astutil.str_constants_in(node):
                knob_names |= set(_KNOB_RE.findall(text))
        if not knob_names:
            yield ctx.finding(
                RULE_ID, ctx.ops_path, fn,
                f"{op} has no env override knob: dispatch must consult "
                f"REPRO_<KIND>_IMPL (via default_impl('<kind>')) so the "
                f"CI backend matrix can force its backend",
                f"{op}:no-knob",
            )
        for name in sorted(knob_names):
            if name not in registered:
                yield ctx.finding(
                    RULE_ID, ctx.ops_path, fn,
                    f"{op} consults {name} which is not in the "
                    f"core/knobs.py registry",
                    f"{op}:unregistered-knob:{name}",
                )

        # a test module naming the op
        pat = re.compile(rf"\b{re.escape(op)}\b")
        if not any(pat.search(t) for t in test_texts.values()):
            yield ctx.finding(
                RULE_ID, ctx.ops_path, fn,
                f"no module under tests/ names {op}: every dispatched op "
                f"needs at least one test exercising it by name",
                f"{op}:no-test",
            )
