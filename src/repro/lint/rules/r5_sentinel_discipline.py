"""R5 sentinel-discipline: storage and kernel code uses exactly one
invalid-id sentinel — ``-1`` — across every neighbor-table dtype
(int16/int32/split-offset), every kernel and every backend. Two things
violate that:

* ``iinfo(...).max`` — a dtype-max sentinel comparison. ``32767`` means
  "invalid" in an int16 table but is a perfectly valid id once the table
  widens; the auto-narrowing storage codecs make this a real, silent
  corruption path. (``iinfo(...).min`` is *not* flagged: the kernels'
  argmin priority masking legitimately uses the int32 minimum, and it is
  not a stored id.) Capacity arithmetic that genuinely needs the dtype
  ceiling carries an inline ``# replint: allow[R5]`` with its reason.
* a magic integer equal to a dtype extreme (``32767``, ``65535``,
  ``2147483647``, ``4294967295``) used in a comparison or in a
  fill/where-style call — the same sentinel spelled as a literal.
"""
from __future__ import annotations

import ast

from repro.lint import astutil

RULE_ID = "R5"
TITLE = "sentinel-discipline"
SUMMARY = "only -1 sentinels in storage/kernel code; no dtype-max comparisons"

_MAGIC = {32767, 65535, 2147483647, 4294967295}
_FILL_CALLS = {"where", "full", "full_like", "select"}


def _is_iinfo_max(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Attribute)
        and node.attr == "max"
        and isinstance(node.value, ast.Call)
        and astutil.dotted(node.value.func).split(".")[-1] == "iinfo"
    )


def _magic_value(node: ast.AST):
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value if node.value in _MAGIC else None
    return None


def check(ctx):
    for path in ctx.sentinel_paths:
        try:
            tree = ctx.tree(path)
        except FileNotFoundError:
            continue
        counts: dict[str, int] = {}

        def slug(base: str) -> str:
            counts[base] = counts.get(base, 0) + 1
            n = counts[base]
            return base if n == 1 else f"{base}:{n}"

        for node in ast.walk(tree):
            if _is_iinfo_max(node):
                yield ctx.finding(
                    RULE_ID, path, node,
                    "iinfo(...).max used as/near a sentinel: the only "
                    "invalid-id sentinel is -1 (dtype-max is a valid id "
                    "once the neighbor table widens). Capacity checks "
                    "that truly need the dtype ceiling take an inline "
                    "`# replint: allow[R5] <reason>`",
                    slug("iinfo-max"),
                )
            elif isinstance(node, ast.Compare):
                for operand in [node.left, *node.comparators]:
                    v = _magic_value(operand)
                    if v is not None:
                        yield ctx.finding(
                            RULE_ID, path, node,
                            f"comparison against magic dtype extreme {v}: "
                            f"use the -1 sentinel (or an explicit named "
                            f"constant with an R5 allow)",
                            slug(f"magic:{v}"),
                        )
            elif isinstance(node, ast.Call):
                fname = astutil.dotted(node.func).split(".")[-1]
                if fname in _FILL_CALLS:
                    for a in node.args:
                        v = _magic_value(a)
                        if v is not None:
                            yield ctx.finding(
                                RULE_ID, path, node,
                                f"{fname}() filled with magic dtype "
                                f"extreme {v}: the one sentinel is -1",
                                slug(f"magic-fill:{v}"),
                            )
