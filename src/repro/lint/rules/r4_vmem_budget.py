"""R4 vmem-budget: every Pallas kernel's VMEM footprint — declared
scratch (``pltpu.VMEM``) plus double-buffered in/out blocks
(``pl.BlockSpec``) — must fit the ~16 MiB/core VMEM budget DESIGN.md
claims, for **every** candidate in the ``kernels/autotune.py``
``CANDIDATES`` grid, at both the autotune probe shape and a
production-scale shape. A tile that compiles at the bench probe but
OOMs VMEM at 100k rows is exactly the failure this rule front-runs.

How it works (see ``astutil.eval_shape``): the rule lifts the *actual*
shape expressions out of each ``*_kernel_call`` body — no parallel
bookkeeping of shapes that could drift — and evaluates them against a
symbol environment computed from the probe shape and the candidate
params using the kernels' own tiling formulas. ``SMEM``/``ANY`` specs
and DMA semaphores don't occupy VMEM blocks and are skipped; block
elements are costed at 4 bytes (f32/int32 worst case) and in/out blocks
are doubled for pipelining double-buffering. An expression the
evaluator cannot reduce is itself a finding, so a new shape idiom in a
kernel forces this rule (and its env) to be taught about it rather than
silently passing.

Completeness is checked both ways: every ``CANDIDATES`` kind must map
to a kernel, and every module under ``kernels/`` that calls
``pl.pallas_call`` must be covered by this rule's kernel table.
"""
from __future__ import annotations

import ast
import os

from repro.lint import astutil
from repro.lint.astutil import SimpleNamespace as NS

RULE_ID = "R4"
TITLE = "vmem-budget"
SUMMARY = "Pallas scratch+blocks fit 16 MiB VMEM for every autotune candidate"

# DESIGN.md's stated per-core VMEM budget (TPU VMEM is ~16 MB/core).
BUDGET_BYTES = 16 << 20

# itemsizes standing in for jnp dtypes in shape/dtype expressions
_DTYPES = NS(
    int32=4, uint32=4, float32=4, int16=2, uint16=2, bfloat16=2,
    float16=2, int8=1, uint8=1, bool_=1,
)

# Probe shapes. "autotune" mirrors benchmarks/hotpath.py::bench_autotune;
# "production" is the acceptance-scale workload (100k rows, d=128) with
# generous beam/candidate widths so the check documents headroom.
PROBES = {
    "autotune": dict(
        B=8, n=4096, d=32, m=8, W=4, m_out=8, C=64, M=64,
        Sq=128, Skv=128, Dh=64,
    ),
    "production": dict(
        B=64, n=100_000, d=128, m=16, W=32, m_out=16, C=256, M=512,
        Sq=2048, Skv=2048, Dh=128,
    ),
}


def _ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _layers(n: int) -> int:
    # logn = ceil(log2 n) (the index depth), layers = logn + 1
    return max(1, (max(int(n), 2) - 1).bit_length()) + 1


def _pq(d: int) -> tuple[int, int]:
    # worst-case PQ geometry for the aux codebook input: dsub=8 lanes
    dsub = 8
    return max(1, d // dsub), dsub


def _hop_env(p, c):
    layers = _layers(p["n"])
    K = layers * p["m"]
    bb = max(1, min(c["block_b"], p["B"]))
    WM = p["W"] * p["m_out"]
    dp = _ceil_to(p["d"], 128)
    pq_m, dsub = _pq(p["d"])
    return {
        "bb": bb, "W": p["W"], "K": K, "WM": WM, "dp": dp,
        "words": -(-p["n"] // 32),
        "win": max(1, min(c["window"], bb * p["W"])),
        "m_out": p["m_out"], "window": c["window"],
        "tp": NS(shape=(p["n"], dp), dtype=4),
        "aux": NS(shape=(pq_m * 256, dsub)),
        "jnp": _DTYPES,
    }


def _gather_env(p, c):
    bb = min(c["block_b"], max(8, p["B"]))
    bm = 128 if p["M"] <= 128 else min(c["block_m"], p["M"])
    dp = _ceil_to(p["d"], 128)
    pq_m, dsub = _pq(p["d"])
    return {
        "bb": bb, "bm": bm, "dp": dp, "window": c["window"],
        "xbuf_shape": (bb * bm, dp),
        "tbl": NS(shape=(p["n"], dp), dtype=4),
        "aux": NS(shape=(pq_m * 256, dsub)),
        "jnp": _DTYPES,
    }


def _edge_env(p, c):
    return {
        "bf": c["block_f"], "K": _layers(p["n"]) * p["m"],
        "m_out": p["m_out"], "window": c["window"], "jnp": _DTYPES,
    }


def _prune_env(p, c):
    bb = min(c["block_b"], max(8, p["B"]))
    dp = _ceil_to(p["d"], 128)
    pq_m, dsub = _pq(p["d"])
    return {
        "bb": bb, "C": p["C"], "m": p["m"], "window": c["window"],
        "tp": NS(shape=(p["n"], dp), dtype=4),
        "aux": NS(shape=(pq_m * 256, dsub)),
        "jnp": _DTYPES,
    }


def _dist_env(p, c):
    return {
        "bq": min(c["block_q"], max(8, p["B"])),
        "bn": min(c["block_n"], max(8, p["n"])),
        "bk": min(c["block_k"], _ceil_to(p["d"], 128)),
        "jnp": _DTYPES,
    }


def _flash_env(p, c):
    return {
        "bq": min(c["block_q"], max(8, p["Sq"])),
        "bk": min(c["block_k"], max(8, p["Skv"])),
        "Dh": p["Dh"], "jnp": _DTYPES,
    }


# kernel table: module -> call fn, autotune kinds (None = no grid entry,
# checked at its wrapper-default candidate), env builder
KERNELS = (
    ("hop.py", "hop_kernel_call", ("hop",), _hop_env, None),
    ("gather_distance.py", "gather_distance_kernel_call",
     ("gather_dist", "gather_dist_codec"), _gather_env, None),
    ("edge_select.py", "edge_select_kernel_call", ("edge_select",),
     _edge_env, None),
    ("prune.py", "prune_kernel_call", ("prune",), _prune_env, None),
    ("distance.py", "pairwise_dist_kernel_call", (None,), _dist_env,
     [{"block_q": 128, "block_n": 128, "block_k": 512}]),
    ("flash_attention.py", "flash_attention_kernel_call", (None,),
     _flash_env, [{"block_q": 128, "block_k": 128}]),
)


def _spec_kind(call: ast.Call) -> str | None:
    """'scratch' | 'block' | None(skip) for a Call node inside the fn."""
    name = astutil.dotted(call.func)
    if "SemaphoreType" in name:
        return None
    if name.endswith(".VMEM") or name == "VMEM":
        return "scratch"
    if name.endswith(".BlockSpec") or name == "BlockSpec":
        for kw in call.keywords:
            if kw.arg == "memory_space":
                space = astutil.dotted(kw.value)
                if space.endswith(("SMEM", "ANY")):
                    return None
        if not call.args:
            return None  # memory_space-only spec
        return "block"
    return None


def _extract(fn: ast.AST):
    """(kind, shape_expr, dtype_expr|None) for every VMEM-occupying
    declaration in the kernel-call body, across all codec branches
    (the union is a conservative superset of any one branch)."""
    out = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        kind = _spec_kind(node)
        if kind == "scratch":
            dtype = node.args[1] if len(node.args) > 1 else None
            out.append((kind, node.args[0], dtype))
        elif kind == "block":
            out.append((kind, node.args[0], None))
    return out


def _nbytes(shape_expr, dtype_expr, env) -> int:
    shape = astutil.eval_shape(shape_expr, env)
    if isinstance(shape, (int, float)):
        shape = (shape,)
    total = 1
    for e in shape:
        if not isinstance(e, int) or e <= 0:
            raise astutil.EvalError(
                f"non-positive/non-int dim {e!r} in "
                f"{ast.unparse(shape_expr)}"
            )
        total *= e
    itemsize = 4
    if dtype_expr is not None:
        itemsize = astutil.eval_shape(dtype_expr, env)
        if not isinstance(itemsize, int):
            raise astutil.EvalError(
                f"dtype {ast.unparse(dtype_expr)} -> {itemsize!r}"
            )
    return total * itemsize


def check(ctx):
    try:
        candidates = astutil.eval_module_constant(
            ctx.tree(ctx.autotune_path), "CANDIDATES", ctx.autotune_path
        )
    except astutil.EvalError as e:
        yield ctx.finding(
            RULE_ID, ctx.autotune_path, 0,
            f"cannot read the CANDIDATES grid statically: {e}",
            "no-candidates",
        )
        return

    covered_kinds, covered_files = set(), set()
    for fname, call_name, kinds, env_fn, defaults in KERNELS:
        path = os.path.join(ctx.kernels_dir, fname)
        covered_files.add(os.path.abspath(path))
        if not os.path.exists(path):
            yield ctx.finding(
                RULE_ID, ctx.kernels_dir, 0,
                f"R4 kernel table names {fname} but kernels/ has no such "
                f"module — update KERNELS in this rule",
                f"missing-module:{fname}",
            )
            continue
        fn = astutil.top_level_functions(ctx.tree(path)).get(call_name)
        if fn is None:
            yield ctx.finding(
                RULE_ID, path, 0,
                f"expected Pallas entry point {call_name}() not found — "
                f"update KERNELS in this rule",
                f"missing-call:{call_name}",
            )
            continue
        decls = _extract(fn)
        if not decls:
            yield ctx.finding(
                RULE_ID, path, fn,
                f"{call_name} declares no VMEM blocks or scratch — "
                f"extraction found nothing to budget (rule out of sync?)",
                f"{call_name}:no-decls",
            )
            continue

        for kind in kinds:
            grid = defaults if kind is None else candidates.get(kind)
            label = kind or fname[:-3]
            covered_kinds.add(kind)
            if grid is None:
                yield ctx.finding(
                    RULE_ID, ctx.autotune_path, 0,
                    f"R4 kernel table maps {fname} to autotune kind "
                    f"{kind!r} but CANDIDATES has no such kind",
                    f"unknown-kind:{kind}",
                )
                continue
            for probe_name, probe in PROBES.items():
                for cand in grid:
                    try:
                        env = env_fn(probe, cand)
                        total = sum(
                            _nbytes(s, d, env) * (2 if k == "block" else 1)
                            for k, s, d in decls
                        )
                    except astutil.EvalError as e:
                        yield ctx.finding(
                            RULE_ID, path, fn,
                            f"{call_name}: cannot evaluate a VMEM shape "
                            f"for {label}/{probe_name} {cand}: {e} — "
                            f"teach r4_vmem_budget the new idiom",
                            f"{call_name}:uneval:{e}",
                        )
                        break
                    if total > BUDGET_BYTES:
                        cd = ",".join(
                            f"{k}={cand[k]}" for k in sorted(cand)
                        )
                        yield ctx.finding(
                            RULE_ID, path, fn,
                            f"{call_name}: candidate {{{cd}}} needs "
                            f"{total / 2**20:.2f} MiB VMEM at the "
                            f"{probe_name} shape — over the "
                            f"{BUDGET_BYTES >> 20} MiB budget DESIGN.md "
                            f"claims; shrink the tile or drop it from "
                            f"CANDIDATES[{label!r}]",
                            f"{call_name}:{label}:{probe_name}:{cd}",
                        )

    for kind in candidates:
        if kind not in covered_kinds:
            yield ctx.finding(
                RULE_ID, ctx.autotune_path, 0,
                f"CANDIDATES kind {kind!r} is not mapped to any kernel in "
                f"r4_vmem_budget.KERNELS — its grid is unchecked",
                f"unmapped-kind:{kind}",
            )

    for path in ctx.py_files(ctx.kernels_dir):
        if os.path.abspath(path) in covered_files:
            continue
        if any(
            isinstance(n, ast.Attribute) and n.attr == "pallas_call"
            for n in ast.walk(ctx.tree(path))
        ):
            yield ctx.finding(
                RULE_ID, path, 0,
                f"{os.path.basename(path)} calls pl.pallas_call but is "
                f"not covered by r4_vmem_budget.KERNELS — its VMEM "
                f"footprint is unchecked",
                f"uncovered:{os.path.basename(path)}",
            )
