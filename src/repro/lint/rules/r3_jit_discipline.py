"""R3 jit-discipline: the jitted cores (``_search_improvised_jit`` and
friends) must stay retrace-free and tracer-safe.

Inside any function that is jit-compiled (``@jax.jit``,
``@functools.partial(jax.jit, static_argnames=...)``, or the assignment
form ``f = jax.jit(g, ...)`` / ``functools.partial(jax.jit, ...)(g)``):

* ``float()`` / ``int()`` / ``bool()`` on an expression rooted at a
  *traced* parameter is a concretization error at trace time (shapes are
  fine: expressions routed through ``.shape`` / ``.ndim`` / ``.size`` /
  ``.dtype`` / ``len()`` are allowed);
* ``.item()`` anywhere is the same error;
* ``np.asarray`` / ``np.array`` on a traced root forces a host transfer;
* every ``static_argnames`` entry must name a parameter of the function;
* a static parameter must not default to a mutable (unhashable) literal —
  static args are dict keys in jax's compilation cache.
"""
from __future__ import annotations

import ast

from repro.lint import astutil

RULE_ID = "R3"
TITLE = "jit-discipline"
SUMMARY = "no tracer coercions or unhashable static args inside jitted cores"

_JIT_NAMES = {"jax.jit", "jit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}
_SHAPE_ATTRS = {"shape", "ndim", "size", "dtype"}
_COERCE = {"float", "int", "bool"}
_NP_BASES = {"np", "numpy", "onp"}
_NP_FUNCS = {"asarray", "array", "ascontiguousarray"}


def _static_names(keywords) -> set[str] | None:
    for kw in keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return {v.value}
            if isinstance(v, (ast.Tuple, ast.List)):
                return {
                    e.value for e in v.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, str)
                }
    return None


def _jit_statics(expr: ast.AST) -> set[str] | None:
    """If ``expr`` is a jit-wrapping expression, its static_argnames set
    (possibly empty); None when it isn't a jit wrapper."""
    if astutil.dotted(expr) in _JIT_NAMES:
        return set()
    if isinstance(expr, ast.Call):
        f = astutil.dotted(expr.func)
        if f in _JIT_NAMES:
            return _static_names(expr.keywords) or set()
        if f in _PARTIAL_NAMES and expr.args:
            if astutil.dotted(expr.args[0]) in _JIT_NAMES:
                return _static_names(expr.keywords) or set()
    return None


def _param_names(fn) -> list[str]:
    a = fn.args
    return [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]


def _mutable_default(fn, name: str):
    a = fn.args
    pos = a.posonlyargs + a.args
    defaults = a.defaults
    for p, d in zip(pos[len(pos) - len(defaults):], defaults):
        if p.arg == name:
            return d if isinstance(d, (ast.List, ast.Dict, ast.Set)) else None
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if p.arg == name and d is not None:
            return d if isinstance(d, (ast.List, ast.Dict, ast.Set)) else None
    return None


def _jit_cores(tree: ast.Module):
    """Yield ``(fn_node, static_names)`` for every jit-compiled function:
    decorator form anywhere, plus module-level assignment form wrapping a
    local function by name."""
    funcs = astutil.top_level_functions(tree)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                statics = _jit_statics(dec)
                if statics is not None:
                    yield node, statics
                    break
    for node in tree.body:
        if not (isinstance(node, ast.Assign) and isinstance(node.value, ast.Call)):
            continue
        call = node.value
        # jax.jit(fn, static_argnames=...) form
        statics = None
        target = None
        if astutil.dotted(call.func) in _JIT_NAMES and call.args:
            statics = _static_names(call.keywords) or set()
            target = call.args[0]
        else:
            # functools.partial(jax.jit, ...)(fn) form
            inner = call.func
            if isinstance(inner, ast.Call):
                s = _jit_statics(inner)
                if s is not None and call.args:
                    statics, target = s, call.args[0]
        if statics is None or target is None:
            continue
        if isinstance(target, ast.Name) and target.id in funcs:
            yield funcs[target.id], statics
        # attribute targets (e.g. _ref.prune) live in another module and
        # are checked when that module is scanned — nothing to do here


def _shape_routed(expr: ast.AST) -> bool:
    for n in ast.walk(expr):
        if isinstance(n, ast.Attribute) and n.attr in _SHAPE_ATTRS:
            return True
        if isinstance(n, ast.Call) and astutil.dotted(n.func) == "len":
            return True
    return False


def check(ctx):
    for path in ctx.py_files(ctx.src_dir):
        tree = ctx.tree(path)
        seen_fns = set()
        for fn, statics in _jit_cores(tree):
            if id(fn) in seen_fns:
                continue
            seen_fns.add(id(fn))
            params = _param_names(fn)
            traced = set(params) - statics

            for s in sorted(statics):
                if s not in params:
                    yield ctx.finding(
                        RULE_ID, path, fn,
                        f"{fn.name}: static_argnames entry {s!r} is not a "
                        f"parameter of the jitted function",
                        f"{fn.name}:static-unknown:{s}",
                    )
                    continue
                bad = _mutable_default(fn, s)
                if bad is not None:
                    yield ctx.finding(
                        RULE_ID, path, bad,
                        f"{fn.name}: static arg {s!r} defaults to a mutable "
                        f"{type(bad).__name__.lower()} literal — static args "
                        f"must be hashable (use a tuple / frozen config)",
                        f"{fn.name}:static-mutable:{s}",
                    )

            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                f = astutil.dotted(node.func)
                if (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item"
                    and not node.args
                ):
                    yield ctx.finding(
                        RULE_ID, path, node,
                        f"{fn.name}: .item() inside a jitted core "
                        f"concretizes a tracer at trace time",
                        f"{fn.name}:item:{node.lineno}",
                    )
                    continue
                if f in _COERCE and len(node.args) == 1:
                    arg = node.args[0]
                    if not _shape_routed(arg) and (
                        astutil.names_in(arg) & traced
                    ):
                        yield ctx.finding(
                            RULE_ID, path, node,
                            f"{fn.name}: {f}() on an expression rooted at "
                            f"traced parameter(s) "
                            f"{sorted(astutil.names_in(arg) & traced)} — "
                            f"this concretizes a tracer (route through "
                            f".shape/.ndim, or make the arg static)",
                            f"{fn.name}:coerce-{f}:{node.lineno}",
                        )
                    continue
                if (
                    isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id in _NP_BASES
                    and node.func.attr in _NP_FUNCS
                ):
                    roots = set()
                    for a in node.args:
                        if not _shape_routed(a):
                            roots |= astutil.names_in(a) & traced
                    if roots:
                        yield ctx.finding(
                            RULE_ID, path, node,
                            f"{fn.name}: np.{node.func.attr}() on traced "
                            f"parameter(s) {sorted(roots)} forces a host "
                            f"transfer inside the jitted core",
                            f"{fn.name}:np-{node.func.attr}:{node.lineno}",
                        )
