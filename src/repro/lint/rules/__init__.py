"""The replint rule set. Each rule module exposes ``RULE_ID``, ``TITLE``,
``SUMMARY`` and ``check(ctx) -> Iterable[Finding]``; adding a rule =
adding a module here and listing it in ``ALL_RULES`` (DESIGN.md §10)."""
from repro.lint.rules import (
    r1_knob_registry,
    r2_dispatch_contract,
    r3_jit_discipline,
    r4_vmem_budget,
    r5_sentinel_discipline,
    r6_reachability,
)

ALL_RULES = (
    r1_knob_registry,
    r2_dispatch_contract,
    r3_jit_discipline,
    r4_vmem_budget,
    r5_sentinel_discipline,
    r6_reachability,
)

__all__ = ["ALL_RULES"]
