"""R6 import-reachability: every module under ``src/repro`` must be
reachable, through the static import graph, from the public entry points
(``Context.entry_points`` — the index/search API, the serving stack, the
workload drivers, the linter). Code nothing imports is code no test
runs and no reader can trust.

The repo grew from a generic training-harness seed, and several seed
packages (``models/``, ``train/``, ``configs/``, ``data/``,
``sharding/``, ``checkpoint/``, ``runtime/``, the ``launch/`` drivers
over them) survive only as the multi-pod dry-run's scaffolding. Those
are *fenced, not deleted*: each lives in ``lint_baseline.json`` with a
one-line reason, so the fence is explicit, the list can only shrink
(``benchmarks/ci_gate.py`` fails growth), and any NEW unreachable
module is a hard finding.
"""
from __future__ import annotations

import ast
import os

RULE_ID = "R6"
TITLE = "import-reachability"
SUMMARY = "no module unreachable from the public entry points (seed fence baselined)"


def _module_map(ctx) -> dict[str, str]:
    """module name -> file path for everything under ``ctx.src_dir``."""
    base = os.path.basename(os.path.abspath(ctx.src_dir))
    out = {}
    for path in ctx.py_files(ctx.src_dir):
        rel = os.path.relpath(path, ctx.src_dir)
        parts = rel.replace(os.sep, "/").split("/")
        if parts[-1] == "__init__.py":
            parts = parts[:-1]
        else:
            parts[-1] = parts[-1][:-3]
        out[".".join([base, *parts]) if parts else base] = path
    return out


def _imports(ctx, path: str, modname: str, known) -> set[str]:
    base = modname.split(".")[0]
    is_pkg = os.path.basename(path) == "__init__.py"
    out = set()

    def add(name: str):
        # an import of repro.a.b marks repro, repro.a and repro.a.b reachable
        parts = name.split(".")
        for i in range(1, len(parts) + 1):
            cand = ".".join(parts[:i])
            if cand in known:
                out.add(cand)

    for node in ast.walk(ctx.tree(path)):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == base or a.name.startswith(base + "."):
                    add(a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                # relative: for module a.b.c level 1 anchors at a.b; for a
                # package __init__ (modname a.b) level 1 anchors at a.b
                parts = modname.split(".")
                drop = node.level - (1 if is_pkg else 0)
                anchor = parts[: len(parts) - drop] if drop else parts
                target = ".".join(
                    anchor + ([node.module] if node.module else [])
                )
            else:
                target = node.module or ""
            if target == base or target.startswith(base + "."):
                add(target)
                for a in node.names:
                    add(f"{target}.{a.name}")
    return out


def check(ctx):
    modules = _module_map(ctx)
    known = set(modules)

    graph = {}
    for name, path in modules.items():
        try:
            graph[name] = _imports(ctx, path, name, modules)
        except SyntaxError as e:
            yield ctx.finding(
                RULE_ID, path, 0, f"cannot parse: {e}", f"parse:{name}"
            )
            graph[name] = set()

    roots = []
    for entry in ctx.entry_points:
        if entry in known:
            roots.append(entry)
        else:
            yield ctx.finding(
                RULE_ID, ctx.src_dir, 0,
                f"entry point {entry!r} names no module under src — "
                f"update Context.entry_points",
                f"missing-entry:{entry}",
            )

    reachable = set(roots)
    # an entry point's enclosing packages are implicitly importable
    for r in roots:
        parts = r.split(".")
        reachable.update(
            ".".join(parts[:i]) for i in range(1, len(parts))
            if ".".join(parts[:i]) in known
        )
    frontier = list(reachable)
    while frontier:
        cur = frontier.pop()
        for nxt in graph.get(cur, ()):
            if nxt not in reachable:
                reachable.add(nxt)
                frontier.append(nxt)

    for name in sorted(known - reachable):
        yield ctx.finding(
            RULE_ID, modules[name], 0,
            f"{name} is unreachable from every public entry point "
            f"({', '.join(ctx.entry_points)}): delete it, wire it in, or "
            f"fence it in lint_baseline.json with a reason",
            name,
        )
