"""R1 knob-registry: every ``REPRO_*`` env access flows through the typed
registry in ``core/knobs.py``, every ``REPRO_*`` name mentioned in code is
a registered knob, and ``docs/KNOBS.md`` is exactly what the registry
generates.

Three findings:

* ``raw-env:<NAME>``       — ``os.environ`` / ``os.getenv`` access with a
  ``REPRO_*`` key outside ``knobs.py`` (the typed accessors exist so a
  knob cannot be read without a declared type/default/doc);
* ``unregistered:<NAME>``  — a ``REPRO_*`` string literal (including in
  docstrings: stale doc mentions are drift too) that is not in
  ``REGISTRY``;
* ``knobs-md-drift``       — ``docs/KNOBS.md`` differs from
  ``knobs.generate_markdown()`` (regenerate with ``--write-knobs``).
"""
from __future__ import annotations

import ast
import importlib.util
import os
import re

from repro.lint import astutil

RULE_ID = "R1"
TITLE = "knob-registry"
SUMMARY = "REPRO_* env access must flow through core/knobs.py; KNOBS.md is generated"

_KNOB_RE = re.compile(r"\bREPRO_[A-Z][A-Z0-9_]*\b")
_ENV_GET = {"os.getenv", "os.environ.get", "environ.get"}
_ENV_MAP = {"os.environ", "environ"}


def load_knobs_module(path: str):
    """Load ``knobs.py`` standalone (it only needs dataclasses + os), so
    the linter — and fixture tests pointing at a stub registry — never
    import the full ``repro.core`` package."""
    import sys

    name = f"_replint_knobs_{abs(hash(os.path.abspath(path)))}"
    if name in sys.modules:
        return sys.modules[name]
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    # dataclasses resolves the module through sys.modules at class-creation
    # time, so the module must be registered before exec
    sys.modules[name] = mod
    try:
        spec.loader.exec_module(mod)
    except BaseException:
        del sys.modules[name]
        raise
    return mod


def check(ctx):
    knobs_mod = load_knobs_module(ctx.knobs_path)
    registered = {k.name for k in knobs_mod.REGISTRY}

    knobs_abs = os.path.abspath(ctx.knobs_path)
    for path in ctx.py_files(ctx.src_dir, *ctx.extra_dirs):
        if os.path.abspath(path) == knobs_abs:
            continue
        tree = ctx.tree(path)
        seen_raw, seen_unreg = set(), set()
        for node in ast.walk(tree):
            key = None
            if (
                isinstance(node, ast.Call)
                and astutil.dotted(node.func) in _ENV_GET
                and node.args
            ):
                key = node.args[0]
            elif (
                isinstance(node, ast.Subscript)
                and isinstance(node.ctx, ast.Load)
                and astutil.dotted(node.value) in _ENV_MAP
            ):
                key = node.slice
            if (
                key is not None
                and isinstance(key, ast.Constant)
                and isinstance(key.value, str)
                and _KNOB_RE.fullmatch(key.value)
                and key.value not in seen_raw
            ):
                seen_raw.add(key.value)
                yield ctx.finding(
                    RULE_ID, path, node,
                    f"raw environment read of {key.value!r}: use the typed "
                    f"accessors in repro.core.knobs (get_str/get_int/...) "
                    f"so the knob has a registered type, default and doc",
                    f"raw-env:{key.value}",
                )
        for text, line in astutil.str_constants_in(tree):
            for name in _KNOB_RE.findall(text):
                if name in registered or name in seen_unreg:
                    continue
                seen_unreg.add(name)
                yield ctx.finding(
                    RULE_ID, path, line,
                    f"{name} is not a registered knob: declare it in "
                    f"repro.core.knobs.REGISTRY (or fix the stale mention) "
                    f"and regenerate docs/KNOBS.md",
                    f"unregistered:{name}",
                )

    # docs/KNOBS.md must be exactly the generated table
    want = knobs_mod.generate_markdown()
    if not os.path.exists(ctx.knobs_md_path):
        yield ctx.finding(
            RULE_ID, ctx.knobs_md_path, 0,
            "docs/KNOBS.md is missing: run "
            "`PYTHONPATH=src python -m repro.lint --write-knobs`",
            "knobs-md-drift",
        )
    else:
        with open(ctx.knobs_md_path, encoding="utf-8") as f:
            have = f.read()
        if have != want:
            yield ctx.finding(
                RULE_ID, ctx.knobs_md_path, 0,
                "docs/KNOBS.md drifted from knobs.generate_markdown(): "
                "edit src/repro/core/knobs.py (the source of truth) and "
                "run `PYTHONPATH=src python -m repro.lint --write-knobs`",
                "knobs-md-drift",
            )
