"""replint — the repo-specific static-analysis suite (DESIGN.md §10).

The codebase rests on invariants that used to exist only as convention:
every kernel op has a ref contract and an oracle backend, every ``REPRO_*``
knob is registered and documented, jitted cores never coerce tracers,
Pallas scratch fits VMEM for every autotune candidate, ``-1`` is the one
sentinel. ``python -m repro.lint`` turns each into a checked rule:

  ====  =====================================================================
  R1    knob-registry: all ``REPRO_*`` env access flows through
        ``core/knobs.py``; ``docs/KNOBS.md`` matches the generated table
  R2    dispatch-contract: every op in ``kernels/ops.py`` has a ``ref.py``
        contract, an oracle impl token, ``_check_impl`` validation, a
        registered override knob, and a test module naming it
  R3    jit-discipline: no tracer coercions (``float()``/``int()``/
        ``bool()``/``.item()``/``np.asarray``) and no unhashable static
        args inside the jitted ``_*_jit`` cores
  R4    vmem-budget: every Pallas kernel's BlockSpec/scratch shapes,
        evaluated over the full ``kernels/autotune.py`` CANDIDATES grid,
        fit the 16 MiB/core VMEM budget DESIGN.md claims
  R5    sentinel-discipline: only ``-1`` sentinels in storage/kernel code —
        no dtype-max comparisons or stray magic sentinels
  R6    import-reachability: no code unreachable from the public entry
        points except the allowlisted seed-vestigial packages
  ====  =====================================================================

Workflow: findings not in the committed baseline (``lint_baseline.json``,
entries carry a one-line reason) fail the run; ``--strict`` additionally
fails on *stale* baseline entries so the baseline only ever shrinks
(``benchmarks/ci_gate.py`` hard-fails growth). Point suppressions use an
inline ``# replint: allow[R5] reason`` comment on the flagged line.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re

__all__ = [
    "Finding", "Context", "run", "load_baseline", "save_baseline",
    "suppressed", "DEFAULT_BASELINE", "repo_root",
]

DEFAULT_BASELINE = "lint_baseline.json"

_ALLOW_RE = re.compile(r"#\s*replint:\s*allow\[([A-Za-z0-9*,\s]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``key`` is the *stable identity* used for baselining — rule + path +
    a slug chosen by the rule (a knob/op/module name, never a line number),
    so baseline entries survive unrelated edits to the file.
    """

    rule: str       # "R1".."R6"
    path: str       # repo-relative, '/'-separated
    line: int       # 1-based; 0 = whole-file finding
    message: str
    slug: str       # stable identity fragment

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.slug}"

    def render(self, tag: str = "") -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        suffix = f"  [{tag}]" if tag else ""
        return f"{self.rule} {loc}: {self.message}{suffix}"


def repo_root(start: str | None = None) -> str:
    """Walk up from ``start`` (default: this file) to the pyproject root."""
    p = os.path.abspath(start or os.path.dirname(__file__))
    while True:
        if os.path.exists(os.path.join(p, "pyproject.toml")):
            return p
        parent = os.path.dirname(p)
        if parent == p:
            raise FileNotFoundError(
                "repro.lint: no pyproject.toml above " + str(start)
            )
        p = parent


class Context:
    """Everything a rule needs to see, injectable for fixture tests.

    The defaults describe *this* repo's layout; ``tests/test_lint.py``
    builds Contexts over tmp fixture trees by overriding the relevant
    paths (``ops_path``, ``src_dir``, ...), which is how each rule's
    violating/clean fixtures run without a full repo copy.
    """

    def __init__(
        self,
        root: str | None = None,
        *,
        src_dir: str | None = None,        # the repro package dir
        extra_dirs: tuple[str, ...] | None = None,  # benchmarks etc. (R1)
        tests_dir: str | None = None,
        knobs_path: str | None = None,     # core/knobs.py (R1/R2)
        knobs_md_path: str | None = None,  # docs/KNOBS.md (R1)
        ops_path: str | None = None,       # kernels/ops.py (R2)
        ref_path: str | None = None,       # kernels/ref.py (R2)
        autotune_path: str | None = None,  # kernels/autotune.py (R4)
        kernels_dir: str | None = None,    # kernels/ (R4)
        sentinel_paths: tuple[str, ...] | None = None,  # R5 scope
        entry_points: tuple[str, ...] | None = None,    # R6 roots
    ):
        self.root = os.path.abspath(root or repo_root())
        j = os.path.join
        self.src_dir = src_dir or j(self.root, "src", "repro")
        self.extra_dirs = (
            extra_dirs if extra_dirs is not None
            else (j(self.root, "benchmarks"),)
        )
        self.tests_dir = tests_dir or j(self.root, "tests")
        self.knobs_path = knobs_path or j(self.src_dir, "core", "knobs.py")
        self.knobs_md_path = (
            knobs_md_path or j(self.root, "docs", "KNOBS.md")
        )
        self.ops_path = ops_path or j(self.src_dir, "kernels", "ops.py")
        self.ref_path = ref_path or j(self.src_dir, "kernels", "ref.py")
        self.autotune_path = (
            autotune_path or j(self.src_dir, "kernels", "autotune.py")
        )
        self.kernels_dir = kernels_dir or j(self.src_dir, "kernels")
        if sentinel_paths is not None:
            self.sentinel_paths = sentinel_paths
        else:
            core = j(self.src_dir, "core")
            self.sentinel_paths = tuple(
                sorted(self.py_files(self.kernels_dir))
            ) + tuple(
                j(core, f) for f in (
                    "storage.py", "bitset.py", "search.py", "edge_select.py",
                    "rng.py", "build.py", "index.py", "distributed.py",
                )
            )
        # the paper-system public surface: the index/search API, the
        # serving stack, the baselines/multiattr/distributed workloads and
        # the linter itself. Deliberately NOT the dryrun/train harness —
        # that is the fence around the seed-vestigial model zoo (R6).
        self.entry_points = entry_points or (
            "repro.core", "repro.core.index", "repro.core.baselines",
            "repro.core.multiattr", "repro.core.distributed",
            "repro.serve.engine", "repro.serve.loop", "repro.serve.executor",
            "repro.kernels.ops", "repro.compressio", "repro.lint",
            "repro.lint.__main__",
        )
        self._source: dict[str, str] = {}
        self._tree: dict[str, ast.Module] = {}

    # -- cached IO ----------------------------------------------------------
    def source(self, path: str) -> str:
        path = os.path.abspath(path)
        if path not in self._source:
            with open(path, encoding="utf-8") as f:
                self._source[path] = f.read()
        return self._source[path]

    def tree(self, path: str) -> ast.Module:
        path = os.path.abspath(path)
        if path not in self._tree:
            self._tree[path] = ast.parse(self.source(path), filename=path)
        return self._tree[path]

    def relpath(self, path: str) -> str:
        return os.path.relpath(os.path.abspath(path), self.root).replace(
            os.sep, "/"
        )

    def py_files(self, *dirs: str) -> list[str]:
        out = []
        for d in dirs:
            if not os.path.isdir(d):
                continue
            for base, _dirnames, names in os.walk(d):
                out.extend(
                    os.path.join(base, f) for f in names
                    if f.endswith(".py")
                )
        return sorted(out)

    def finding(self, rule, path, node_or_line, message, slug) -> Finding:
        line = (
            node_or_line if isinstance(node_or_line, int)
            else getattr(node_or_line, "lineno", 0)
        )
        return Finding(rule, self.relpath(path), line, message, slug)


def suppressed(ctx: Context, f: Finding) -> bool:
    """True when the flagged source line carries ``# replint: allow[Rn]``."""
    if not f.line:
        return False
    try:
        lines = ctx.source(os.path.join(ctx.root, f.path)).splitlines()
        text = lines[f.line - 1]
    except (OSError, IndexError):
        return False
    m = _ALLOW_RE.search(text)
    if not m:
        return False
    rules = {t.strip() for t in m.group(1).split(",")}
    return "*" in rules or f.rule in rules


def run(ctx: Context, rule_ids=None) -> list[Finding]:
    """Run the requested rules (default: all) and drop inline-suppressed
    findings. Baseline handling is the caller's (``__main__``) job."""
    from repro.lint import rules as rules_pkg

    out: list[Finding] = []
    for mod in rules_pkg.ALL_RULES:
        if rule_ids and mod.RULE_ID not in rule_ids:
            continue
        out.extend(f for f in mod.check(ctx) if not suppressed(ctx, f))
    return sorted(out, key=lambda f: (f.rule, f.path, f.line, f.slug))


def load_baseline(path: str) -> dict[str, str]:
    """``{finding key: one-line reason}`` from the committed baseline."""
    if not os.path.exists(path):
        return {}
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    out = {}
    for entry in data.get("entries", []):
        key, reason = entry["key"], entry.get("reason", "")
        if not reason.strip():
            raise ValueError(
                f"lint baseline {path}: entry {key!r} has no reason — "
                f"every baselined finding must carry a one-line "
                f"justification"
            )
        out[key] = reason
    return out


def save_baseline(path: str, entries: dict[str, str]) -> None:
    data = {
        "_comment": (
            "replint findings baseline (DESIGN.md §10). Every entry is a "
            "known, justified violation; python -m repro.lint fails on "
            "findings not listed here, --strict also fails on stale "
            "entries, and benchmarks/ci_gate.py hard-fails if this file "
            "grows. Shrink it by fixing findings, never grow it casually."
        ),
        "entries": [
            {"key": k, "reason": entries[k]} for k in sorted(entries)
        ],
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=2)
        f.write("\n")
