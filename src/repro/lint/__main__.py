"""CLI for the repo linter.

    PYTHONPATH=src python -m repro.lint [--strict] [--rules R1,R4] [--json]
    PYTHONPATH=src python -m repro.lint --write-knobs     # regen docs/KNOBS.md
    PYTHONPATH=src python -m repro.lint --write-baseline  # refresh baseline

Exit status: 0 when every finding is baselined (``lint_baseline.json``)
or inline-suppressed; 1 on any new finding. ``--strict`` (the CI mode)
additionally fails on *stale* baseline entries — a baselined finding
that no longer fires must be removed, so the baseline only ever shrinks.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.lint import (
    Context, DEFAULT_BASELINE, load_baseline, run, save_baseline,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="replint: repo-specific static analysis (DESIGN.md §10)",
    )
    ap.add_argument("--strict", action="store_true",
                    help="also fail on stale baseline entries (CI mode)")
    ap.add_argument("--rules", default="",
                    help="comma list of rule ids to run (default: all)")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline path (default: <root>/{DEFAULT_BASELINE})")
    ap.add_argument("--root", default=None,
                    help="repo root (default: walk up to pyproject.toml)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings on stdout")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from current findings "
                    "(keeps existing reasons, new entries get TODO)")
    ap.add_argument("--write-knobs", action="store_true",
                    help="regenerate docs/KNOBS.md from core/knobs.py "
                    "and exit")
    args = ap.parse_args(argv)

    ctx = Context(root=args.root)

    if args.write_knobs:
        from repro.lint.rules.r1_knob_registry import load_knobs_module

        content = load_knobs_module(ctx.knobs_path).generate_markdown()
        with open(ctx.knobs_md_path, "w", encoding="utf-8") as f:
            f.write(content)
        print(f"wrote {ctx.relpath(ctx.knobs_md_path)} "
              f"({len(content)} chars) from core/knobs.py::REGISTRY")
        return 0

    rule_ids = (
        {r.strip().upper() for r in args.rules.split(",") if r.strip()}
        or None
    )
    baseline_path = args.baseline or os.path.join(
        ctx.root, DEFAULT_BASELINE
    )
    baseline = load_baseline(baseline_path)
    findings = run(ctx, rule_ids)

    if args.write_baseline:
        entries = {
            f.key: baseline.get(f.key, "TODO: justify or fix")
            for f in findings
        }
        save_baseline(baseline_path, entries)
        print(f"wrote {len(entries)} entries to "
              f"{os.path.relpath(baseline_path, ctx.root)}")
        return 0

    new = [f for f in findings if f.key not in baseline]
    old = [f for f in findings if f.key in baseline]
    # staleness only applies to rules that actually ran this invocation
    ran_rules = rule_ids or {"R1", "R2", "R3", "R4", "R5", "R6"}
    stale = sorted(
        k for k in baseline
        if k.split(":", 1)[0] in ran_rules
        and k not in {f.key for f in findings}
    )

    if args.as_json:
        print(json.dumps({
            "new": [vars(f) | {"key": f.key} for f in new],
            "baselined": [vars(f) | {"key": f.key} for f in old],
            "stale_baseline": stale,
            "strict": args.strict,
        }, indent=2))
    else:
        for f in new:
            print(f.render())
        for f in old:
            print(f.render(tag=f"baselined: {baseline[f.key]}"))
        for k in stale:
            print(f"stale baseline entry (no longer fires): {k}")
        print(
            f"replint: {len(new)} new, {len(old)} baselined, "
            f"{len(stale)} stale baseline "
            f"entr{'y' if len(stale) == 1 else 'ies'}"
        )

    if new:
        return 1
    if args.strict and stale:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
