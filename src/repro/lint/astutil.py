"""Shared AST machinery for the lint rules.

Two jobs:

* small structural helpers (dotted-name rendering, name collection,
  top-level function lookup) used by every rule, and
* a *restricted symbolic evaluator* for shape expressions (R4): it
  evaluates the exact ``pltpu.VMEM(shape, dtype)`` / ``pl.BlockSpec(shape,
  ...)`` expressions out of a kernel's source against a symbol environment
  the rule computes from the probe shape and an autotune candidate. The
  evaluator is deliberately tiny — tuples, ints, names, ``+ - * // %``,
  ``min``/``max``, attribute and constant-index subscripts. Anything it
  cannot evaluate becomes a finding rather than a silent pass, which is
  what keeps R4 honest when a kernel grows a new shape idiom.
"""
from __future__ import annotations

import ast
from types import SimpleNamespace

__all__ = [
    "EvalError", "dotted", "top_level_functions", "names_in",
    "str_constants_in", "eval_shape", "eval_module_constant",
    "SimpleNamespace",
]


class EvalError(Exception):
    """A shape expression the symbolic evaluator does not understand."""


def dotted(node: ast.AST) -> str:
    """``a.b.c`` for Name/Attribute chains, '' for anything else."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def top_level_functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    return {
        n.name: n for n in tree.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }


def names_in(node: ast.AST) -> set[str]:
    """All Name identifiers loaded anywhere inside ``node``."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def str_constants_in(node: ast.AST) -> list[tuple[str, int]]:
    """(string literal, line) pairs anywhere inside ``node``."""
    return [
        (n.value, n.lineno) for n in ast.walk(node)
        if isinstance(n, ast.Constant) and isinstance(n.value, str)
    ]


_BINOPS = {
    ast.Add: lambda a, b: a + b,
    ast.Sub: lambda a, b: a - b,
    ast.Mult: lambda a, b: a * b,
    ast.FloorDiv: lambda a, b: a // b,
    ast.Div: lambda a, b: a / b,
    ast.Mod: lambda a, b: a % b,
    ast.Pow: lambda a, b: a ** b,
}

_CALLS = {"min": min, "max": max, "len": len, "int": int}


def eval_shape(node: ast.AST, env: dict) -> object:
    """Evaluate a shape/dtype expression against ``env``.

    ``env`` maps names to ints, tuples, or SimpleNamespace objects
    (e.g. ``tp -> SimpleNamespace(shape=(n, dp), dtype=4)`` standing in
    for an array, with dtypes represented by their itemsize in bytes).
    """
    if isinstance(node, ast.Constant):
        if isinstance(node.value, (int, float)):
            return node.value
        raise EvalError(f"non-numeric constant {node.value!r}")
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(eval_shape(e, env) for e in node.elts)
    if isinstance(node, ast.Name):
        if node.id in env:
            return env[node.id]
        raise EvalError(f"unknown name {node.id!r}")
    if isinstance(node, ast.BinOp):
        fn = _BINOPS.get(type(node.op))
        if fn is None:
            raise EvalError(f"operator {type(node.op).__name__}")
        return fn(eval_shape(node.left, env), eval_shape(node.right, env))
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -eval_shape(node.operand, env)  # type: ignore[operator]
    if isinstance(node, ast.Attribute):
        base = eval_shape(node.value, env)
        try:
            return getattr(base, node.attr)
        except AttributeError as e:
            raise EvalError(str(e)) from e
    if isinstance(node, ast.Subscript):
        base = eval_shape(node.value, env)
        idx = eval_shape(node.slice, env)
        try:
            return base[idx]  # type: ignore[index]
        except (TypeError, IndexError, KeyError) as e:
            raise EvalError(str(e)) from e
    if isinstance(node, ast.Call):
        fname = dotted(node.func)
        if fname in _CALLS and not node.keywords:
            return _CALLS[fname](*[eval_shape(a, env) for a in node.args])
        raise EvalError(f"call to {fname or '<expr>'}()")
    if isinstance(node, ast.IfExp):
        test = eval_shape(node.test, env)
        return eval_shape(node.body if test else node.orelse, env)
    if isinstance(node, ast.Compare) and len(node.ops) == 1:
        a = eval_shape(node.left, env)
        b = eval_shape(node.comparators[0], env)
        op = type(node.ops[0])
        table = {
            ast.Lt: a < b, ast.LtE: a <= b, ast.Gt: a > b,
            ast.GtE: a >= b, ast.Eq: a == b, ast.NotEq: a != b,
        }
        if op in table:
            return table[op]
        raise EvalError(f"comparison {op.__name__}")
    raise EvalError(f"node {type(node).__name__}")


def eval_module_constant(tree: ast.Module, name: str, filename: str):
    """Evaluate a module-level ``NAME = <expr>`` without importing the
    module (R4 pulls ``CANDIDATES`` out of ``kernels/autotune.py`` this
    way — the grid is literals and comprehensions, no imports needed)."""
    for node in tree.body:
        if (
            isinstance(node, ast.Assign)
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and node.targets[0].id == name
        ):
            expr = ast.Expression(body=node.value)
            ast.fix_missing_locations(expr)
            return eval(  # noqa: S307 - literal/comprehension grid only
                compile(expr, filename, "eval"), {"__builtins__": {}}, {}
            )
    raise EvalError(f"{filename}: no module-level assignment to {name!r}")
