"""Int8 gradient compression with error feedback.

At 1000+ node scale the gradient all-reduce over DCI dominates the step for
DP-heavy meshes. Compressing the cross-pod reduction to int8 with a carried
residual (error feedback) keeps convergence (Seide et al. / Karimireddy et
al.) while cutting collective bytes 4x. Applied *around* the reduction:

    q, new_err = compress(g + err)          # per-tensor scale, int8
    g_hat      = decompress(q)              # what gets reduced / applied

In the pjit data-flow the quantize/dequantize pair is placed on the gradient
before the optimizer; XLA then reduces the int8 tensor (verified in the HLO
collective sweep — see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_error_state", "compress_grads"]


def init_error_state(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def _quantize(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads, err):
    """Returns (decompressed grads, new error state)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quantize(gf)
        ghat = q.astype(jnp.float32) * scale
        return ghat, gf - ghat

    out = jax.tree.map(one, grads, err)
    ghat = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return ghat, new_err
