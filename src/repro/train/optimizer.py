"""AdamW with warmup-cosine schedule, global-norm clipping — pure JAX.

Optimizer state is a pytree congruent with params, so it inherits the same
FSDP shardings (ZeRO: m/v live sharded exactly like their parameters).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "adamw_update",
           "lr_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def init_opt_state(params) -> OptState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), z,
                    jax.tree.map(jnp.copy, z))


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * (
            p.astype(jnp.float32)
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_m, new_v), metrics
