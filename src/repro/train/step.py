"""train_step / serve_step builders — the functions the launcher lowers.

``build_train_step`` returns a pure function
    (params, opt_state, batch[, err]) -> (params, opt_state, metrics[, err])
with optional microbatch gradient accumulation (lax.scan over microbatches,
so peak activation memory is one microbatch) and optional int8
error-feedback gradient compression. Donation of params/opt_state is the
caller's business (launch/train.py passes donate_argnums).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.train import compression
from repro.train.optimizer import AdamWConfig, adamw_update

__all__ = ["build_train_step", "build_prefill_step", "build_decode_step"]


def build_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
    compress: bool = False,
):
    loss_fn = model.loss

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params, batch)
        return loss, metrics, grads

    def accumulate(params, batch):
        if microbatches == 1:
            return grads_of(params, batch)

        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])

        mb = jax.tree.map(split, batch)

        def body(carry, b):
            acc, loss_acc = carry
            loss, metrics, g = grads_of(params, b)
            acc = jax.tree.map(jnp.add, acc, g)
            return (acc, loss_acc + loss), metrics

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (gsum, loss_sum), metrics = jax.lax.scan(
            body, (zero, jnp.float32(0.0)), mb,
            unroll=True if model.cfg.scan_unroll else 1,
        )
        g = jax.tree.map(lambda a: a / microbatches, gsum)
        last_metrics = jax.tree.map(lambda a: a[-1], metrics)
        return loss_sum / microbatches, last_metrics, g

    if compress:
        def step(params, opt_state, batch, err):
            loss, metrics, grads = accumulate(params, batch)
            grads, err = compression.compress_grads(grads, err)
            params, opt_state, om = adamw_update(
                opt_cfg, params, grads, opt_state
            )
            out = {"loss": loss, **metrics, **om}
            return params, opt_state, out, err

        return step

    def step(params, opt_state, batch):
        loss, metrics, grads = accumulate(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state)
        out = {"loss": loss, **metrics, **om}
        return params, opt_state, out

    return step


def build_prefill_step(model: Model):
    def step(params, inputs):
        return model.prefill(params, **inputs)

    return step


def build_decode_step(model: Model, *, sample_top_k: int = 0):
    """serve_step for the decode shapes: one token for the whole batch
    against the KV/state cache, returning the next token ids + new cache."""

    def step(params, token, cache, pos):
        logits, cache = model.decode(params, token, cache, pos)
        logits = logits.reshape(logits.shape[0], -1)
        # mask the padded vocab tail
        cfg = model.cfg
        if cfg.padded_vocab != cfg.vocab:
            pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
            logits = jnp.where(pad[None, :], -jnp.inf, logits)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    return step
