"""Pallas TPU kernel: fused edge selection (the other half of the hop).

Each beam-search iteration improvises up to ``m_out`` out-edges per frontier
node (paper Algorithm 1). The XLA formulation gathers the full
``[F, (logn+1)*m]`` candidate-edge block into HBM before masking; at serving
batch sizes that gather plus the per-row dedup dominate the remaining hop
cost. Here the packed table ``nbrs[n, layers*m]`` stays un-blocked in
``ANY``/HBM space and the kernel row-DMAs only each frontier node's edge
block into a VMEM scratch (software-pipelined like ``gather_distance.py``,
``-1`` frontier slots skipped by predication), computes the
``segment_tree.scan_mask`` closed form in-kernel, and replaces the stable
argsort dedup with a sort-free formulation. Two dedup variants:

  * ``dedup="lazy"`` (default) — O(m_out·K): the priority-ordered
    top-``m_out`` runs as ``m_out`` masked argmin steps, and each step
    wipes *every* position holding the id it just selected, so later
    steps can only surface new ids. No ``[K, K]`` intermediate exists,
    VMEM stays flat in K. CPU measurements showed ~8.2x vs eager at
    K=288 (ROADMAP "lazy-vs-eager" decision — resolved in favor of lazy).
  * ``dedup="eager"`` — the historical **equality matrix**: a
    strictly-lower-triangular ``[K, K]`` ``id[i] == id[j]`` comparison
    marks non-first occurrences up front, then the same ``m_out`` argmin
    steps select. Kept selectable for A/B benchmarking.

Ids match ``kernels/ref.py::select_edges`` (and the historical argsort
formulation ``core/edge_select.py::select_edges_batch``) bit-for-bit in
both variants; the math is integer-exact, so parity is equality, not
tolerance.

VMEM residency: lazy keeps only the flat ``[bf, K]`` buffers, so the
default row tile is ``bf=8`` at every K. Eager's ``[bf, K, K]``
intermediates dominate (at ``bf=8``, K=288 the masks pad to
``8*288*384`` lanes, ~3.5 MB as i32; K=400 pads to 512 lanes, ~6.5 MB),
so eager auto-drops ``block_f`` to 4 above K=384 — the cap lazy lifts.
The gather scratch itself is tiny (``bf*K*4`` bytes). CPU/CI runs use
``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref as _ref

__all__ = ["edge_select_kernel_call"]


def _edge_select_kernel(
    meta_smem,   # SMEM [bf, 4] (u, L, R, pad) — DMA row indices
    meta_vmem,   # VMEM [bf, 4] (vectorized u/L/R)
    table_ref,   # ANY  [n, K]  (packed nbrs, never blocked)
    o_ref,       # VMEM [bf, m_out]
    xbuf,        # VMEM scratch [bf, K] gathered edge blocks
    sems,        # DMA semaphores [window]
    *, bf, K, m, logn, m_out, skip_layers, window, dedup,
):
    big = jnp.int32(2**30)

    def slot_u(t):
        return meta_smem[t, 0]

    def row_copy(t):
        return pltpu.make_async_copy(
            table_ref.at[slot_u(t)], xbuf.at[t], sems.at[t % window]
        )

    def start(t):
        @pl.when(slot_u(t) >= 0)
        def _():
            row_copy(t).start()

    def wait(t):
        @pl.when(slot_u(t) >= 0)
        def _():
            row_copy(t).wait()

    # software-pipelined gather: keep up to `window` row DMAs in flight
    def fill(t, carry):
        @pl.when(t >= window)
        def _():
            wait(t - window)

        start(t)
        return carry

    jax.lax.fori_loop(0, bf, fill, 0)

    def drain(t, carry):
        wait(t)
        return carry

    jax.lax.fori_loop(max(0, bf - window), bf, drain, 0)

    us = meta_vmem[:, 0:1]                                # [bf, 1]
    L = meta_vmem[:, 1:2]
    R = meta_vmem[:, 2:3]
    flat = xbuf[...]                                      # [bf, K]

    # scan-mask + in-range validity: the one shared closed form (Mosaic
    # needs the 2D broadcasted iota; everything inside is elementwise)
    lay = jax.lax.broadcasted_iota(jnp.int32, (bf, K), 1) // m
    valid = _ref.edge_scan_valid(
        flat, us, L, R, lay, logn=logn, skip_layers=skip_layers
    )

    # priority == flat position (upper layer first, then slot order)
    pos = jax.lax.broadcasted_iota(jnp.int32, (bf, K), 1)
    if dedup == "eager":
        # strictly-lower-triangular equality matrix marks non-first
        # occurrences up front (the [bf, K, K] VMEM hog)
        pos_i = jax.lax.broadcasted_iota(jnp.int32, (bf, K, K), 1)
        pos_j = jax.lax.broadcasted_iota(jnp.int32, (bf, K, K), 2)
        eq = (flat[:, :, None] == flat[:, None, :]) & valid[:, None, :]
        dup = jnp.any(eq & (pos_j < pos_i), axis=2)       # [bf, K]
        prio = jnp.where(valid & ~dup, pos, big)
    else:
        prio = jnp.where(valid, pos, big)

    # -- priority-ordered top-m_out: m_out masked argmin steps --------------
    outs = []
    for _ in range(m_out):
        pmin = jnp.min(prio, axis=1, keepdims=True)       # [bf, 1]
        sel = prio == pmin                                # one hit unless BIG
        idt = jnp.max(
            jnp.where(sel, flat, jnp.iinfo(jnp.int32).min),
            axis=1, keepdims=True,
        )
        out_t = jnp.where(pmin < big, idt, jnp.int32(-1))
        outs.append(out_t)
        if dedup == "eager":
            prio = jnp.where(sel, big, prio)
        else:
            # lazy: wipe every position holding the selected id so later
            # steps can only surface new ids — O(m_out*K), no [K, K]
            taken = (flat == out_t) & (prio < big)
            prio = jnp.where(sel | taken, big, prio)
    o_ref[...] = jnp.concatenate(outs, axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("logn", "m_out", "skip_layers", "block_f", "window",
                     "dedup", "interpret"),
)
def edge_select_kernel_call(
    nbrs, us, L, R, *, logn, m_out, skip_layers=True, block_f=None,
    window=8, dedup="lazy", interpret=False,
):
    """Fused per-hop edge improvisation (DESIGN.md §2/§3; oracle:
    ``ref.select_edges``).

    nbrs int16/int32[n, layers, m] (any compact neighbor width, -1
    sentinel; ``SplitNeighbors`` structs decode before dispatch in
    ``ops.select_edges``), us int32[F] (-1 masked), L/R scalars or
    int32[F] -> int32[F, m_out] improvised edges, -1 padded. Ids are
    bit-identical to the oracle across dtypes and backends.

    Pads F to the ``block_f`` row-tile multiple internally; the table is
    passed flattened ``[n, layers*m]`` so each frontier node is one
    contiguous row DMA. ``dedup`` picks "lazy" (default, O(m_out*K)) or
    "eager" (the [K, K] equality matrix, kept for A/B) — bit-identical ids.
    """
    if dedup not in ("lazy", "eager"):
        raise ValueError(
            f"edge_select: unknown dedup {dedup!r} "
            "(expected 'lazy' or 'eager')"
        )
    n, layers, m = nbrs.shape
    K = layers * m
    F = us.shape[0]
    us = us.astype(jnp.int32)
    L = jnp.broadcast_to(jnp.asarray(L, jnp.int32), us.shape)
    R = jnp.broadcast_to(jnp.asarray(R, jnp.int32), us.shape)
    # lazy dedup has no [bf, K, K] intermediate, so the row tile no longer
    # shrinks above K=384
    if block_f is not None:
        bf = block_f
    elif dedup == "lazy":
        bf = 8
    else:
        bf = 8 if K <= 384 else 4

    meta = jnp.stack(
        [us, L, R, jnp.zeros_like(us)], axis=1
    )                                                     # [F, 4]
    r = (-F) % bf
    if r:
        pad = jnp.full((r, 4), -1, jnp.int32)
        meta = jnp.concatenate([meta, pad], axis=0)
    Fp = meta.shape[0]
    grid = (Fp // bf,)

    out = pl.pallas_call(
        functools.partial(
            _edge_select_kernel, bf=bf, K=K, m=m, logn=logn, m_out=m_out,
            skip_layers=skip_layers, window=min(window, bf), dedup=dedup,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bf, 4), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bf, 4), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((bf, m_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Fp, m_out), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((bf, K), jnp.int32),
            pltpu.SemaphoreType.DMA((min(window, bf),)),
        ],
        interpret=interpret,
    )(meta, meta, nbrs.reshape(n, K))
    return out[:F]
