"""Pallas TPU kernel: tiled pairwise distance (the RFANN compute hot spot).

``d(q, x) = ||q||^2 - 2 q.x + ||x||^2`` expressed as an MXU matmul with the
norm terms fused into the accumulation — each K-chunk contributes its partial
dot product *and* its partial norms, so the result is exact without a second
pass over HBM.

Grid: ``(Bq/bq, N/bn, D/bk)`` with the reduction dim innermost; the
``(bq, bn)`` f32 output tile lives in VMEM across the K-loop (revisited
blocks). Default tiles (128, 128, 512) mean VMEM residency of
``2*128*512*4B (operands) + 128*128*4B (acc) ≈ 0.6 MB`` — comfortably within
the ~16 MB/core budget, and both matmul dims are multiples of the 128-wide
MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pairwise_dist_kernel_call"]


def _dist_kernel(q_ref, x_ref, o_ref, *, metric, nk):
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)  # [bq, bk]
    x = x_ref[...].astype(jnp.float32)  # [bn, bk]
    dot = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    if metric == "ip":
        o_ref[...] += -dot
    else:
        qq = jnp.sum(q * q, axis=1)
        xx = jnp.sum(x * x, axis=1)
        o_ref[...] += qq[:, None] - 2.0 * dot + xx[None, :]


@functools.partial(
    jax.jit,
    static_argnames=("metric", "block_q", "block_n", "block_k", "interpret"),
)
def pairwise_dist_kernel_call(
    q, x, *, metric="l2", block_q=128, block_n=128, block_k=512,
    interpret=False,
):
    """Tiled all-pairs distance (DESIGN.md §3; oracle: ``ref.pairwise_dist``).

    q[Bq, D], x[N, D] (f32/bf16/f16 — upcast in-register, math f32; the
    quantized codec structs go through ``gather_distance.py``/``hop.py``,
    not this dense kernel) -> f32[Bq, N] with ``metric`` "l2" (squared) or
    "ip" (negated). Pads every dim to its block multiple internally.
    """
    Bq, D = q.shape
    N, _ = x.shape
    bq = min(block_q, max(8, Bq))
    bn = min(block_n, max(8, N))
    bk = min(block_k, D)

    def pad(a, mult, axis):
        r = (-a.shape[axis]) % mult
        if r == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, r)
        return jnp.pad(a, widths)

    qp = pad(pad(q, bq, 0), bk, 1)
    xp = pad(pad(x, bn, 0), bk, 1)
    grid = (qp.shape[0] // bq, xp.shape[0] // bn, qp.shape[1] // bk)

    out = pl.pallas_call(
        functools.partial(_dist_kernel, metric=metric, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], xp.shape[0]), jnp.float32),
        interpret=interpret,
    )(qp, xp)
    return out[:Bq, :N]
