"""Pallas TPU kernel: fused gather + masked distance (the beam-search hop).

DESIGN.md §3 (hot path) and §9 (codec decode). Each beam-search iteration
needs distances from ``B`` queries to the ``M`` neighbors just pulled from
the improvised graph — ids ``int32[B, M]`` with ``-1`` marking masked slots.
The XLA formulation materializes the gathered ``[B, M, d]`` tensor in HBM
before the einsum; at serving batch sizes that intermediate dominates the
hop's HBM traffic. Here the gather lands directly in VMEM: per ``(bb, bm)``
tile the kernel row-DMAs only the *valid* vector rows from the table (kept
whole in ``ANY``/HBM space, never blocked) into a VMEM scratch, overlapping
up to ``window`` copies, then emits masked ``f32[bb, bm]`` distances off one
MXU matmul — no ``[B, M, d]`` intermediate ever exists.

**Codec decode happens here, in VMEM registers** (§9): the table may be a
``storage.Int8Vectors`` (the DMA moves int8 rows; the kernel multiplies by
the pre-gathered per-row scales) or a ``storage.PQVectors`` (the DMA moves
uint8 code rows; the kernel looks the codebook — resident in VMEM — up per
subspace). The decoded f32 rows exist only in the register file /
scratch-local values; no widened table ever hits HBM, so the footprint
saving is also a hop-bandwidth saving.

Shape contract: ``q f32[B, d]``, ``table [n, d]`` float dtypes or codec
struct, ``ids int32[B, M]`` -> ``f32[B, M]``. Math matches
``kernels/ref.py::gather_dist`` (and the historical inline ``_pairdist``)
bit-for-bit in f32 under identical fusion: ``||x||^2 - 2 x.q + ||q||^2``
for l2, ``-x.q`` for ip; invalid slots return ``+inf``.

VMEM residency per program is ``bb*bm*row_bytes`` for the gather scratch
(default tiles 8x128: 0.5 MB at f32 d=128, 128 KB at int8) plus the query
tile and, for PQ, the ``[M*256, dsub]`` codebook (128 KB at d=128, M=32).
The codec tiles are autotuned separately (``kind="gather_dist_codec"``,
``kernels/autotune.py``). CPU/CI runs use ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import storage as _storage

__all__ = ["gather_distance_kernel_call"]


def _gather_dist_kernel(
    q_ref,       # VMEM [bb, dp]
    ids_smem,    # SMEM [bb, bm] (DMA row indices)
    ids_vmem,    # VMEM [bb, bm] (vectorized mask)
    *refs,       # table_ref (ANY [n, w]), [aux_ref], o_ref, xbuf, sems
    bb, bm, metric, window, codec, dp, pq_m, pq_dsub,
):
    if codec is None:
        table_ref, o_ref, xbuf, sems = refs
    else:
        table_ref, aux_ref, o_ref, xbuf, sems = refs
    total = bb * bm

    def slot_id(t):
        return ids_smem[t // bm, t % bm]

    def row_copy(t):
        return pltpu.make_async_copy(
            table_ref.at[slot_id(t)], xbuf.at[t], sems.at[t % window]
        )

    def start(t):
        @pl.when(slot_id(t) >= 0)
        def _():
            row_copy(t).start()

    def wait(t):
        @pl.when(slot_id(t) >= 0)
        def _():
            row_copy(t).wait()

    # software-pipelined gather: keep up to `window` row DMAs in flight
    def fill(t, carry):
        @pl.when(t >= window)
        def _():
            wait(t - window)

        start(t)
        return carry

    jax.lax.fori_loop(0, total, fill, 0)

    def drain(t, carry):
        wait(t)
        return carry

    jax.lax.fori_loop(max(0, total - window), total, drain, 0)

    q = q_ref[...].astype(jnp.float32)       # [bb, dp]
    # codec decode, in-register (§9): xbuf holds the *stored* rows
    if codec == "int8":
        x = xbuf[...].astype(jnp.float32)                 # [bb*bm, dp]
        x = x * aux_ref[...].reshape(total, 1)            # per-row scales
    elif codec == "pq":
        codes = xbuf[...][:, :pq_m].astype(jnp.int32)     # [bb*bm, M]
        sub = jax.lax.broadcasted_iota(jnp.int32, (total, pq_m), 1)
        idx = codes + sub * _storage.PQ_CENTROIDS
        x = jnp.take(aux_ref[...], idx.reshape(-1), axis=0)
        x = x.reshape(total, pq_m * pq_dsub)
        pad = dp - pq_m * pq_dsub
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((total, pad), jnp.float32)], axis=1)
    else:
        x = xbuf[...].astype(jnp.float32)                 # [bb*bm, dp]
    # one MXU pass against every query in the tile, then keep the diagonal
    # query<->row pairing (overcompute factor bb is tiny next to the gather)
    dots = jax.lax.dot_general(
        x, q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ).reshape(bb, bm, bb)
    row_q = jax.lax.broadcasted_iota(jnp.int32, (bb, bm, bb), 0)
    col_q = jax.lax.broadcasted_iota(jnp.int32, (bb, bm, bb), 2)
    dot = jnp.sum(jnp.where(row_q == col_q, dots, 0.0), axis=2)  # [bb, bm]

    if metric == "ip":
        out = -dot
    else:
        xx = jnp.sum(x * x, axis=1).reshape(bb, bm)
        qq = jnp.sum(q * q, axis=1)
        out = xx - 2.0 * dot + qq[:, None]
    valid = ids_vmem[...] >= 0
    o_ref[...] = jnp.where(valid, out, jnp.inf)


@functools.partial(
    jax.jit,
    static_argnames=("metric", "block_b", "block_m", "window", "interpret"),
)
def gather_distance_kernel_call(
    q, table, ids, *, metric="l2", block_b=8, block_m=128, window=16,
    interpret=False,
):
    """q[B, d], table ([n, d] float / Int8Vectors / PQVectors), ids
    int32[B, M] (-1 masked) -> f32[B, M].

    Distances from query b to the decoded table[ids[b, j]]; +inf where
    ids < 0. Pads B/M to tile multiples and the stored row width to the 128
    lane width internally (zero columns are exact for both metrics). For
    ``Int8Vectors`` the per-row scales are pre-gathered outside the kernel
    (ids are known at call time) and ride in as a ``[bb, bm]`` f32 tile; for
    ``PQVectors`` the flattened codebook is a VMEM-resident input and codes
    decode in-register after the DMA.
    """
    B, d = q.shape
    M = ids.shape[1]
    bb = min(block_b, max(8, B))
    bm = 128 if M <= 128 else min(block_m, M)

    def pad_to(a, mult, axis, value=0):
        r = (-a.shape[axis]) % mult
        if r == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, r)
        return jnp.pad(a, widths, constant_values=value)

    qp = pad_to(pad_to(q, bb, 0), 128, 1)
    idp = pad_to(pad_to(ids, bb, 0, value=-1), bm, 1, value=-1)
    dp = qp.shape[1]
    grid = (qp.shape[0] // bb, idp.shape[1] // bm)

    codec, aux, aux_spec, pq_m, pq_dsub = None, None, None, 0, 0
    if isinstance(table, _storage.Int8Vectors):
        codec = "int8"
        tbl = pad_to(table.codes, 128, 1)
        scales = table.scales[jnp.maximum(ids, 0)].astype(jnp.float32)
        aux = pad_to(pad_to(scales, bb, 0), bm, 1)
        aux_spec = pl.BlockSpec((bb, bm), lambda i, j: (i, j))
        xbuf_shape = (bb * bm, tbl.shape[1])
    elif isinstance(table, _storage.PQVectors):
        codec = "pq"
        pq_m, _, pq_dsub = table.codebook.shape
        tbl = pad_to(table.codes, 128, 1)
        aux = table.codebook.reshape(pq_m * _storage.PQ_CENTROIDS, pq_dsub)
        aux_spec = pl.BlockSpec(aux.shape, lambda i, j: (0, 0))
        xbuf_shape = (bb * bm, tbl.shape[1])
    else:
        tbl = pad_to(table, 128, 1)
        xbuf_shape = (bb * bm, dp)

    in_specs = [
        pl.BlockSpec((bb, dp), lambda i, j: (i, 0)),
        pl.BlockSpec((bb, bm), lambda i, j: (i, j),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    args = [qp, idp, idp, tbl]
    if codec is not None:
        in_specs.append(aux_spec)
        args.append(aux)

    out = pl.pallas_call(
        functools.partial(
            _gather_dist_kernel, bb=bb, bm=bm, metric=metric,
            window=min(window, bb * bm), codec=codec, dp=dp,
            pq_m=pq_m, pq_dsub=pq_dsub,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], idp.shape[1]),
                                       jnp.float32),
        scratch_shapes=[
            pltpu.VMEM(xbuf_shape, tbl.dtype),
            pltpu.SemaphoreType.DMA((min(window, bb * bm),)),
        ],
        interpret=interpret,
    )(*args)
    return out[:B, :M]
