"""Pallas TPU kernel: fused gather + masked distance (the beam-search hop).

Each beam-search iteration needs distances from ``B`` queries to the ``M``
neighbors just pulled from the improvised graph — ids ``int32[B, M]`` with
``-1`` marking masked slots. The XLA formulation materializes the gathered
``[B, M, d]`` tensor in HBM before the einsum; at serving batch sizes that
intermediate dominates the hop's HBM traffic. Here the gather lands directly
in VMEM: per ``(bb, bm)`` tile the kernel row-DMAs only the *valid* vector
rows from the table (kept whole in ``ANY``/HBM space, never blocked) into a
VMEM scratch, overlapping up to ``window`` copies, then emits masked
``f32[bb, bm]`` distances off one MXU matmul — no ``[B, M, d]`` intermediate
ever exists.

Math matches ``kernels/ref.py::gather_dist`` (and the historical inline
``_pairdist``) bit-for-bit in f32: ``||x||^2 - 2 x.q + ||q||^2`` for l2,
``-x.q`` for ip; invalid slots return ``+inf``.

VMEM residency per program is ``bb*bm*d_pad*4B`` for the gather scratch
(default tiles 8x128 at d=128: 0.5 MB) plus the query tile; lower ``block_m``
for very large ``d``. CPU/CI runs use ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gather_distance_kernel_call"]


def _gather_dist_kernel(
    q_ref,       # VMEM [bb, d]
    ids_smem,    # SMEM [bb, bm] (DMA row indices)
    ids_vmem,    # VMEM [bb, bm] (vectorized mask)
    table_ref,   # ANY  [n, d]   (full table, never blocked)
    o_ref,       # VMEM [bb, bm]
    xbuf,        # VMEM scratch [bb*bm, d]
    sems,        # DMA semaphores [window]
    *, bb, bm, metric, window,
):
    total = bb * bm

    def slot_id(t):
        return ids_smem[t // bm, t % bm]

    def row_copy(t):
        return pltpu.make_async_copy(
            table_ref.at[slot_id(t)], xbuf.at[t], sems.at[t % window]
        )

    def start(t):
        @pl.when(slot_id(t) >= 0)
        def _():
            row_copy(t).start()

    def wait(t):
        @pl.when(slot_id(t) >= 0)
        def _():
            row_copy(t).wait()

    # software-pipelined gather: keep up to `window` row DMAs in flight
    def fill(t, carry):
        @pl.when(t >= window)
        def _():
            wait(t - window)

        start(t)
        return carry

    jax.lax.fori_loop(0, total, fill, 0)

    def drain(t, carry):
        wait(t)
        return carry

    jax.lax.fori_loop(max(0, total - window), total, drain, 0)

    q = q_ref[...].astype(jnp.float32)       # [bb, d]
    x = xbuf[...].astype(jnp.float32)        # [bb*bm, d]
    # one MXU pass against every query in the tile, then keep the diagonal
    # query<->row pairing (overcompute factor bb is tiny next to the gather)
    dots = jax.lax.dot_general(
        x, q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ).reshape(bb, bm, bb)
    row_q = jax.lax.broadcasted_iota(jnp.int32, (bb, bm, bb), 0)
    col_q = jax.lax.broadcasted_iota(jnp.int32, (bb, bm, bb), 2)
    dot = jnp.sum(jnp.where(row_q == col_q, dots, 0.0), axis=2)  # [bb, bm]

    if metric == "ip":
        out = -dot
    else:
        xx = jnp.sum(x * x, axis=1).reshape(bb, bm)
        qq = jnp.sum(q * q, axis=1)
        out = xx - 2.0 * dot + qq[:, None]
    valid = ids_vmem[...] >= 0
    o_ref[...] = jnp.where(valid, out, jnp.inf)


@functools.partial(
    jax.jit,
    static_argnames=("metric", "block_b", "block_m", "window", "interpret"),
)
def gather_distance_kernel_call(
    q, table, ids, *, metric="l2", block_b=8, block_m=128, window=16,
    interpret=False,
):
    """q[B, d], table[n, d], ids int32[B, M] (-1 masked) -> f32[B, M].

    Distances from query b to table[ids[b, j]]; +inf where ids < 0. Pads B/M
    to tile multiples and d to the 128 lane width internally (zero columns
    are exact for both metrics).
    """
    B, d = q.shape
    n, _ = table.shape
    M = ids.shape[1]
    bb = min(block_b, max(8, B))
    bm = 128 if M <= 128 else min(block_m, M)

    def pad_to(a, mult, axis, value=0):
        r = (-a.shape[axis]) % mult
        if r == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, r)
        return jnp.pad(a, widths, constant_values=value)

    qp = pad_to(pad_to(q, bb, 0), 128, 1)
    tp = pad_to(table, 128, 1)
    idp = pad_to(pad_to(ids, bb, 0, value=-1), bm, 1, value=-1)
    dp = qp.shape[1]
    grid = (qp.shape[0] // bb, idp.shape[1] // bm)

    out = pl.pallas_call(
        functools.partial(
            _gather_dist_kernel, bb=bb, bm=bm, metric=metric,
            window=min(window, bb * bm),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, bm), lambda i, j: (i, j),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], idp.shape[1]),
                                       jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bb * bm, dp), table.dtype),
            pltpu.SemaphoreType.DMA((min(window, bb * bm),)),
        ],
        interpret=interpret,
    )(qp, idp, idp, tp)
    return out[:B, :M]
