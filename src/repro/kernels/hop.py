"""Pallas TPU megakernel: one whole beam-search hop in a single launch.

Each beam-search iteration used to be three kernel launches — edge
selection (``kernels/edge_select.py``), the packed-bitset visited update
(``core/bitset.py``), and gather-distance (``kernels/gather_distance.py``)
— with the frontier round-tripping through HBM between them: the improvised
edges land in HBM, get re-read by the bitset scatter, and the surviving ids
get re-read again to drive the vector gather. This kernel fuses the whole
hop so the frontier never leaves VMEM:

  1. **edge gather** — per ``(bb)`` query tile the packed neighbor table
     stays un-blocked in ``ANY``/HBM space and the kernel row-DMAs each of
     the ``bb*W`` frontier nodes' ``K = (logn+1)*m`` edge blocks into a
     VMEM scratch (software-pipelined, up to ``window`` copies in flight,
     ``-1`` frontier slots skipped by predication);
  2. **edge selection** — the ``segment_tree.scan_mask`` closed form
     (``ref.edge_scan_valid``) plus the *lazy* O(m_out·K) dedup: ``m_out``
     masked-argmin steps, each wiping every position holding the id it just
     selected — no ``[K, K]`` equality matrix, so VMEM stays flat in K;
  3. **visited test-and-set** — the query tile's ``uint32[bb, words]``
     bitset rows live in VMEM for the whole launch; membership is
     shift/mask arithmetic, in-row dedup is the same strictly-earlier
     equality mask as ``core/bitset.py``, and the updated rows are written
     back once at the end (single-bit masks scatter-add, exact OR after
     dedup);
  4. **vector gather + distance** — the surviving (newly-visited) ids DMA
     their vector rows straight from the un-blocked table into a VMEM
     scratch and one MXU matmul emits masked f32 distances, exactly the
     ``gather_distance.py`` structure.

Semantics are ``kernels/ref.py::hop`` (select_edges -> bitset.test_and_set
-> gather_dist): integer outputs (edges, newly-visited mask, bitset words)
must match bit-for-bit, distances to f32 tolerance.

VMEM residency per program: the vector scratch ``bb*W*m_out*d_pad`` rows
dominate (defaults bb=4, W=4, m_out=16, d=128: 128 KB f32), plus the edge
scratch ``bb*W*K*4`` bytes and the bitset tile ``bb*ceil(n/32)*4`` bytes —
the bitset tile grows with n, so the autotuner (``kernels/autotune.py``)
drops ``block_b`` for very large n. CPU/CI runs use ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import storage as _storage
from repro.kernels import ref as _ref

__all__ = ["hop_kernel_call"]

# plain Python ints so the kernel body inlines them as literals (Pallas
# rejects closure-captured traced constants)
_BIG = 2**30
_IMIN = -(2**31)


def _hop_kernel(
    meta_smem,   # SMEM [bb, 4*W] (u | L | R | exp) — DMA row indices
    meta_vmem,   # VMEM [bb, 4*W] (vectorized u/L/R/exp)
    q_ref,       # VMEM [bb, dp]
    vis_ref,     # VMEM [bb, words] (query tile's bitset rows)
    nbrs_ref,    # ANY  [n, K]  (packed edge table, never blocked)
    table_ref,   # ANY  [n, w]  (vector table / code table, never blocked)
    *refs,       # [aux_ref], outputs, ebuf, xbuf, [sbuf], sems, [sems2]
    bb, W, K, m, m_out, logn, skip_layers, metric, window,
    codec, dp, pq_m, pq_dsub,
):
    if codec is None:
        nbr_out, dist_out, nvalid_out, vis_out, ebuf, xbuf, sems = refs
    elif codec == "int8":
        (aux_ref, nbr_out, dist_out, nvalid_out, vis_out,
         ebuf, xbuf, sbuf, sems, sems2) = refs
    else:  # pq
        (aux_ref, nbr_out, dist_out, nvalid_out, vis_out,
         ebuf, xbuf, sems) = refs
    WM = W * m_out
    F = bb * W

    # -- 1. pipelined edge-block gather (one row DMA per frontier node) -----
    def edge_u(t):
        return meta_smem[t // W, t % W]

    def edge_copy(t):
        return pltpu.make_async_copy(
            nbrs_ref.at[edge_u(t)], ebuf.at[t], sems.at[t % window]
        )

    def edge_fill(t, carry):
        @pl.when(t >= window)
        def _():
            @pl.when(edge_u(t - window) >= 0)
            def _():
                edge_copy(t - window).wait()

        @pl.when(edge_u(t) >= 0)
        def _():
            edge_copy(t).start()

        return carry

    jax.lax.fori_loop(0, F, edge_fill, 0)

    def edge_drain(t, carry):
        @pl.when(edge_u(t) >= 0)
        def _():
            edge_copy(t).wait()

        return carry

    jax.lax.fori_loop(max(0, F - window), F, edge_drain, 0)

    # -- 2. edge selection: scan-mask validity + lazy O(m_out*K) dedup ------
    us = meta_vmem[:, 0 * W:1 * W].reshape(F, 1)
    L = meta_vmem[:, 1 * W:2 * W].reshape(F, 1)
    R = meta_vmem[:, 2 * W:3 * W].reshape(F, 1)
    exp_ok = meta_vmem[:, 3 * W:4 * W] != 0               # [bb, W]
    flat = ebuf[...]                                      # [F, K]

    lay = jax.lax.broadcasted_iota(jnp.int32, (F, K), 1) // m
    valid = _ref.edge_scan_valid(
        flat, us, L, R, lay, logn=logn, skip_layers=skip_layers
    )

    # priority == flat position (upper layer first, then slot order); the
    # lazy dedup wipes every position holding a selected id, so later steps
    # can only surface new ids — bit-identical to the eager [K, K] matrix
    pos = jax.lax.broadcasted_iota(jnp.int32, (F, K), 1)
    prio = jnp.where(valid, pos, _BIG)
    outs = []
    for _ in range(m_out):
        pmin = jnp.min(prio, axis=1, keepdims=True)       # [F, 1]
        sel = prio == pmin
        idt = jnp.max(jnp.where(sel, flat, _IMIN), axis=1, keepdims=True)
        out_t = jnp.where(pmin < _BIG, idt, jnp.int32(-1))
        outs.append(out_t)
        taken = (flat == out_t) & (prio < _BIG)
        prio = jnp.where(sel | taken, _BIG, prio)
    edges = jnp.concatenate(outs, axis=1).reshape(bb, WM)
    nbr_out[...] = edges

    # -- 3. visited test-and-set, bitset rows resident in VMEM --------------
    pre_valid = edges >= 0
    pre_valid &= jnp.repeat(exp_ok, m_out, axis=1)        # [bb, WM]
    safe = jnp.maximum(edges, 0)
    word_idx = safe >> 5
    shift = (safe & 31).astype(jnp.uint32)
    vis = vis_ref[...]                                    # [bb, words]
    word = jnp.take_along_axis(vis, word_idx, axis=1)
    seen = ((word >> shift) & jnp.uint32(1)) == 1
    seen &= pre_valid
    # first occurrence wins within a row (same id from two expansions)
    j_pos = jax.lax.broadcasted_iota(jnp.int32, (bb, WM, WM), 1)
    i_pos = jax.lax.broadcasted_iota(jnp.int32, (bb, WM, WM), 2)
    eq = (safe[:, :, None] == safe[:, None, :]) \
        & pre_valid[:, :, None] & pre_valid[:, None, :]
    dup = jnp.any(eq & (i_pos < j_pos), axis=2)           # [bb, WM]
    new = pre_valid & ~seen & ~dup
    nvalid = pre_valid & ~(seen | dup)
    # single-bit masks are unique (row, word, bit) after dedup: add == OR
    mask = jnp.where(new, jnp.uint32(1) << shift, jnp.uint32(0))
    rows = jax.lax.broadcasted_iota(jnp.int32, (bb, WM), 0)
    vis_out[...] = vis.at[rows, word_idx].add(mask)
    nvalid_out[...] = nvalid.astype(jnp.int32)

    # -- 4. pipelined vector gather for the newly-visited ids ---------------
    gids = jnp.where(nvalid, edges, -1).reshape(bb * WM)

    def vec_id(t):
        return gids[t]

    def vec_copy(t):
        return pltpu.make_async_copy(
            table_ref.at[vec_id(t)], xbuf.at[t], sems.at[t % window]
        )

    def scale_copy(t):
        # int8 only: ids are discovered in-kernel, so the per-row scales
        # must ride a parallel DMA (aux_ref is ANY [n, 1] f32)
        return pltpu.make_async_copy(
            aux_ref.at[vec_id(t)], sbuf.at[t], sems2.at[t % window]
        )

    def vec_fill(t, carry):
        @pl.when(t >= window)
        def _():
            @pl.when(vec_id(t - window) >= 0)
            def _():
                vec_copy(t - window).wait()
                if codec == "int8":
                    scale_copy(t - window).wait()

        @pl.when(vec_id(t) >= 0)
        def _():
            vec_copy(t).start()
            if codec == "int8":
                scale_copy(t).start()

        return carry

    jax.lax.fori_loop(0, bb * WM, vec_fill, 0)

    def vec_drain(t, carry):
        @pl.when(vec_id(t) >= 0)
        def _():
            vec_copy(t).wait()
            if codec == "int8":
                scale_copy(t).wait()

        return carry

    jax.lax.fori_loop(max(0, bb * WM - window), bb * WM, vec_drain, 0)

    # -- distance: one MXU pass, keep the diagonal query<->row pairing ------
    q = q_ref[...].astype(jnp.float32)                    # [bb, dp]
    # codec decode, in-register (DESIGN.md §9): xbuf holds the stored rows
    if codec == "int8":
        x = xbuf[...].astype(jnp.float32)                 # [bb*WM, w]
        x = x * sbuf[...].reshape(bb * WM, 1)             # per-row scales
    elif codec == "pq":
        codes = xbuf[...][:, :pq_m].astype(jnp.int32)     # [bb*WM, M]
        sub = jax.lax.broadcasted_iota(jnp.int32, (bb * WM, pq_m), 1)
        idx = codes + sub * _storage.PQ_CENTROIDS
        x = jnp.take(aux_ref[...], idx.reshape(-1), axis=0)
        x = x.reshape(bb * WM, pq_m * pq_dsub)
        pad = dp - pq_m * pq_dsub
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((bb * WM, pad), jnp.float32)], axis=1)
    else:
        x = xbuf[...].astype(jnp.float32)                 # [bb*WM, dp]
    dots = jax.lax.dot_general(
        x, q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ).reshape(bb, WM, bb)
    row_q = jax.lax.broadcasted_iota(jnp.int32, (bb, WM, bb), 0)
    col_q = jax.lax.broadcasted_iota(jnp.int32, (bb, WM, bb), 2)
    dot = jnp.sum(jnp.where(row_q == col_q, dots, 0.0), axis=2)  # [bb, WM]
    if metric == "ip":
        out = -dot
    else:
        xx = jnp.sum(x * x, axis=1).reshape(bb, WM)
        qq = jnp.sum(q * q, axis=1)
        out = xx - 2.0 * dot + qq[:, None]
    dist_out[...] = jnp.where(nvalid, out, jnp.inf)


@functools.partial(
    jax.jit,
    static_argnames=("logn", "m_out", "skip_layers", "metric", "block_b",
                     "window", "interpret"),
)
def hop_kernel_call(
    q, table, nbrs, u, L, R, visited, exp_ok, *, logn, m_out,
    skip_layers=True, metric="l2", block_b=4, window=8, interpret=False,
):
    """One fused whole-hop launch (DESIGN.md §3). See ``kernels/ref.py::hop``
    for the semantic contract and shapes: q f32[B, d], table ([n, d] float /
    Int8Vectors / PQVectors), nbrs int32[n, layers, m] (pre-decoded), u
    int32[B, W], L/R int32[B*W], visited uint32[B, words], exp_ok
    bool[B, W]. Returns ``(nbr, ndist, nvalid, visited')``.

    Pads B to the ``block_b`` tile multiple and the stored row width to the
    128 lane width internally (zero columns are exact for both metrics);
    the edge and vector tables pass flattened/un-blocked so every gather is
    one contiguous row DMA. Codec decode happens in-register after the DMA
    (DESIGN.md §9). Because the gathered ids are *discovered inside* the
    kernel, the int8 per-row scales cannot be pre-gathered like
    ``gather_distance.py`` does — they ride as an ``ANY [n, 1]`` f32 input
    with a parallel per-row DMA (second semaphore array) into a
    ``[bb*WM, 1]`` scratch; the PQ codebook is a VMEM-resident input.
    """
    B, d = q.shape
    n, layers, m = nbrs.shape
    K = layers * m
    W = u.shape[1]
    words = visited.shape[1]
    bb = max(1, min(block_b, B))

    def pad_to(a, mult, axis, value=0):
        r = (-a.shape[axis]) % mult
        if r == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, r)
        return jnp.pad(a, widths, constant_values=value)

    meta = jnp.concatenate(
        [
            u.astype(jnp.int32),
            L.astype(jnp.int32).reshape(B, W),
            R.astype(jnp.int32).reshape(B, W),
            exp_ok.astype(jnp.int32),
        ],
        axis=1,
    )                                                     # [B, 4W]
    meta = pad_to(meta, bb, 0, value=-1)
    qp = pad_to(pad_to(q, bb, 0), 128, 1)
    vp = pad_to(visited, bb, 0)
    dp = qp.shape[1]
    Bp = meta.shape[0]
    grid = (Bp // bb,)
    WM = W * m_out
    win = max(1, min(window, bb * W))

    codec, aux, aux_spec, pq_m, pq_dsub = None, None, None, 0, 0
    if isinstance(table, _storage.Int8Vectors):
        codec = "int8"
        tp = pad_to(table.codes, 128, 1)
        aux = table.scales.astype(jnp.float32).reshape(n, 1)
        aux_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    elif isinstance(table, _storage.PQVectors):
        codec = "pq"
        pq_m, _, pq_dsub = table.codebook.shape
        tp = pad_to(table.codes, 128, 1)
        aux = table.codebook.reshape(pq_m * _storage.PQ_CENTROIDS, pq_dsub)
        aux_spec = pl.BlockSpec(aux.shape, lambda i: (0, 0))
    else:
        tp = pad_to(table, 128, 1)

    in_specs = [
        pl.BlockSpec((bb, 4 * W), lambda i: (i, 0),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((bb, 4 * W), lambda i: (i, 0)),
        pl.BlockSpec((bb, dp), lambda i: (i, 0)),
        pl.BlockSpec((bb, words), lambda i: (i, 0)),
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    args = [meta, meta, qp, vp, nbrs.reshape(n, K), tp]
    if codec is not None:
        in_specs.append(aux_spec)
        args.append(aux)

    scratch_shapes = [
        pltpu.VMEM((bb * W, K), jnp.int32),
        pltpu.VMEM((bb * WM, tp.shape[1]), tp.dtype),
    ]
    if codec == "int8":
        scratch_shapes.append(pltpu.VMEM((bb * WM, 1), jnp.float32))
    scratch_shapes.append(pltpu.SemaphoreType.DMA((win,)))
    if codec == "int8":
        scratch_shapes.append(pltpu.SemaphoreType.DMA((win,)))

    nbr, dist, nvalid, vis = pl.pallas_call(
        functools.partial(
            _hop_kernel, bb=bb, W=W, K=K, m=m, m_out=m_out, logn=logn,
            skip_layers=skip_layers, metric=metric, window=win,
            codec=codec, dp=dp, pq_m=pq_m, pq_dsub=pq_dsub,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bb, WM), lambda i: (i, 0)),
            pl.BlockSpec((bb, WM), lambda i: (i, 0)),
            pl.BlockSpec((bb, WM), lambda i: (i, 0)),
            pl.BlockSpec((bb, words), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, WM), jnp.int32),
            jax.ShapeDtypeStruct((Bp, WM), jnp.float32),
            jax.ShapeDtypeStruct((Bp, WM), jnp.int32),
            jax.ShapeDtypeStruct((Bp, words), jnp.uint32),
        ],
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(*args)
    return nbr[:B], dist[:B], nvalid[:B] != 0, vis[:B]
