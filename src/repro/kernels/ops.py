"""Jit'd public wrappers around the Pallas kernels.

On a TPU backend the Mosaic kernels run natively; everywhere else (this CPU
container, tests) they run in ``interpret=True`` mode unless the caller asks
for the pure-XLA reference instead. ``impl`` selection:

  * "pallas"    — pallas_call, interpret on non-TPU backends
  * "xla"       — ref.py jnp implementation (what the multi-pod dry-run
                  lowers, since Mosaic cannot lower on the CPU host platform)
  * "auto"      — pallas on TPU else xla; overridable per-op via the
                  ``REPRO_DIST_IMPL`` / ``REPRO_EDGE_IMPL`` env vars, or
                  globally via ``REPRO_IMPL`` (the CI backend matrix)
  * "argsort"   — edge selection only: the historical stable-argsort
                  formulation (``core/edge_select.py``), kept for regression
                  benchmarking

``select_edges`` is integer-exact: all three backends return bit-identical
ids. ``gather_dist`` backends agree to f32 tolerance (and bit-exactly under
identical fusion).
"""
from __future__ import annotations

import os

import jax

from repro.core import edge_select as _legacy_edge_select
from repro.kernels import distance as _distance
from repro.kernels import edge_select as _edge_select
from repro.kernels import flash_attention as _flash
from repro.kernels import gather_distance as _gather
from repro.kernels import ref as _ref

__all__ = [
    "pairwise_dist", "gather_dist", "select_edges", "flash_attention",
    "default_impl",
]


def default_impl(kind: str | None = None) -> str:
    """Backend for ``impl="auto"``: pallas on TPU, xla elsewhere.

    ``kind`` ("dist" | "edge" | ...) checks ``REPRO_<KIND>_IMPL`` first,
    then the global ``REPRO_IMPL`` — the hook the CI backend matrix uses to
    force every auto dispatch through one backend.
    """
    if kind:
        forced = os.environ.get(f"REPRO_{kind.upper()}_IMPL")
        if forced:
            return forced
    forced = os.environ.get("REPRO_IMPL")
    if forced:
        return forced
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pairwise_dist(q, x, *, metric="l2", impl="auto", **block_kw):
    if impl == "auto":
        impl = default_impl("dist")
    if impl == "xla":
        return _ref.pairwise_dist(q, x, metric=metric)
    return _distance.pairwise_dist_kernel_call(
        q, x, metric=metric, interpret=_interpret(), **block_kw
    )


def gather_dist(q, table, ids, *, metric="l2", impl="auto", **block_kw):
    """Fused gather + masked distance for the beam-search hop.

    "pallas" runs the Mosaic kernel (no [B, M, d] intermediate); "xla" is the
    gather+einsum reference, which is also what "auto" picks off-TPU.
    """
    if impl == "auto":
        impl = default_impl("dist")
    if impl == "xla":
        return _ref.gather_dist(q, table, ids, metric=metric)
    return _gather.gather_distance_kernel_call(
        q, table, ids, metric=metric, interpret=_interpret(), **block_kw
    )


def select_edges(nbrs, us, L, R, *, logn, m_out, skip_layers=True,
                 impl="auto", **block_kw):
    """Fused edge improvisation (Algorithm 1) for a flat [F] frontier.

    "pallas" runs the Mosaic kernel (row-DMA gather + sort-free dedup, no
    [F, layers*m] HBM intermediate); "xla" is the sort-free jnp formulation
    (``ref.select_edges``), also what "auto" picks off-TPU; "argsort" is the
    historical stable-argsort formulation kept as a benchmark baseline. All
    backends return bit-identical int32[F, m_out] ids.
    """
    if impl == "auto":
        impl = default_impl("edge")
    if impl == "xla":
        return _ref.select_edges(
            nbrs, us, L, R, logn=logn, m_out=m_out, skip_layers=skip_layers
        )
    if impl == "argsort":
        return _legacy_edge_select.select_edges_batch(
            nbrs, us, L, R, logn=logn, m_out=m_out, skip_layers=skip_layers
        )
    return _edge_select.edge_select_kernel_call(
        nbrs, us, L, R, logn=logn, m_out=m_out, skip_layers=skip_layers,
        interpret=_interpret(), **block_kw
    )


def flash_attention(
    q, k, v, *, causal=True, window=None, softcap=None, scale=None,
    q_offset=0, impl="auto", unroll=1, **block_kw,
):
    if impl == "auto":
        impl = default_impl()
    if impl == "xla":
        return _ref.attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, q_offset=q_offset, unroll=unroll,
            **{k2: v2 for k2, v2 in block_kw.items() if k2 == "block_q"},
        )
    return _flash.flash_attention_kernel_call(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, interpret=_interpret(), **block_kw
    )
