"""Jit'd public wrappers around the Pallas kernels.

On a TPU backend the Mosaic kernels run natively; everywhere else (this CPU
container, tests) they run in ``interpret=True`` mode unless the caller asks
for the pure-XLA reference instead. ``impl`` selection:

  * "pallas"    — pallas_call, interpret on non-TPU backends
  * "xla"       — ref.py jnp implementation (what the multi-pod dry-run
                  lowers, since Mosaic cannot lower on the CPU host platform)
  * "auto"      — pallas on TPU else xla
"""
from __future__ import annotations

import jax

from repro.kernels import distance as _distance
from repro.kernels import flash_attention as _flash
from repro.kernels import gather_distance as _gather
from repro.kernels import ref as _ref

__all__ = ["pairwise_dist", "gather_dist", "flash_attention", "default_impl"]


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pairwise_dist(q, x, *, metric="l2", impl="auto", **block_kw):
    if impl == "auto":
        impl = default_impl()
    if impl == "xla":
        return _ref.pairwise_dist(q, x, metric=metric)
    return _distance.pairwise_dist_kernel_call(
        q, x, metric=metric, interpret=_interpret(), **block_kw
    )


def gather_dist(q, table, ids, *, metric="l2", impl="auto", **block_kw):
    """Fused gather + masked distance for the beam-search hop.

    "pallas" runs the Mosaic kernel (no [B, M, d] intermediate); "xla" is the
    gather+einsum reference, which is also what "auto" picks off-TPU.
    """
    if impl == "auto":
        impl = default_impl()
    if impl == "xla":
        return _ref.gather_dist(q, table, ids, metric=metric)
    return _gather.gather_distance_kernel_call(
        q, table, ids, metric=metric, interpret=_interpret(), **block_kw
    )


def flash_attention(
    q, k, v, *, causal=True, window=None, softcap=None, scale=None,
    q_offset=0, impl="auto", unroll=1, **block_kw,
):
    if impl == "auto":
        impl = default_impl()
    if impl == "xla":
        return _ref.attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, q_offset=q_offset, unroll=unroll,
            **{k2: v2 for k2, v2 in block_kw.items() if k2 == "block_q"},
        )
    return _flash.flash_attention_kernel_call(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, interpret=_interpret(), **block_kw
    )
