"""Jit'd public wrappers around the Pallas kernels.

On a TPU backend the Mosaic kernels run natively; everywhere else (this CPU
container, tests) they run in ``interpret=True`` mode unless the caller asks
for the pure-XLA reference instead. ``impl`` selection:

  * "pallas"    — pallas_call, interpret on non-TPU backends
  * "xla"       — ref.py jnp implementation (what the multi-pod dry-run
                  lowers, since Mosaic cannot lower on the CPU host platform)
  * "auto"      — pallas on TPU else xla; overridable per-op via the
                  ``REPRO_DIST_IMPL`` / ``REPRO_EDGE_IMPL`` /
                  ``REPRO_PRUNE_IMPL`` / ``REPRO_FLASH_IMPL`` env vars, or
                  globally via ``REPRO_IMPL`` (the CI backend matrix)
  * "argsort"   — edge selection only: the historical stable-argsort
                  formulation (``core/edge_select.py``), kept for regression
                  benchmarking
  * "legacy"    — construction prune only: the historical eager path
                  (``core/rng.py::prune_batch``, full [C, C] matrix), kept
                  as the bit-identical oracle and benchmark baseline
  * "composed"  — whole hop only: the three-op composition (select_edges
                  -> bitset.test_and_set -> gather_dist), kept as the
                  bit-identical oracle; the per-op ``edge_impl`` /
                  ``dist_impl`` knobs apply inside it. ``hop``'s "auto"
                  resolves to "composed" off-TPU (not "xla") so the per-op
                  knobs keep meaning something; any global ``REPRO_IMPL``
                  (including "legacy") resolves it the same way — only
                  ``REPRO_HOP_IMPL`` or TPU auto engages the megakernel —
                  and explicit per-op pins force it regardless of impl.

``select_edges`` is integer-exact: all three backends return bit-identical
ids. ``prune`` backends agree bit-identically in kept ids (keep decisions
compare f32 distances built from the same expansion). ``gather_dist``
backends agree to f32 tolerance (and bit-exactly under identical fusion).
``hop`` is integer-exact in (edges, newly-visited mask, bitset words)
across all three backends; distances agree to f32 tolerance.

Pallas branches merge the autotuner's installed picks
(``kernels/autotune.py::get_pick``) underneath any explicit ``**block_kw``,
so a measured block-size/pipeline-depth choice applies process-wide while
caller overrides still win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitset as _bitset
from repro.core import edge_select as _legacy_edge_select
from repro.core import knobs as _knobs
from repro.core import rng as _legacy_rng
from repro.core import storage as _storage
from repro.kernels import autotune as _autotune
from repro.kernels import distance as _distance
from repro.kernels import edge_select as _edge_select
from repro.kernels import flash_attention as _flash
from repro.kernels import gather_distance as _gather
from repro.kernels import hop as _hop
from repro.kernels import prune as _prune
from repro.kernels import ref as _ref

__all__ = [
    "pairwise_dist", "gather_dist", "select_edges", "prune", "hop",
    "flash_attention", "default_impl",
]


def default_impl(kind: str | None = None) -> str:
    """Backend for ``impl="auto"``: pallas on TPU, xla elsewhere.

    ``kind`` ("dist" | "edge" | ...) checks ``REPRO_<KIND>_IMPL`` first,
    then the global ``REPRO_IMPL`` — the hook the CI backend matrix uses to
    force every auto dispatch through one backend.
    """
    if kind:
        forced = _knobs.get_str(f"REPRO_{kind.upper()}_IMPL")
        if forced:
            return forced
    forced = _knobs.get_str("REPRO_IMPL")
    if forced:
        return forced
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _check_impl(op, impl, allowed):
    """Reject unknown backend tokens instead of silently running Pallas —
    e.g. a global REPRO_IMPL=legacy (prune-only token) or a typo must not
    route the other ops through the interpreter on CPU."""
    if impl not in allowed:
        raise ValueError(
            f"{op}: unknown impl {impl!r} (expected one of {sorted(allowed)})"
        )


def pairwise_dist(q, x, *, metric="l2", impl="auto", **block_kw):
    if impl == "auto":
        impl = default_impl("dist")
    _check_impl("pairwise_dist", impl, {"pallas", "xla"})
    if impl == "xla":
        return _ref.pairwise_dist(q, x, metric=metric)
    return _distance.pairwise_dist_kernel_call(
        q, x, metric=metric, interpret=_interpret(), **block_kw
    )


def gather_dist(q, table, ids, *, metric="l2", impl="auto", **block_kw):
    """Fused gather + masked distance for the beam-search hop.

    "pallas" runs the Mosaic kernel (no [B, M, d] intermediate); "xla" is the
    gather+einsum reference, which is also what "auto" picks off-TPU. The
    table may be a plain float [n, d] array or a codec struct
    (``storage.Int8Vectors`` / ``storage.PQVectors``, DESIGN.md §9): both
    backends decode — XLA via ``storage.decode_rows``, Pallas in-register
    after the row DMA. Codec tables use the separately-tuned
    ``"gather_dist_codec"`` autotune pick (narrow rows shift the optimum).
    """
    if impl == "auto":
        impl = default_impl("dist")
    _check_impl("gather_dist", impl, {"pallas", "xla"})
    if impl == "xla":
        return _ref.gather_dist(q, table, ids, metric=metric)
    kind = ("gather_dist_codec"
            if isinstance(table, (_storage.Int8Vectors, _storage.PQVectors))
            else "gather_dist")
    return _gather.gather_distance_kernel_call(
        q, table, ids, metric=metric, interpret=_interpret(),
        **{**_autotune.get_pick(kind), **block_kw},
    )


def select_edges(nbrs, us, L, R, *, logn, m_out, skip_layers=True,
                 impl="auto", **block_kw):
    """Fused edge improvisation (Algorithm 1) for a flat [F] frontier.

    "pallas" runs the Mosaic kernel (row-DMA gather + sort-free dedup, no
    [F, layers*m] HBM intermediate); "xla" is the sort-free jnp formulation
    (``ref.select_edges``), also what "auto" picks off-TPU; "argsort" is the
    historical stable-argsort formulation kept as a benchmark baseline. All
    backends return bit-identical int32[F, m_out] ids.
    """
    if impl == "auto":
        impl = default_impl("edge")
    _check_impl("select_edges", impl, {"pallas", "xla", "argsort"})
    # compact neighbor tables (int16 ids, -1 sentinel) decode here so every
    # backend sees int32; trace-time no-op for already-wide tables
    nbrs = _storage.decode_neighbors(nbrs)
    if impl == "xla":
        return _ref.select_edges(
            nbrs, us, L, R, logn=logn, m_out=m_out, skip_layers=skip_layers
        )
    if impl == "argsort":
        return _legacy_edge_select.select_edges_batch(
            nbrs, us, L, R, logn=logn, m_out=m_out, skip_layers=skip_layers
        )
    return _edge_select.edge_select_kernel_call(
        nbrs, us, L, R, logn=logn, m_out=m_out, skip_layers=skip_layers,
        interpret=_interpret(),
        **{**_autotune.get_pick("edge_select"), **block_kw},
    )


_prune_xla = functools.partial(
    jax.jit, static_argnames=("m", "fill")
)(_ref.prune)
_prune_xla_vecs = functools.partial(
    jax.jit, static_argnames=("m", "fill")
)(_ref.prune_vecs)


@functools.partial(jax.jit, static_argnames=("m", "fill"))
def _prune_legacy(cand_ids, cand_dists, table, *, m, alpha=1.0, fill=True):
    cvec = _storage.decode_rows(table, jnp.maximum(cand_ids, 0))
    return _legacy_rng.prune_batch(
        cand_ids, cand_dists, cvec, m=m, alpha=alpha, fill=fill
    )


def prune(cand_ids, cand_dists, table, *, m, alpha=1.0, fill=True,
          impl="auto", cand_vecs=None, **block_kw):
    """Fused construction prune (the per-level build hot loop).

    "pallas" runs the Mosaic kernel (row-DMA gather + lazy cc columns, no
    [B, C, d] or [B, C, C] HBM intermediates); "xla" is the lazy-column jnp
    formulation (``ref.prune``), also what "auto" picks off-TPU; "legacy" is
    the historical eager path (XLA gather + full [C, C] distance matrix +
    C-step scan, ``core/rng.py::prune_batch``), kept as the bit-identical
    oracle and benchmark baseline. All backends agree in kept ids.

    ``cand_vecs`` [B, C, d]: the already-gathered candidate vectors, when
    the caller materialized them anyway (the build loop does, to compute
    ``cand_dists``) — saves the xla/legacy paths a redundant gather. The
    Pallas path ignores it: DMA-ing rows straight from ``table`` is the
    point. Gathers are exact, so results are identical either way.

    ``table`` may be a codec struct (``storage.Int8Vectors`` /
    ``storage.PQVectors``); every backend decodes — the Pallas kernel
    in-register after the row DMA (DESIGN.md §9).
    """
    if impl == "auto":
        impl = default_impl("prune")
    _check_impl("prune", impl, {"pallas", "xla", "legacy"})
    if impl == "xla":
        if cand_vecs is not None:
            return _prune_xla_vecs(
                cand_ids, cand_dists, cand_vecs, m=m, alpha=alpha, fill=fill
            )
        return _prune_xla(
            cand_ids, cand_dists, table, m=m, alpha=alpha, fill=fill
        )
    if impl == "legacy":
        if cand_vecs is not None:
            return _legacy_rng.prune_batch(
                cand_ids, cand_dists, cand_vecs, m=m, alpha=alpha, fill=fill
            )
        return _prune_legacy(
            cand_ids, cand_dists, table, m=m, alpha=alpha, fill=fill
        )
    return _prune.prune_kernel_call(
        cand_ids, cand_dists, table, m=m, alpha=float(alpha), fill=fill,
        interpret=_interpret(),
        **{**_autotune.get_pick("prune"), **block_kw},
    )


def hop(q, table, nbrs, u, L, R, visited, exp_ok, *, logn, m_out,
        skip_layers=True, metric="l2", impl="auto", edge_impl="auto",
        dist_impl="auto", **block_kw):
    """One whole beam-search hop: edge improvisation + visited test-and-set
    + gather-distance, the full ``beam_search`` iteration body.

    "pallas" runs the fused megakernel (``kernels/hop.py``) — one launch,
    frontier resident in VMEM; "xla" is the jnp composition
    (``ref.hop``); "composed" chains the three *dispatched* ops
    (``select_edges`` -> ``bitset.test_and_set`` -> ``gather_dist``), so
    the per-op ``edge_impl`` / ``dist_impl`` knobs apply — it is the
    bit-identical oracle and the pre-fusion production path. "auto" picks
    pallas on TPU and "composed" off-TPU (keeping per-op knobs live);
    ``REPRO_HOP_IMPL`` overrides that choice. The global ``REPRO_IMPL``
    resolves "auto" to "composed" (its job is forcing the *per-op*
    kernels, which run inside the composition; "legacy" maps the same
    way) — only ``REPRO_HOP_IMPL`` or TPU auto engages the megakernel.
    An explicit non-"auto" ``edge_impl``/``dist_impl`` pin always wins:
    it routes any resolved impl through "composed", since the fused
    kernel has no per-op backends. Integer outputs
    (nbr, nvalid, visited) are bit-identical across backends; distances
    agree to f32 tolerance.

    Shapes: q f32[B, d], table ([n, d] float or a codec struct —
    ``storage.Int8Vectors`` / ``storage.PQVectors``, decoded in-register by
    the megakernel per DESIGN.md §9), nbrs [n, layers, m] (compact
    int16/split decodes here), u int32[B, W], L/R int32[B*W], visited
    uint32[B, words], exp_ok bool[B, W] -> (nbr i32[B, W*m_out], ndist
    f32[B, W*m_out], nvalid bool[B, W*m_out], visited' uint32[B, words]).
    """
    if impl == "auto":
        forced = _knobs.get_str("REPRO_HOP_IMPL")
        glob = _knobs.get_str("REPRO_IMPL")
        if forced:
            impl = forced
        elif glob == "legacy":
            impl = "legacy"
        elif glob:
            # the global override targets the *per-op* kernels: keep the hop
            # composed so each inner op's auto resolves to the forced
            # backend — only REPRO_HOP_IMPL (or TPU auto) engages the fused
            # megakernel, so e.g. the REPRO_IMPL=pallas CI leg still runs
            # the per-op interpreted kernels, not an interpreted whole-hop
            # inside every deadline-sensitive serving test
            impl = "composed"
        else:
            # off-TPU auto stays "composed" (not "xla") so the per-op
            # edge_impl/dist_impl knobs keep applying inside the hop
            impl = "pallas" if jax.default_backend() == "tpu" else "composed"
    if impl == "legacy":
        # global REPRO_IMPL=legacy (prune-only token) falls back to the
        # composed path rather than erroring the whole hop; the inner ops
        # would reject the token too, so their autos resolve backend-default
        impl = "composed"
        inner = "pallas" if jax.default_backend() == "tpu" else "xla"
        if edge_impl == "auto":
            edge_impl = inner
        if dist_impl == "auto":
            dist_impl = inner
    _check_impl("hop", impl, {"pallas", "xla", "composed"})
    if impl != "composed" and not (edge_impl == "auto"
                                   and dist_impl == "auto"):
        # an explicit per-op pin always wins — neither the megakernel nor
        # the jnp composition has per-op backends, so a caller that pinned
        # edge_impl/dist_impl (e.g. dist_impl="xla" for per-backend
        # bit-exactness) routes through the composed path even when
        # REPRO_HOP_IMPL forces "pallas"
        impl = "composed"
    nbrs = _storage.decode_neighbors(nbrs)
    if impl == "composed":
        B, W = u.shape
        nbr = select_edges(
            nbrs, u.reshape(B * W), L, R, logn=logn, m_out=m_out,
            skip_layers=skip_layers, impl=edge_impl,
        ).reshape(B, W * m_out)
        pre_valid = (nbr >= 0) & jnp.repeat(exp_ok, m_out, axis=1)
        visited, seen = _bitset.test_and_set(visited, nbr, pre_valid)
        nvalid = pre_valid & ~seen
        ndist = gather_dist(
            q, table, jnp.where(nvalid, nbr, -1), metric=metric,
            impl=dist_impl,
        )
        return nbr, ndist, nvalid, visited
    if impl == "xla":
        return _ref.hop(
            q, table, nbrs, u, L, R, visited, exp_ok, logn=logn,
            m_out=m_out, skip_layers=skip_layers, metric=metric,
        )
    return _hop.hop_kernel_call(
        q, table, nbrs, u, L, R, visited, exp_ok, logn=logn, m_out=m_out,
        skip_layers=skip_layers, metric=metric, interpret=_interpret(),
        **{**_autotune.get_pick("hop"), **block_kw},
    )


def flash_attention(
    q, k, v, *, causal=True, window=None, softcap=None, scale=None,
    q_offset=0, impl="auto", unroll=1, **block_kw,
):
    if impl == "auto":
        impl = default_impl("flash")
    _check_impl("flash_attention", impl, {"pallas", "xla"})
    if impl == "xla":
        return _ref.attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, q_offset=q_offset, unroll=unroll,
            **{k2: v2 for k2, v2 in block_kw.items() if k2 == "block_q"},
        )
    return _flash.flash_attention_kernel_call(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, interpret=_interpret(), **block_kw
    )
