"""Block-size / pipeline-depth autotuner for the Pallas kernels.

Every Pallas kernel in this repo exposes two knobs the compiler cannot pick
for us: the row-tile size (``block_b`` / ``block_f`` / ``block_m``: how many
queries or frontier nodes one grid program owns, bounded by VMEM residency)
and the DMA pipeline depth (``window``: how many row copies stay in flight).
The right values depend on the host — interpret-mode CPU wants small tiles,
a real TPU wants the MXU fed — so, like the build-path chunk auto-tuner
(``core/build.py::auto_chunk``), picks are *measured*, not hardcoded:

  * ``CANDIDATES[kind]`` is the search space per kernel
    ("hop" | "gather_dist" | "gather_dist_codec" | "edge_select" |
    "prune" — the codec kind retunes the decode tile for quantized
    tables, DESIGN.md §9);
  * ``autotune(kind, run)`` times ``run(**params)`` for every candidate
    (min over ``iters`` after a warmup call that also pays the compile)
    and returns a record ``{kind, best, best_ms, candidates: [...]}``;
  * ``benchmarks/hotpath.py`` drives it on representative probe shapes,
    installs the winners via ``set_pick``, and persists the records in
    ``artifacts/BENCH_hotpath.json`` under ``autotune`` —
    ``benchmarks/ci_gate.py`` then flags pick drift between the committed
    record and a fresh smoke run (malformed/missing → hard fail, a changed
    pick → soft warn, since timing is host-dependent);
  * the ``kernels/ops.py`` wrappers merge ``get_pick(kind)`` underneath any
    explicit ``**block_kw`` on their Pallas branches, so installed picks
    apply process-wide while caller overrides still win.

Picks only ever feed jit-static arguments, so installing one changes which
compiled executable serves a call; serving installs picks before
``SearchExecutor.warmup()`` (or never), keeping the zero-post-warmup-compile
guarantee intact.
"""
from __future__ import annotations

import time

import jax

__all__ = [
    "CANDIDATES", "autotune", "set_pick", "get_pick", "all_picks",
    "clear_picks", "install",
]

# search spaces: small, host-agnostic grids — the point is recording a
# measured pick, not exhaustive search
CANDIDATES = {
    "hop": [
        {"block_b": bb, "window": w}
        for bb in (2, 4, 8) for w in (4, 8, 16)
    ],
    "gather_dist": [
        {"block_b": bb, "block_m": bm, "window": w}
        for bb in (4, 8) for bm in (64, 128) for w in (8, 16)
    ],
    # codec tables (int8/PQ) change the DMA row width (narrow int8/uint8
    # rows) and add in-register decode work, so the optimal tile differs
    # from the f32 table's — tuned as its own kind (DESIGN.md §9)
    "gather_dist_codec": [
        {"block_b": bb, "block_m": bm, "window": w}
        for bb in (4, 8) for bm in (64, 128) for w in (8, 16, 32)
    ],
    "edge_select": [
        {"block_f": bf, "window": w}
        for bf in (4, 8, 16) for w in (4, 8)
    ],
    "prune": [
        {"block_b": bb, "window": w}
        for bb in (4, 8, 16) for w in (8, 16)
    ],
}

_PICKS: dict[str, dict] = {}


def set_pick(kind: str, params: dict) -> None:
    """Install ``params`` as the process-wide default block kwargs for
    ``kind``'s Pallas branch (caller-explicit kwargs still override)."""
    if kind not in CANDIDATES:
        raise ValueError(
            f"autotune: unknown kernel kind {kind!r} "
            f"(expected one of {sorted(CANDIDATES)})"
        )
    _PICKS[kind] = dict(params)


def get_pick(kind: str) -> dict:
    return dict(_PICKS.get(kind, {}))


def all_picks() -> dict:
    return {k: dict(v) for k, v in _PICKS.items()}


def clear_picks() -> None:
    _PICKS.clear()


def install(picks: dict) -> None:
    """Install a ``{kind: params}`` mapping (e.g. the ``autotune`` section
    of a committed BENCH artifact) wholesale."""
    for kind, params in picks.items():
        set_pick(kind, params)


def _time_ms(run, params, iters):
    out = run(**params)                     # pays compile + correctness
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(run(**params))
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def autotune(kind: str, run, *, iters: int = 3, candidates=None) -> dict:
    """Time ``run(**params)`` over the candidate grid and return the record.

    ``run`` must execute the kernel on a fixed representative probe shape
    and return its (device) outputs; timing is min-of-``iters`` after one
    untimed warmup call per candidate. A candidate that raises (e.g. a tile
    too large for VMEM on a real TPU) is recorded with ``ms=None`` and
    skipped. The returned record is JSON-ready::

        {"kind": ..., "best": {...}, "best_ms": ...,
         "candidates": [{"params": {...}, "ms": ...}, ...]}
    """
    if candidates is None:
        if kind not in CANDIDATES:
            raise ValueError(
                f"autotune: unknown kernel kind {kind!r} "
                f"(expected one of {sorted(CANDIDATES)})"
            )
        candidates = CANDIDATES[kind]
    rows = []
    best_params, best_ms = None, float("inf")
    for params in candidates:
        try:
            ms = _time_ms(run, params, iters)
        except Exception:                   # tile does not fit / bad combo
            rows.append({"params": dict(params), "ms": None})
            continue
        rows.append({"params": dict(params), "ms": round(ms, 4)})
        if ms < best_ms:
            best_params, best_ms = dict(params), ms
    if best_params is None:
        raise RuntimeError(f"autotune: every {kind} candidate failed")
    return {
        "kind": kind,
        "best": best_params,
        "best_ms": round(best_ms, 4),
        "candidates": rows,
    }
