"""Pallas TPU kernel: blockwise (flash) attention for the LM backbones.

Covers the variants the ten assigned architectures need:
  * causal and bidirectional (seamless encoder)
  * GQA/MQA — `Hq % Hkv == 0`, the kv head index is `h // group`
  * sliding-window local attention (gemma2 alternating layers)
  * logit softcap (gemma2)
  * q_offset for chunked prefill (absolute positions of the q block)

Layout: grid ``(B*Hq, Sq/bq, Skv/bk)``, reduction over key blocks innermost.
Running (m, l, acc) live in VMEM scratch — the classic two-pass-free
streaming softmax. Out-of-range key blocks (fully above the causal diagonal
or fully outside the local window) are skipped with ``pl.when`` so the causal
lower-left triangle costs ~half the FLOPs, and local attention is O(S*w).

VMEM at defaults (bq=bk=128, Dh<=256, f32): q/k/v tiles 3*128*256*4 ≈ 0.4 MB,
scores 128*128*4 = 64 KB, acc 128*256*4 = 128 KB — well inside budget; MXU
dims are 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

_NEG = -1e30  # python float: jnp scalars would be captured consts in pallas


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
    scale, causal, window, softcap, bq, bk, nk, q_offset, sq_real, skv_real,
):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qpos0 = iq * bq + q_offset
    kpos0 = jk * bk

    # block-level skip: entirely above the diagonal / outside the window
    skip = jnp.bool_(False)
    if causal:
        skip |= kpos0 > qpos0 + bq - 1
    if window is not None:
        skip |= kpos0 + bk - 1 <= qpos0 - window

    @pl.when(~skip)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale       # [bq, d]
        k = k_ref[0].astype(jnp.float32)               # [bk, d]
        v = v_ref[0].astype(jnp.float32)               # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                              # [bq, bk]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < skv_real
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG)

        m_prev = m_scr[...]                            # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        correction = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * correction + jnp.sum(
            p, axis=1, keepdims=True
        )
        acc_scr[...] = acc_scr[...] * correction + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(jk == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "scale", "block_q", "block_k",
        "interpret", "q_offset",
    ),
)
def flash_attention_kernel_call(
    q, k, v, *, causal=True, window=None, softcap=None, scale=None,
    q_offset=0, block_q=128, block_k=128, interpret=False,
):
    """Blockwise attention (module docstring above; dispatch contract and
    backend-rejection tests: DESIGN.md §6; oracle: ``ref.attention``).

    q[B, Hq, Sq, Dh], k/v[B, Hkv, Skv, Dh] with ``Hq % Hkv == 0`` (GQA) ->
    [B, Hq, Sq, Dh] in q's dtype; softmax statistics and accumulation are
    f32 regardless of input dtype. No codec structs here — attention
    operands are activations, not stored tables.
    """
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (Dh ** 0.5)

    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Skv))

    def padto(a, mult, axis):
        r = (-a.shape[axis]) % mult
        if r == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, r)
        return jnp.pad(a, widths)

    qp = padto(q.reshape(B * Hq, Sq, Dh), bq, 1)
    kp = padto(k.reshape(B * Hkv, Skv, Dh), bk, 1)
    vp = padto(v.reshape(B * Hkv, Skv, Dh), bk, 1)
    Sqp, Skvp = qp.shape[1], kp.shape[1]
    grid = (B * Hq, Sqp // bq, Skvp // bk)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, nk=grid[2], q_offset=q_offset, sq_real=Sq,
        skv_real=Skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, Dh), lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, bk, Dh), lambda h, i, j, g=g: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sqp, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Sq, :].reshape(B, Hq, Sq, Dh)
