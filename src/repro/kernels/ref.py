"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function here is the semantic definition; kernels must match it to
float tolerance across the shape/dtype sweeps in tests/test_kernels_*.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["pairwise_dist", "gather_dist", "attention"]


def pairwise_dist(q, x, metric="l2"):
    """q[Bq, D], x[N, D] -> [Bq, N].

    l2: squared euclidean distance; ip: negative inner product.
    """
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    dot = q @ x.T
    if metric == "ip":
        return -dot
    qq = jnp.sum(q * q, axis=1, keepdims=True)
    xx = jnp.sum(x * x, axis=1)
    return qq - 2.0 * dot + xx[None, :]


def gather_dist(q, table, ids, metric="l2"):
    """q[B, d], table[n, d], ids int32[B, M] (-1 masked) -> f32[B, M].

    Distance from query b to table[ids[b, j]]; +inf where ids < 0. This is
    the semantic contract of the fused gather-distance kernel; on non-TPU
    backends it is also the production path (XLA gather + einsum).
    """
    q = q.astype(jnp.float32)
    x = table[jnp.maximum(ids, 0)].astype(jnp.float32)  # [B, M, d]
    if metric == "l2":
        xx = jnp.sum(x * x, axis=-1)
        qq = jnp.sum(q * q, axis=-1, keepdims=True)
        xq = jnp.einsum("bd,bmd->bm", q, x)
        d = xx - 2.0 * xq + qq
    elif metric == "ip":
        d = -jnp.einsum("bd,bmd->bm", q, x)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    return jnp.where(ids < 0, jnp.inf, d)


def attention(
    q, k, v, *, causal=True, window=None, softcap=None, scale=None,
    q_offset=0, block_q=None, unroll=1,
):
    """Multi-head attention with GQA, optional local window and logit softcap.

    q: [B, Hq, Sq, Dh]; k, v: [B, Hkv, Skv, Dh]; Hq % Hkv == 0.
    window: if set, query i attends keys j with i - window < j (sliding).
    softcap: gemma2-style ``cap * tanh(scores / cap)``.
    q_offset: absolute position of q[..., 0, :] (for decode: Skv - Sq).
    block_q: query-chunked (flash-style) evaluation: peak live memory is
      O(block_q * Skv) instead of O(Sq * Skv). Auto-enabled on long
      sequences; the tiny-shape path stays single-shot for exactness tests.
    Returns [B, Hq, Sq, Dh] in q's dtype; math in f32.
    """
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (Dh ** 0.5)
    if block_q is None and Sq >= 2048:
        block_q = 512
    if block_q and Sq > block_q and Sq % block_q == 0:
        nq = Sq // block_q
        qs = jnp.moveaxis(
            q.reshape(B, Hq, nq, block_q, Dh), 2, 0
        )                                               # [nq, B, Hq, bq, Dh]
        offs = q_offset + jnp.arange(nq) * block_q

        @jax.checkpoint  # recompute chunk probs in backward: O(bq*Skv) live
        def body(_, blk):
            qb, off = blk
            ob = _attn_chunk(qb, k, v, g, scale, causal, window, softcap,
                             off, Skv)
            return None, ob

        _, outs = jax.lax.scan(body, None, (qs, offs), unroll=unroll)
        out = jnp.moveaxis(outs, 0, 2).reshape(B, Hq, Sq, Dh)
        return out.astype(q.dtype)
    out = _attn_chunk(q, k, v, g, scale, causal, window, softcap,
                      q_offset, Skv)
    return out.astype(q.dtype)


def _attn_chunk(q, k, v, g, scale, causal, window, softcap, q_offset, Skv):
    """One query block against the full KV. q_offset may be traced."""
    B, Hq, Sq, Dh = q.shape
    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    # additive bias fuses into the softmax (no second S x S where-pass)
    scores = scores + jnp.where(mask[None, None], 0.0, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores - m)
    denom = jnp.maximum(jnp.sum(probs, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", probs / denom, vf)
