"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function here is the semantic definition (DESIGN.md §6); kernels must
match it to float tolerance across the shape/dtype sweeps in
tests/test_kernels_*. Table-reading oracles accept the quantized codec
structs (``storage.Int8Vectors`` / ``storage.PQVectors``) and decode
through ``storage.decode_rows`` — the same values the kernels' in-VMEM
dequant must produce (DESIGN.md §9, tests/test_codecs.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitset as _bitset
from repro.core import segment_tree
from repro.core import storage as _storage

__all__ = [
    "pairwise_dist", "gather_dist", "select_edges", "edge_scan_valid",
    "hop", "prune", "prune_vecs", "attention",
]

# plain int: safe to reference from inside any trace
_BIG = 2**30


def pairwise_dist(q, x, metric="l2"):
    """q[Bq, D], x[N, D] -> [Bq, N].

    l2: squared euclidean distance; ip: negative inner product.
    """
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    dot = q @ x.T
    if metric == "ip":
        return -dot
    qq = jnp.sum(q * q, axis=1, keepdims=True)
    xx = jnp.sum(x * x, axis=1)
    return qq - 2.0 * dot + xx[None, :]


def gather_dist(q, table, ids, metric="l2"):
    """q[B, d], table[n, d] or a codec struct, ids int32[B, M] (-1 masked)
    -> f32[B, M].

    Distance from query b to the decoded table[ids[b, j]]; +inf where
    ids < 0. ``table`` may be a plain float table or a quantized codec
    struct (``storage.Int8Vectors`` / ``storage.PQVectors``, DESIGN.md §9)
    — rows decode to f32 through ``storage.decode_rows``, the contract the
    kernels' in-VMEM dequant is pinned against. This is the semantic
    contract of the fused gather-distance kernel; on non-TPU backends it is
    also the production path (XLA gather + einsum).
    """
    q = q.astype(jnp.float32)
    x = _storage.decode_rows(table, jnp.maximum(ids, 0))  # [B, M, d] f32
    if metric == "l2":
        xx = jnp.sum(x * x, axis=-1)
        qq = jnp.sum(q * q, axis=-1, keepdims=True)
        xq = jnp.einsum("bd,bmd->bm", q, x)
        d = xx - 2.0 * xq + qq
    elif metric == "ip":
        d = -jnp.einsum("bd,bmd->bm", q, x)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    return jnp.where(ids < 0, jnp.inf, d)


def edge_scan_valid(flat, us, L, R, lay, *, logn, skip_layers=True):
    """Candidate validity of Algorithm 1, closed form per flat position.

    The one definition of ``segment_tree.scan_mask`` + in-range semantics
    shared by the jnp path below and the Pallas edge-selection kernel (both
    callers pass their own ``lay`` iota, since Mosaic needs a broadcasted
    2D iota while XLA takes a plain ``arange``).

    flat: int[.., K] gathered candidate edges; us/L/R: int[.., 1]; lay:
    int[.., K] (broadcastable) layer of each flat position. Returns
    bool[.., K].
    """
    layers = logn + 1
    u = jnp.maximum(us, 0)
    lo, hi = segment_tree.seg_bounds(u, lay, logn)
    terminal = (lo >= L) & (hi <= R)
    # first fully-covered layer; argmax(all-False) == 0 in scan_mask, so an
    # all-False row (u outside [L, R]) degrades to layer 0 only
    ft = jnp.min(jnp.where(terminal, lay, layers), axis=-1, keepdims=True)
    ft = jnp.where(ft == layers, 0, ft)
    mask = lay <= ft
    if skip_layers:
        # skip a layer when the child segment's [L, R]-intersection equals
        # the current one; the child of u's segment at lay is its segment
        # at lay+1 (leaves have no child, never skip)
        lo2, hi2 = segment_tree.seg_bounds(u, jnp.minimum(lay + 1, logn), logn)
        skip = (
            (jnp.maximum(lo2, L) == jnp.maximum(lo, L))
            & (jnp.minimum(hi2, R) == jnp.minimum(hi, R))
            & (lay < logn)
        )
        mask &= ~skip
    return (
        (flat >= 0) & (flat >= L) & (flat <= R) & mask
        & (flat != u) & (us >= 0)
    )


def select_edges(nbrs, us, L, R, *, logn, m_out, skip_layers=True):
    """Sort-free edge improvisation (paper Algorithm 1) for a flat frontier.

    ``nbrs`` int32[n, layers, m] packed elemental-graph table; ``us``
    int32[F] frontier node ids (-1 for inactive slots); ``L``/``R`` scalars
    or int32[F] inclusive rank ranges. Returns int32[F, m_out] improvised
    edges in priority order, -1 padded.

    This is the semantic contract of the Pallas edge-selection kernel and the
    off-TPU production path. It produces ids bit-identical to the historical
    argsort formulation (``core/edge_select.py::select_edges_batch``) but
    contains no sort: the priority-ordered top-``m_out`` falls out of
    ``m_out`` masked argmin steps, and the set-union dedup is folded into
    them *lazily* — after a step selects an id, every position holding that
    id is wiped, so later steps can only yield new ids. That is equivalent
    to the kernel's eager strictly-lower-triangular ``[K, K]`` equality
    matrix (``K = layers*m``): entries that never reach the top-``m_out``
    never needed dedup. O(m_out * K) work instead of O(K^2), which is what
    makes this formulation beat the argsort one on shallow-parallelism CPU
    hosts, not just on the VPU. See DESIGN.md §2.
    """
    n, layers, m = nbrs.shape
    K = layers * m
    F = us.shape[0]
    us = us.astype(jnp.int32)
    L = jnp.broadcast_to(jnp.asarray(L, jnp.int32), us.shape)[:, None]
    R = jnp.broadcast_to(jnp.asarray(R, jnp.int32), us.shape)[:, None]
    us = us[:, None]                                      # [F, 1]
    # compact (int16) tables widen here: -1 is the sentinel in every storage
    # dtype, so the cast is the whole decode (see core/storage.py)
    flat = (
        nbrs[jnp.maximum(us[:, 0], 0)].reshape(F, K).astype(jnp.int32)
    )                                                     # [F, K]

    lay = jnp.arange(K, dtype=jnp.int32)[None, :] // m    # [1, K]
    valid = edge_scan_valid(
        flat, us, L, R, lay, logn=logn, skip_layers=skip_layers
    )

    # priority == flat position (upper layer first, then slot order)
    pos = jnp.arange(K, dtype=jnp.int32)
    prio = jnp.where(valid, pos[None, :], _BIG)

    # -- top-m_out with lazy dedup: m_out masked argmin steps ---------------
    # Each step takes the best remaining priority and wipes *every* position
    # holding the selected id, so duplicates never surface in later steps.
    def step(p, _):
        pmin = jnp.min(p, axis=1)                         # [F]
        sel = p == pmin[:, None]                          # one hit unless BIG
        idt = jnp.max(
            jnp.where(sel, flat, jnp.iinfo(jnp.int32).min), axis=1
        )
        out_t = jnp.where(pmin < _BIG, idt, jnp.int32(-1))
        taken = (flat == out_t[:, None]) & (p < _BIG)     # all dups of idt
        return jnp.where(sel | taken, _BIG, p), out_t

    _, outs = jax.lax.scan(step, prio, None, length=m_out)
    return outs.T                                         # [F, m_out]


def hop(q, table, nbrs, u, L, R, visited, exp_ok, *, logn, m_out,
        skip_layers=True, metric="l2"):
    """One whole beam-search hop (the megakernel's semantic contract).

    Fuses the three per-iteration pieces of ``core/search.py::beam_search``'s
    loop body into one function: edge improvisation for the flattened
    ``[B*W]`` frontier (:func:`select_edges`), the packed-uint32 visited
    test-and-set (``core/bitset.py``), and the masked gather-distance
    (:func:`gather_dist`). Applying the three pieces in this order IS the
    definition — the composed dispatch path in ``kernels/ops.py::hop`` and
    the Pallas megakernel must both match it: integer outputs (edges, the
    newly-visited mask, the updated bitset) bit-identically, distances to
    f32 tolerance (bit-exactly under identical fusion).

    q f32[B, d]; table [n, d] (f32/bf16) or a quantized codec struct
    (``storage.Int8Vectors`` / ``storage.PQVectors``, decoded per-row via
    :func:`gather_dist` — DESIGN.md §9); nbrs int32[n, layers, m]
    (pre-decoded); u int32[B, W] expansion frontier (-1 inactive);
    L/R int32[B*W] per-frontier-row ranges; visited uint32[B, words];
    exp_ok bool[B, W] which expansions are live.

    Returns ``(nbr, ndist, nvalid, visited')``:
      nbr    int32[B, W*m_out]  improvised edges (-1 padded),
      ndist  f32[B, W*m_out]    distances, +inf where not newly visited,
      nvalid bool[B, W*m_out]   newly-visited mask (exactly-once per id),
      visited' uint32[B, words] bitset with the new ids marked.
    """
    B, W = u.shape
    nbr = select_edges(
        nbrs, u.reshape(B * W), L, R, logn=logn, m_out=m_out,
        skip_layers=skip_layers,
    ).reshape(B, W * m_out)
    exp_rep = jnp.repeat(exp_ok, m_out, axis=1)           # [B, W*m_out]
    pre_valid = (nbr >= 0) & exp_rep
    visited, seen = _bitset.test_and_set(visited, nbr, pre_valid)
    nvalid = pre_valid & ~seen
    ndist = gather_dist(q, table, jnp.where(nvalid, nbr, -1), metric=metric)
    return nbr, ndist, nvalid, visited


def prune(cand_ids, cand_dists, table, *, m, alpha=1.0, fill=True):
    """Lazy-column RNG prune (paper Def. 2.1) for a chunk of build nodes.

    ``cand_ids`` int32[B, C] candidate ids into ``table`` (-1 invalid);
    ``cand_dists`` f32[B, C] squared distance to the chunk's node u (inf for
    invalid slots); ``table`` the full vector table — f32/bf16 ``[n, d]``
    or a quantized codec struct (decoded per-row via
    ``storage.decode_rows``, DESIGN.md §9). Returns int32[B, m] pruned
    neighbor ids, -1 padded — the semantic contract of the Pallas
    construction-prune kernel and the off-TPU production path.

    Matches ``core/rng.py::prune`` (the eager oracle) in kept ids but never
    materializes the ``[C, C]`` candidate-candidate distance matrix: the
    sequential keep-set recurrence is flipped into at most ``m`` masked-argmin
    sweeps. Each sweep selects the nearest still-live candidate by
    ``(class, du, position)`` — class 0 while unsuppressed candidates remain,
    class 1 for the HNSW-style fill of pruned survivors — and, when the
    selection is a *keep*, computes that single candidate's distance column
    ``cc[:, j]`` on the fly (same ``xx_i - 2 x_i.x_j + xx_j`` expansion as the
    oracle's ``pairwise_sq_dists``) to grow the suppressed set. Keeps are
    selected in ascending distance order, so a candidate's suppression state
    at selection time equals the oracle's scan state; suppression never
    shrinks, so every keep step precedes every fill step and the emitted
    order matches the oracle's keep-then-fill key sort. O(m * C * d) work
    instead of O(C^2 * d), with only [C] live columns.
    """
    vecs = _storage.decode_rows(table, jnp.maximum(cand_ids, 0))  # [B, C, d]
    return prune_vecs(
        cand_ids, cand_dists, vecs, m=m, alpha=alpha, fill=fill
    )


def prune_vecs(cand_ids, cand_dists, cand_vecs, *, m, alpha=1.0, fill=True):
    """``prune`` for callers that already gathered ``cand_vecs`` [B, C, d]
    (the build loop materializes it to compute ``cand_dists`` anyway)."""
    cand_ids = cand_ids.astype(jnp.int32)
    cand_dists = cand_dists.astype(jnp.float32)
    vecs = cand_vecs.astype(jnp.float32)
    return jax.vmap(
        lambda i, du, x: _prune_row(i, du, x, m=m, alpha=alpha, fill=fill)
    )(cand_ids, cand_dists, vecs)


def _prune_row(ids, du, vecs, *, m, alpha, fill):
    """One node's lazy-column prune: ids[C], du[C], vecs[C, d] -> int32[m]."""
    C = ids.shape[0]
    pos = jnp.arange(C, dtype=jnp.int32)
    valid = (ids >= 0) & jnp.isfinite(du)
    # first-occurrence dedup in (du, position) order — the same winner as the
    # oracle's stable distance sort followed by keep-first-id
    same = ids[:, None] == ids[None, :]
    earlier = (du[:, None] < du[None, :]) | (
        (du[:, None] == du[None, :]) & (pos[:, None] < pos[None, :])
    )
    dup = jnp.any(
        same & earlier & valid[:, None] & valid[None, :], axis=0
    )
    valid &= ~dup
    xx = jnp.sum(vecs * vecs, axis=-1)                    # [C]

    def step(carry, _):
        supp, taken = carry
        avail = valid & ~taken
        keepable = avail & ~supp
        fillable = (avail & supp) if fill else jnp.zeros_like(avail)
        cls = jnp.where(keepable, 0, jnp.where(fillable, 1, 2))
        cmin = jnp.min(cls)
        cand = (cls == cmin) & (cmin < 2)
        dmask = jnp.where(cand, du, jnp.inf)
        dmin = jnp.min(dmask)
        p = jnp.min(jnp.where(cand & (dmask == dmin), pos, _BIG))
        has = cmin < 2
        p_safe = jnp.where(has, p, 0)
        out_t = jnp.where(has, ids[p_safe], jnp.int32(-1))
        # the selected keep's cc column, computed lazily (oracle's expansion)
        xy = jnp.einsum("cd,d->c", vecs, vecs[p_safe])
        cc = jnp.maximum(xx - 2.0 * xy + xx[p_safe], 0.0)
        is_keep = has & (cmin == 0)
        supp |= is_keep & (alpha * cc < du)
        taken |= pos == p
        return (supp, taken), out_t

    init = (jnp.zeros((C,), bool), jnp.zeros((C,), bool))
    _, outs = jax.lax.scan(step, init, None, length=m)
    return outs


def attention(
    q, k, v, *, causal=True, window=None, softcap=None, scale=None,
    q_offset=0, block_q=None, unroll=1,
):
    """Multi-head attention with GQA, optional local window and logit softcap.

    q: [B, Hq, Sq, Dh]; k, v: [B, Hkv, Skv, Dh]; Hq % Hkv == 0.
    window: if set, query i attends keys j with i - window < j (sliding).
    softcap: gemma2-style ``cap * tanh(scores / cap)``.
    q_offset: absolute position of q[..., 0, :] (for decode: Skv - Sq).
    block_q: query-chunked (flash-style) evaluation: peak live memory is
      O(block_q * Skv) instead of O(Sq * Skv). Auto-enabled on long
      sequences; the tiny-shape path stays single-shot for exactness tests.
    Returns [B, Hq, Sq, Dh] in q's dtype; math in f32.
    """
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (Dh ** 0.5)
    if block_q is None and Sq >= 2048:
        block_q = 512
    if block_q and Sq > block_q and Sq % block_q == 0:
        nq = Sq // block_q
        qs = jnp.moveaxis(
            q.reshape(B, Hq, nq, block_q, Dh), 2, 0
        )                                               # [nq, B, Hq, bq, Dh]
        offs = q_offset + jnp.arange(nq) * block_q

        @jax.checkpoint  # recompute chunk probs in backward: O(bq*Skv) live
        def body(_, blk):
            qb, off = blk
            ob = _attn_chunk(qb, k, v, g, scale, causal, window, softcap,
                             off, Skv)
            return None, ob

        _, outs = jax.lax.scan(body, None, (qs, offs), unroll=unroll)
        out = jnp.moveaxis(outs, 0, 2).reshape(B, Hq, Sq, Dh)
        return out.astype(q.dtype)
    out = _attn_chunk(q, k, v, g, scale, causal, window, softcap,
                      q_offset, Skv)
    return out.astype(q.dtype)


def _attn_chunk(q, k, v, g, scale, causal, window, softcap, q_offset, Skv):
    """One query block against the full KV. q_offset may be traced."""
    B, Hq, Sq, Dh = q.shape
    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    # additive bias fuses into the softmax (no second S x S where-pass)
    scores = scores + jnp.where(mask[None, None], 0.0, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores - m)
    denom = jnp.maximum(jnp.sum(probs, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", probs / denom, vf)
