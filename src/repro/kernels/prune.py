"""Pallas TPU kernel: fused construction prune (the build-path hot loop).

Every level of the bottom-up build RNG-prunes each node's candidate list
(paper Def. 2.1 / §3.2.2). The legacy formulation (``core/rng.py``)
precomputes the full ``[C, C]`` candidate-candidate distance matrix in XLA —
``O(C^2 d)`` flops and a ``[B, C, C]`` HBM intermediate — before a
``C``-step sequential keep-set scan. Here the candidate vectors never touch
an XLA gather: the vector table stays un-blocked in ``ANY``/HBM space and
the kernel row-DMAs only each chunk row's ``C`` candidate vectors into a
VMEM scratch (software-pipelined like ``gather_distance.py``, ``-1`` slots
skipped by predication), then runs the keep-set recurrence *flipped*: at
most ``m`` masked-argmin sweeps each select the nearest live candidate by
``(class, du, position)`` and — only when the selection is a keep — compute
that one candidate's distance column ``cc[:, j]`` on the fly against the
chunk (one MXU pass), growing the suppressed set. Only the kept set (≤ m
rows) ever contributes columns, so the work drops to ``O(m C d)`` and the
HNSW-style ``keepPrunedConnections`` fill pass folds into the same sweep as
selection class 1 (suppressed survivors, still in distance order).

Ids match ``kernels/ref.py::prune`` (the lazy jnp contract) and
``core/rng.py::prune`` (the eager oracle) in kept ids; the keep decisions
compare f32 distances built from the same ``xx_i - 2 x_i.x_j + xx_j``
expansion, so parity holds under identical fusion.

VMEM residency per program: the gather scratch ``bb*C*d_pad*4`` bytes
(default ``bb=8``, C=128, d=128: 0.5 MB) plus the ``[bb, C, C]`` dedup
masks (0.5 MB as i32 at C=128); lower ``block_b`` for very large ``C*d``.
CPU/CI runs use ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import storage as _storage

__all__ = ["prune_kernel_call"]


def _prune_kernel(
    ids_smem,    # SMEM [bb, C] (DMA row indices)
    ids_vmem,    # VMEM [bb, C] (vectorized ids)
    du_ref,      # VMEM [bb, C] squared distances to u
    *refs,       # table_ref (ANY [n, w]), [aux_ref], o_ref, xbuf, sems
    bb, C, m, alpha, fill, window, codec, pq_m, pq_dsub,
):
    if codec is None:
        table_ref, o_ref, xbuf, sems = refs
    else:
        table_ref, aux_ref, o_ref, xbuf, sems = refs
    total = bb * C
    big = jnp.int32(2**30)

    def slot_id(t):
        return ids_smem[t // C, t % C]

    def row_copy(t):
        return pltpu.make_async_copy(
            table_ref.at[slot_id(t)], xbuf.at[t], sems.at[t % window]
        )

    def start(t):
        @pl.when(slot_id(t) >= 0)
        def _():
            row_copy(t).start()

    def wait(t):
        @pl.when(slot_id(t) >= 0)
        def _():
            row_copy(t).wait()

    # software-pipelined gather: keep up to `window` row DMAs in flight
    def fill_loop(t, carry):
        @pl.when(t >= window)
        def _():
            wait(t - window)

        start(t)
        return carry

    jax.lax.fori_loop(0, total, fill_loop, 0)

    def drain(t, carry):
        wait(t)
        return carry

    jax.lax.fori_loop(max(0, total - window), total, drain, 0)

    ids = ids_vmem[...]                                   # [bb, C]
    du = du_ref[...]                                      # [bb, C]
    # codec decode, in-register (DESIGN.md §9): xbuf holds the stored rows
    if codec == "int8":
        x = xbuf[...].astype(jnp.float32)
        x = x * aux_ref[...].reshape(total, 1)            # per-row scales
    elif codec == "pq":
        codes = xbuf[...][:, :pq_m].astype(jnp.int32)
        sub = jax.lax.broadcasted_iota(jnp.int32, (total, pq_m), 1)
        idx = codes + sub * _storage.PQ_CENTROIDS
        x = jnp.take(aux_ref[...], idx.reshape(-1), axis=0)
        x = x.reshape(total, pq_m * pq_dsub)
    else:
        x = xbuf[...].astype(jnp.float32)                 # [bb*C, d]
    xx = jnp.sum(x * x, axis=1).reshape(bb, C)            # [bb, C]
    pos = jax.lax.broadcasted_iota(jnp.int32, (bb, C), 1)
    valid = (ids >= 0) & jnp.isfinite(du)

    # first-occurrence dedup in (du, position) order: same winner as the
    # oracle's stable distance sort followed by keep-first-id
    pos_i = jax.lax.broadcasted_iota(jnp.int32, (bb, C, C), 1)
    pos_j = jax.lax.broadcasted_iota(jnp.int32, (bb, C, C), 2)
    same = ids[:, :, None] == ids[:, None, :]
    earlier = (du[:, :, None] < du[:, None, :]) | (
        (du[:, :, None] == du[:, None, :]) & (pos_i < pos_j)
    )
    dup = jnp.any(
        same & earlier & valid[:, :, None] & valid[:, None, :], axis=1
    )
    valid &= ~dup

    # -- keep-set recurrence + fill, one masked-argmin sweep per slot -------
    supp = jnp.zeros((bb, C), bool)
    taken = jnp.zeros((bb, C), bool)
    outs = []
    for _ in range(m):
        avail = valid & ~taken
        keepable = avail & ~supp
        fillable = (avail & supp) if fill else jnp.zeros_like(avail)
        cls = jnp.where(keepable, 0, jnp.where(fillable, 1, 2))
        cmin = jnp.min(cls, axis=1, keepdims=True)        # [bb, 1]
        cand = (cls == cmin) & (cmin < 2)
        dmask = jnp.where(cand, du, jnp.inf)
        dmin = jnp.min(dmask, axis=1, keepdims=True)
        p = jnp.min(
            jnp.where(cand & (dmask == dmin), pos, big), axis=1,
            keepdims=True,
        )                                                 # [bb, 1]
        onehot = pos == p                                 # no hit when big
        has = cmin < 2
        out_t = jnp.max(
            jnp.where(onehot, ids, jnp.iinfo(jnp.int32).min),
            axis=1, keepdims=True,
        )
        outs.append(jnp.where(has, out_t, jnp.int32(-1)))
        # the selected keep's cc column, computed lazily: one MXU pass of
        # the whole gathered chunk against the selected vector (overcompute
        # factor bb, the gather_distance diagonal trick)
        vsel = jnp.sum(
            jnp.where(onehot[:, :, None], x.reshape(bb, C, -1), 0.0), axis=1
        )                                                 # [bb, d]
        xx_sel = jnp.sum(jnp.where(onehot, xx, 0.0), axis=1, keepdims=True)
        dots = jax.lax.dot_general(
            x, vsel, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(bb, C, bb)
        row_q = jax.lax.broadcasted_iota(jnp.int32, (bb, C, bb), 0)
        col_q = jax.lax.broadcasted_iota(jnp.int32, (bb, C, bb), 2)
        xy = jnp.sum(jnp.where(row_q == col_q, dots, 0.0), axis=2)
        cc = jnp.maximum(xx - 2.0 * xy + xx_sel, 0.0)
        is_keep = has & (cmin == 0)
        supp |= is_keep & (alpha * cc < du)
        taken |= onehot
    o_ref[...] = jnp.concatenate(outs, axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("m", "alpha", "fill", "block_b", "window", "interpret"),
)
def prune_kernel_call(
    cand_ids, cand_dists, table, *, m, alpha=1.0, fill=True, block_b=8,
    window=16, interpret=False,
):
    """Fused construction prune (DESIGN.md §4; oracle: ``ref.prune``).

    cand_ids int32[B, C] (-1 masked), cand_dists f32[B, C] (inf masked),
    table ([n, d] float / Int8Vectors / PQVectors) -> int32[B, m] pruned
    neighbor ids, -1 padded.

    Pads B to the ``block_b`` row-tile multiple and the stored row width to
    the 128 lane width internally (zero columns are exact for squared L2);
    the table is passed un-blocked so each candidate is one contiguous row
    DMA. Codec tables decode in VMEM registers after the DMA (DESIGN.md §9),
    exactly like the gather-distance kernel.
    """
    B, C = cand_ids.shape
    bb = min(block_b, max(8, B))
    ids = cand_ids.astype(jnp.int32)
    du = cand_dists.astype(jnp.float32)

    def pad_to(a, mult, axis, value=0):
        r = (-a.shape[axis]) % mult
        if r == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, r)
        return jnp.pad(a, widths, constant_values=value)

    idp = pad_to(ids, bb, 0, value=-1)
    dup_ = pad_to(du, bb, 0, value=jnp.inf)
    grid = (idp.shape[0] // bb,)

    codec, aux, aux_spec, pq_m, pq_dsub = None, None, None, 0, 0
    if isinstance(table, _storage.Int8Vectors):
        codec = "int8"
        tp = pad_to(table.codes, 128, 1)
        scales = table.scales[jnp.maximum(ids, 0)].astype(jnp.float32)
        aux = pad_to(scales, bb, 0)
        aux_spec = pl.BlockSpec((bb, C), lambda i: (i, 0))
    elif isinstance(table, _storage.PQVectors):
        codec = "pq"
        pq_m, _, pq_dsub = table.codebook.shape
        tp = pad_to(table.codes, 128, 1)
        aux = table.codebook.reshape(pq_m * 256, pq_dsub)
        aux_spec = pl.BlockSpec(aux.shape, lambda i: (0, 0))
    else:
        tp = pad_to(table, 128, 1)

    in_specs = [
        pl.BlockSpec((bb, C), lambda i: (i, 0),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((bb, C), lambda i: (i, 0)),
        pl.BlockSpec((bb, C), lambda i: (i, 0)),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    args = [idp, idp, dup_, tp]
    if codec is not None:
        in_specs.append(aux_spec)
        args.append(aux)

    out = pl.pallas_call(
        functools.partial(
            _prune_kernel, bb=bb, C=C, m=m, alpha=alpha, fill=fill,
            window=min(window, bb * C), codec=codec, pq_m=pq_m,
            pq_dsub=pq_dsub,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((idp.shape[0], m), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((bb * C, tp.shape[1]), tp.dtype),
            pltpu.SemaphoreType.DMA((min(window, bb * C),)),
        ],
        interpret=interpret,
    )(*args)
    return out[:B]
