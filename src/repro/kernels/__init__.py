"""Pallas TPU kernels for the perf-critical compute layers.

kernels/<name>.py  — pl.pallas_call + BlockSpec
kernels/ops.py     — jit'd wrappers with impl selection
kernels/ref.py     — pure-jnp oracles

Use ``from repro.kernels import ops`` and call ``ops.pairwise_dist`` /
``ops.gather_dist`` / ``ops.select_edges`` / ``ops.prune`` /
``ops.flash_attention`` (impl="auto" picks Pallas on TPU, XLA elsewhere;
the ``REPRO_IMPL`` / ``REPRO_DIST_IMPL`` / ``REPRO_EDGE_IMPL`` /
``REPRO_PRUNE_IMPL`` env vars force a backend).
"""
from repro.kernels import ops

__all__ = ["ops"]
