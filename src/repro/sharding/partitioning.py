"""Logical-axis partitioning (MaxText-style) with divisibility fallback.

Every parameter is declared as a ``ParamDef(shape, axes, ...)`` where
``axes`` names each dimension logically ("vocab", "embed", "mlp", ...).
``RULES`` maps logical names to mesh axes; a dimension whose size does not
divide its mesh axis falls back to replication (e.g. 4-head xlstm on a
16-way model axis), so every assigned architecture shards without bespoke
case analysis.

The same machinery shards activations (see ``act_rules``).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ParamDef", "RULES", "init_params", "abstract_params", "param_specs",
    "named_shardings", "logical_to_spec", "constrain", "use_global_mesh",
    "global_mesh",
]

_GLOBAL_MESH: list = [None]


@contextlib.contextmanager
def use_global_mesh(mesh: Mesh):
    """Make ``mesh`` visible to ``constrain`` inside traced model code."""
    _GLOBAL_MESH.append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _GLOBAL_MESH.pop()


def global_mesh() -> Mesh | None:
    return _GLOBAL_MESH[-1]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple          # logical name (or None) per dim; len == len(shape)
    init: str = "normal"  # normal | zeros | ones | scaled
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# logical axis -> mesh axis (or tuple for multi-axis sharding, or None)
RULES: Mapping[str, object] = {
    "vocab": "model",
    "embed": "data",        # FSDP: weight-stationary dim sharded over data
    "embed_tp": "model",    # used where embed is the contracting TP dim
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qkv": None,
    "expert": "model",
    "layers": None,
    "ssm_state": None,
    "ssm_heads": "model",
    "conv": None,
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": "data",    # sequence parallelism for long-context decode
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    "act_expert": "model",
    "act_vocab": "model",
}


def logical_to_spec(axes, mesh: Mesh, shape=None) -> P:
    """Map logical axes -> PartitionSpec.

    Falls back to replication when the dim does not divide the mesh axis,
    and when a mesh axis is already taken by an earlier dim of the same
    tensor (e.g. stacked MoE weights map both "expert" and "mlp" to the
    model axis — the first one wins)."""
    out = []
    used: set = set()
    for i, name in enumerate(axes):
        if name is None:
            out.append(None)
            continue
        mesh_ax = RULES.get(name)
        if mesh_ax is None:
            out.append(None)
            continue
        if isinstance(mesh_ax, tuple):
            mesh_ax = tuple(
                a for a in mesh_ax if a in mesh.shape and a not in used
            )
            if not mesh_ax:
                out.append(None)
                continue
            size = int(np.prod([mesh.shape[a] for a in mesh_ax]))
            if len(mesh_ax) == 1:
                mesh_ax = mesh_ax[0]
        else:
            if mesh_ax not in mesh.shape or mesh_ax in used:
                out.append(None)
                continue
            size = mesh.shape[mesh_ax]
        if shape is not None and shape[i] % size != 0:
            out.append(None)  # divisibility fallback: replicate
        else:
            out.append(mesh_ax)
            for a in (mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)):
                used.add(a)
    return P(*out)


# ---------------------------------------------------------------------------
# param tree materialization
# ---------------------------------------------------------------------------

def _is_def(x):
    return isinstance(x, ParamDef)


def init_params(defs, key, dtype=jnp.float32):
    """Materialize a pytree of ParamDef into real arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    arrs = []
    for d, k in zip(leaves, keys):
        if d.init == "zeros":
            a = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            a = jnp.ones(d.shape, dtype)
        else:
            a = jax.random.normal(k, d.shape, dtype) * d.scale
        arrs.append(a)
    return jax.tree.unflatten(treedef, arrs)


def abstract_params(defs, dtype=jnp.float32):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=_is_def
    )


def param_specs(defs, mesh: Mesh):
    """PartitionSpec tree matching the ParamDef tree."""
    return jax.tree.map(
        lambda d: logical_to_spec(d.axes, mesh, d.shape), defs, is_leaf=_is_def
    )


def named_shardings(defs, mesh: Mesh):
    return jax.tree.map(
        lambda d: NamedSharding(mesh, logical_to_spec(d.axes, mesh, d.shape)),
        defs,
        is_leaf=_is_def,
    )


def constrain(x, *axes):
    """with_sharding_constraint by logical names (no-op outside a mesh)."""
    mesh = global_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(axes, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
