"""Train a ~100M-parameter LM for a few hundred steps (the assignment's
end-to-end training driver), with checkpointing and fault-tolerant loop.

    PYTHONPATH=src python examples/train_embedder.py [--steps 200]

Uses the qwen3 family at ~100M scale (reduced width/depth, real vocab kept
at 8k so the CE path is exercised meaningfully on CPU).
"""
import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    losses = train.main([
        "--arch", "qwen3-0.6b",
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "128",
        "--lr", "3e-3",
        "--ckpt-dir", "artifacts/ckpt_embedder",
        # ~100M params: 8 layers x 512 width, vocab 8192
        "--reduced-overrides",
        "n_layers=8,d_model=512,n_heads=8,n_kv_heads=8,d_ff=2048,"
        "vocab=8192,head_dim=64",
    ])
    drop = (losses[0] - losses[-1]) / losses[0]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} ({drop:.0%} drop)")
    if drop < 0.05:
        sys.exit("training made no progress")


if __name__ == "__main__":
    main()
