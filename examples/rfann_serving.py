"""End-to-end driver: LM embeddings -> iRangeGraph -> batched serving.

The full framework path on CPU-sized configs:
  1. a qwen3-family backbone (reduced) embeds a corpus,
  2. iRangeGraph indexes the embeddings by a numeric attribute,
  3. the serving engine answers batched range-filtered queries,
  4. recall is probed against the exact scan.

    PYTHONPATH=src python examples/rfann_serving.py
"""
from repro.launch import serve


def main():
    qps, recall = serve.main([
        "--arch", "qwen3-0.6b", "--n", "2048", "--queries", "128",
        "--ef", "64",
    ])
    assert recall >= 0.8, f"serving recall degraded: {recall}"
    print(f"end-to-end OK: {qps:.0f} qps at recall {recall:.3f}")


if __name__ == "__main__":
    main()
