"""Multi-attribute conjunctive RFANN (paper §4): compare post-filtering,
in-filtering, and the adaptive p = exp(-t) strategy (iRangeGraph+).

    PYTHONPATH=src python examples/multi_attribute.py
"""
import time

import numpy as np

from repro.core import BuildConfig, RangeGraphIndex, SearchConfig, recall
from repro.core import multiattr
from repro.data.pipeline import vector_dataset


def main():
    n, dim, B = 4096, 64, 128
    vectors, attrs, queries = vector_dataset(
        n, dim, seed=2, queries=B, n_attrs=2
    )
    index = RangeGraphIndex.build(
        vectors, attrs[:, 0], BuildConfig(m=16, ef_construction=64)
    )
    # second attribute re-ordered to the index's rank order
    attr2 = attrs[index.perm, 1].astype(np.float32)

    rng = np.random.default_rng(0)
    # ~2^-2 fraction on each attribute (paper §5.2.5 workload)
    L = rng.integers(0, n // 2, B).astype(np.int32)
    R = (L + n // 4).astype(np.int32)
    lo2 = np.quantile(attr2, 0.3) * np.ones(B, np.float32)
    hi2 = np.quantile(attr2, 0.8) * np.ones(B, np.float32)

    gt, _ = multiattr.brute_force_multiattr(
        index, attr2, queries, L, R, lo2, hi2, k=10
    )
    cfg = SearchConfig(ef=96)
    for mode in ("post", "in", "adaptive"):
        multiattr.search_multiattr(  # compile
            index, attr2, queries[:8], L[:8], R[:8], lo2[:8], hi2[:8],
            k=10, mode=mode, config=cfg,
        )
        t0 = time.perf_counter()
        res = multiattr.search_multiattr(
            index, attr2, queries, L, R, lo2, hi2, k=10, mode=mode,
            config=cfg,
        )
        dt = time.perf_counter() - t0
        rec = recall(np.asarray(res.ids), gt)
        label = {"post": "Post-filtering", "in": "In-filtering",
                 "adaptive": "iRangeGraph+ (p=exp(-t))"}[mode]
        print(f"{label:28s} qps={B / dt:8.1f}  recall@10={rec:.3f}")


if __name__ == "__main__":
    main()
