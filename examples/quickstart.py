"""Quickstart: build an iRangeGraph index and answer RFANN queries.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import BuildConfig, RangeGraphIndex, SearchConfig, recall
from repro.data.pipeline import vector_dataset


def main():
    # 1. data: vectors + one numeric attribute (price, timestamp, ...)
    n, dim = 4096, 64
    vectors, attrs, queries = vector_dataset(
        n, dim, seed=0, queries=100, attr_kind="uniform"
    )
    attrs = attrs[:, 0]

    # 2. build the segment-tree of elemental graphs (paper §3.2)
    index = RangeGraphIndex.build(
        vectors, attrs, BuildConfig(m=16, ef_construction=64), verbose=True
    )
    print(f"index: n={index.n} layers={index.logn + 1} "
          f"m={index.m} size={index.nbytes / 1e6:.1f} MB")

    # 3. RFANN queries: nearest neighbors with attribute in [lo, hi]
    lo = np.quantile(attrs, 0.30)
    hi = np.quantile(attrs, 0.45)
    res = index.search(queries, np.full(100, lo), np.full(100, hi),
                       k=10, config=SearchConfig(ef=64))

    # 4. verify against the exact answer
    L, R = index.ranks_of(np.full(100, lo), np.full(100, hi))
    gt, _ = index.brute_force(queries, L, R, k=10)
    print(f"recall@10 = {recall(np.asarray(res.ids), gt):.3f}")
    print(f"mean hops = {np.mean(np.asarray(res.n_hops)):.1f}, "
          f"mean distance computations = "
          f"{np.mean(np.asarray(res.n_dists)):.0f} "
          f"(vs {int(R[0]) - int(L[0]) + 1} for the exact scan)")

    # 5. results carry original object ids
    orig = index.original_ids(np.asarray(res.ids))
    ok = orig[orig >= 0]
    assert ((attrs[ok] >= lo) & (attrs[ok] <= hi)).all()
    print("all results satisfy the range predicate — done.")


if __name__ == "__main__":
    main()
