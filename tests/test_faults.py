"""serve/faults.py: the chaos harness itself must be deterministic.

The injector is the instrument the chaos suite measures the serving stack
with, so these tests pin the instrument: config validation, env parsing
(via the explicit ``env=`` dict — the ambient ``REPRO_FAULTS`` of the CI
chaos leg must not leak in), seed-determinism of the fault stream, and the
``resolve()`` convention every serving component funnels its ``faults=``
parameter through.
"""
import dataclasses

import pytest

from repro.serve.errors import InjectedFaultError
from repro.serve.faults import FAULT_KINDS, FaultConfig, FaultInjector, \
    resolve


def test_config_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultConfig(kinds=("latency", "gremlins"))


def test_config_rejects_bad_rates():
    with pytest.raises(ValueError, match="latency_rate"):
        FaultConfig(kinds=("latency",), latency_rate=1.5)
    with pytest.raises(ValueError, match="flush_error_rate"):
        FaultConfig(kinds=("flush_error",), flush_error_rate=-0.1)
    with pytest.raises(ValueError, match="latency_s"):
        FaultConfig(kinds=("latency",), latency_s=-1.0)


def test_config_is_hashable_and_frozen():
    cfg = FaultConfig(kinds=["latency"])   # list normalizes to tuple
    assert cfg.kinds == ("latency",)
    assert hash(cfg) == hash(FaultConfig(kinds=("latency",)))
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.seed = 1


def test_from_env_unset_and_blank():
    assert FaultConfig.from_env(env={}) is None
    assert FaultConfig.from_env(env={"REPRO_FAULTS": "  "}) is None
    assert FaultConfig.from_env(env={"REPRO_FAULTS": ","}) is None


def test_from_env_parses_kinds_and_knobs():
    cfg = FaultConfig.from_env(env={
        "REPRO_FAULTS": "latency, flush_error",
        "REPRO_FAULT_LATENCY_S": "0.5",
        "REPRO_FAULT_FLUSH_ERROR_RATE": "1.0",
        "REPRO_FAULT_SEED": "42",
    })
    assert cfg.kinds == ("latency", "flush_error")
    assert cfg.latency_s == 0.5
    assert cfg.flush_error_rate == 1.0
    assert cfg.seed == 42
    assert cfg.latency_rate == 0.25   # default survives partial env


def test_injector_is_deterministic_per_seed():
    cfg = FaultConfig(kinds=FAULT_KINDS, queue_full_rate=0.5, seed=3)
    a = FaultInjector(cfg)
    b = FaultInjector(cfg)
    seq_a = [a.queue_full() for _ in range(64)]
    seq_b = [b.queue_full() for _ in range(64)]
    assert seq_a == seq_b
    assert any(seq_a) and not all(seq_a)
    assert a.counts["queue_full"] == sum(seq_a)
    c = FaultInjector(FaultConfig(kinds=FAULT_KINDS, queue_full_rate=0.5,
                                  seed=4))
    assert [c.queue_full() for _ in range(64)] != seq_a


def test_flush_error_raises_typed_and_counts():
    inj = FaultInjector(FaultConfig(kinds=("flush_error",),
                                    flush_error_rate=1.0))
    with pytest.raises(InjectedFaultError) as ei:
        inj.maybe_flush_error()
    assert ei.value.kind == "flush_error"
    assert isinstance(ei.value, RuntimeError)
    assert inj.counts["flush_error"] == 1
    # kinds not enabled never fire, whatever their rate
    assert inj.queue_full() is False
    inj.maybe_latency()
    assert inj.counts["latency"] == inj.counts["queue_full"] == 0


def test_disarm_stops_firing_without_losing_counts():
    inj = FaultInjector(FaultConfig(kinds=("queue_full",),
                                    queue_full_rate=1.0))
    assert inj.queue_full() is True
    inj.armed = False
    assert inj.queue_full() is False
    assert inj.counts["queue_full"] == 1
    inj.armed = True
    assert inj.queue_full() is True
    assert inj.counts["queue_full"] == 2


def test_resolve_convention(monkeypatch):
    # False disables injection even when the env asks for chaos (this is
    # what keeps deterministic tests deterministic under the CI chaos leg)
    monkeypatch.setenv("REPRO_FAULTS", "latency")
    assert resolve(False) is None
    inj = resolve(None)
    assert isinstance(inj, FaultInjector)
    assert inj.config.kinds == ("latency",)
    monkeypatch.delenv("REPRO_FAULTS")
    assert resolve(None) is None
    cfg = FaultConfig(kinds=("latency",))
    assert isinstance(resolve(cfg), FaultInjector)
    shared = FaultInjector(cfg)
    assert resolve(shared) is shared
    with pytest.raises(TypeError, match="faults must be"):
        resolve("latency")
