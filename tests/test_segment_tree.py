"""Property tests for the closed-form segment-tree math."""
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import segment_tree as sgt


def ref_seg_bounds(u, lay, logn):
    size = 1 << (logn - lay)
    lo = (u // size) * size
    return lo, lo + size - 1


@given(
    logn=st.integers(1, 12),
    u=st.integers(0, 2**12 - 1),
    lay=st.integers(0, 12),
)
@settings(max_examples=200, deadline=None)
def test_seg_bounds_matches_reference(logn, u, lay):
    u = u % (1 << logn)
    lay = lay % (logn + 1)
    lo, hi = sgt.seg_bounds(np.int32(u), np.int32(lay), logn)
    rlo, rhi = ref_seg_bounds(u, lay, logn)
    assert (int(lo), int(hi)) == (rlo, rhi)
    assert rlo <= u <= rhi


@given(st.data())
@settings(max_examples=100, deadline=None)
def test_decompose_range_exact_cover(data):
    logn = data.draw(st.integers(1, 10))
    n = 1 << logn
    L = data.draw(st.integers(0, n - 1))
    R = data.draw(st.integers(L, n - 1))
    segs = sgt.decompose_range(L, R, logn)
    covered = np.zeros(n, bool)
    for lay, lo, hi in segs:
        rlo, rhi = ref_seg_bounds(lo, lay, logn)
        assert (rlo, rhi) == (lo, hi), "decomposition must use tree segments"
        assert not covered[lo : hi + 1].any(), "segments must be disjoint"
        covered[lo : hi + 1] = True
    assert covered[L : R + 1].all()
    assert covered.sum() == R - L + 1
    assert len(segs) <= 2 * logn + 1


@given(st.data())
@settings(max_examples=100, deadline=None)
def test_covering_segment_is_smallest(data):
    logn = data.draw(st.integers(1, 10))
    n = 1 << logn
    L = data.draw(st.integers(0, n - 1))
    R = data.draw(st.integers(L, n - 1))
    lay, lo, hi = sgt.covering_segment(L, R, logn)
    assert lo <= L and R <= hi
    if lay < logn:  # its children must not cover [L, R]
        mid = (lo + hi) // 2
        assert not (R <= mid or L > mid)


@given(st.data())
@settings(max_examples=150, deadline=None)
def test_scan_mask_structure(data):
    logn = data.draw(st.integers(2, 10))
    n = 1 << logn
    L = data.draw(st.integers(0, n - 1))
    R = data.draw(st.integers(L, n - 1))
    u = data.draw(st.integers(L, R))
    mask = np.asarray(sgt.scan_mask(u, L, R, logn, skip_layers=True))
    naive = np.asarray(sgt.scan_mask(u, L, R, logn, skip_layers=False))
    assert mask.shape == (logn + 1,)
    # skipping only removes layers
    assert not (mask & ~naive).any()
    # the first fully-covered layer is always scanned by both
    for lay in range(logn + 1):
        lo, hi = ref_seg_bounds(u, lay, logn)
        if L <= lo and hi <= R:
            assert mask[lay] and naive[lay]
            assert not mask[lay + 1 :].any()
            assert not naive[lay + 1 :].any()
            break
    else:
        pytest.fail("leaf layer must be covered when u in range")
    # full-range query scans exactly the root
    full = np.asarray(sgt.scan_mask(u, 0, n - 1, logn, skip_layers=True))
    assert full[0] and not full[1:].any()
