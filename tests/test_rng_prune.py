"""RNG pruning invariants (paper Def. 2.1)."""
import numpy as np
from _hypo import given, settings, st

from repro.core import rng as rng_mod


def ref_prune(u_vec, cand_vecs, cand_ids, m, alpha=1.0):
    """Sequential reference of the candidate-based RNG rule."""
    d_u = ((cand_vecs - u_vec) ** 2).sum(1)
    order = np.argsort(d_u, kind="stable")
    kept = []
    seen = set()
    for j in order:
        if cand_ids[j] < 0 or cand_ids[j] in seen:
            continue
        seen.add(cand_ids[j])
        pruned = any(
            alpha * ((cand_vecs[i] - cand_vecs[j]) ** 2).sum() < d_u[j]
            for i in kept
        )
        if not pruned and len(kept) < m:
            kept.append(j)
    return [int(cand_ids[j]) for j in kept]


@given(st.data())
@settings(max_examples=50, deadline=None)
def test_prune_matches_reference(data):
    d = data.draw(st.integers(2, 8))
    C = data.draw(st.integers(2, 24))
    m = data.draw(st.integers(1, 8))
    seed = data.draw(st.integers(0, 2**31))
    rng = np.random.default_rng(seed)
    u = rng.standard_normal(d).astype(np.float32)
    cand = rng.standard_normal((C, d)).astype(np.float32)
    ids = np.arange(C, dtype=np.int32)
    # randomly invalidate some slots
    bad = rng.random(C) < 0.2
    ids = np.where(bad, -1, ids).astype(np.int32)
    dists = ((cand - u) ** 2).sum(1).astype(np.float32)
    dists = np.where(bad, np.inf, dists)
    cc = rng_mod.pairwise_sq_dists(cand[None])[0]
    got = np.asarray(
        rng_mod.prune(ids, dists, cc, m=m, alpha=1.0, fill=False)
    )
    got = [int(x) for x in got if x >= 0]
    want = ref_prune(u, cand, ids, m)
    assert got == want, (got, want)


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_kept_edges_cannot_prune_each_other(data):
    """Core RNG property: for kept edges v ordered by distance, no earlier
    kept w satisfies delta(w, v) < delta(u, v)."""
    d, C, m = 4, 16, 6
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    u = rng.standard_normal(d).astype(np.float32)
    cand = rng.standard_normal((C, d)).astype(np.float32)
    ids = np.arange(C, dtype=np.int32)
    dists = ((cand - u) ** 2).sum(1).astype(np.float32)
    cc = np.asarray(rng_mod.pairwise_sq_dists(cand[None])[0])
    kept = np.asarray(rng_mod.prune(ids, dists, cc, m=m, fill=False))
    kept = [int(x) for x in kept if x >= 0]
    for a in range(len(kept)):
        for b in range(a + 1, len(kept)):
            i, j = kept[a], kept[b]
            assert not (cc[i, j] < dists[j] and dists[i] < dists[j]), (
                "kept edge should have been pruned"
            )


def test_fill_pads_with_nearest_pruned():
    # three collinear points: the middle one prunes the far one
    u = np.zeros(2, np.float32)
    cand = np.array([[1, 0], [2, 0], [10, 0]], np.float32)
    ids = np.array([0, 1, 2], np.int32)
    dists = ((cand - u) ** 2).sum(1)
    cc = np.asarray(rng_mod.pairwise_sq_dists(cand[None])[0])
    nofill = np.asarray(rng_mod.prune(ids, dists, cc, m=3, fill=False))
    fill = np.asarray(rng_mod.prune(ids, dists, cc, m=3, fill=True))
    assert [int(x) for x in nofill if x >= 0] == [0]
    assert [int(x) for x in fill] == [0, 1, 2]
