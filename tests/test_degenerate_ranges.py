"""Degenerate query ranges through the FULL query path.

Edge selection has its own degenerate-range tests (test_edge_select.py);
these pin the whole ``search_improvised`` engine: empty ranges (L > R),
single-element ranges (L == R), and whole-domain ranges must terminate and
return -1-padded / correct results on every edge_impl backend.
"""
import numpy as np
import pytest

from repro.core import BuildConfig, RangeGraphIndex, recall
from repro.core import storage as storage_mod

EDGE_IMPLS = ("xla", "argsort", "pallas")


@pytest.fixture(scope="module")
def small_index():
    rng = np.random.default_rng(11)
    n, d = 256, 12
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 100, n)
    cfg = BuildConfig(m=8, ef_construction=32, brute_threshold=32)
    return RangeGraphIndex.build(vectors, attrs, cfg), rng


@pytest.mark.parametrize("edge_impl", EDGE_IMPLS)
def test_empty_range_returns_all_padding(small_index, edge_impl):
    idx, rng = small_index
    B = 6
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    L = np.array([10, 100, 255, 1, 200, 37], np.int32)
    R = L - 1  # every query empty
    res = idx.search_ranks(q, L, R, k=5, ef=16, edge_impl=edge_impl)
    assert (np.asarray(res.ids) == -1).all()
    assert np.isinf(np.asarray(res.dists)).all()
    # the engine must notice immediately, not burn max_iters hops
    assert (np.asarray(res.n_hops) == 0).all()
    assert (np.asarray(res.n_dists) == 0).all()


@pytest.mark.parametrize("edge_impl", EDGE_IMPLS)
def test_single_element_range(small_index, edge_impl):
    idx, rng = small_index
    B = 5
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    L = np.array([0, 17, 128, 200, 255], np.int32)
    R = L.copy()  # exactly one in-range object each
    res = idx.search_ranks(q, L, R, k=4, ef=16, edge_impl=edge_impl)
    ids = np.asarray(res.ids)
    np.testing.assert_array_equal(ids[:, 0], L)   # the element itself
    assert (ids[:, 1:] == -1).all()               # nothing else exists
    # decode first: under the CI storage legs idx.vectors may be a codec
    # struct (bf16 array or Int8Vectors) rather than an indexable f32 table
    vecs = storage_mod.decode_vectors(idx.vectors)
    want = ((vecs[L] - q) ** 2).sum(1)
    np.testing.assert_allclose(
        np.asarray(res.dists)[:, 0], want, rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("edge_impl", EDGE_IMPLS)
def test_whole_domain_range(small_index, edge_impl):
    idx, rng = small_index
    B = 8
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    L = np.zeros(B, np.int32)
    R = np.full(B, idx.n - 1, np.int32)
    res = idx.search_ranks(q, L, R, k=10, ef=64, edge_impl=edge_impl)
    ids = np.asarray(res.ids)
    assert ((ids >= 0) & (ids < idx.n)).all()     # full domain: k results
    gt, _ = idx.brute_force(q, L, R, k=10)
    assert recall(ids, gt) >= 0.85


def test_mixed_degenerate_batch(small_index):
    """Degenerate and ordinary queries coexist in one batch."""
    idx, rng = small_index
    q = rng.standard_normal((4, idx.dim)).astype(np.float32)
    L = np.array([50, 9, 0, 70], np.int32)
    R = np.array([49, 9, idx.n - 1, 199], np.int32)  # empty, single, all, wide
    res = idx.search_ranks(q, L, R, k=5, ef=32)
    ids = np.asarray(res.ids)
    assert (ids[0] == -1).all()
    assert ids[1, 0] == 9 and (ids[1, 1:] == -1).all()
    assert (ids[2] >= 0).all()
    got = ids[3][ids[3] >= 0]
    assert len(got) == 5 and ((got >= 70) & (got <= 199)).all()
