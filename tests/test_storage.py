"""Compact storage end-to-end: codec laws, index threading, kernel parity.

The contract under test (``core/storage.py``): vectors may store bf16/f16
and neighbor ids int16 with ONE sentinel convention — ``-1`` in every
storage dtype — so the decode is a plain widening cast, ids are
bit-identical across codecs, and all distance math stays f32.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import BuildConfig, RangeGraphIndex, StorageConfig, recall
from repro.core import storage as storage_mod
from repro.kernels import ops, ref
from repro.kernels.gather_distance import gather_distance_kernel_call


# ---------------------------------------------------------------------------
# codec laws
# ---------------------------------------------------------------------------

def test_neighbor_codec_roundtrip_preserves_sentinel():
    rng = np.random.default_rng(0)
    n = 1000
    nbrs = rng.integers(0, n, (64, 5, 8)).astype(np.int32)
    nbrs[rng.random(nbrs.shape) < 0.3] = -1
    enc = storage_mod.encode_neighbors(nbrs, n, StorageConfig.compact())
    assert enc.dtype == np.int16
    dec = storage_mod.decode_neighbors(enc)
    assert dec.dtype == np.int32
    np.testing.assert_array_equal(dec, nbrs)


def test_neighbor_dtype_auto_boundary():
    """int16 holds ids up to 32767, so n=32768 fits and n=32769 does not."""
    assert storage_mod.resolve_neighbor_dtype(32768, "auto") == np.int16
    assert storage_mod.resolve_neighbor_dtype(32769, "auto") == np.int32
    assert storage_mod.resolve_neighbor_dtype(32769, "int32") == np.int32
    with pytest.raises(ValueError, match="cannot hold ids"):
        storage_mod.resolve_neighbor_dtype(32769, "int16")


def test_encode_neighbors_rejects_out_of_range_ids():
    nbrs = np.array([[0, 5]], np.int32)
    with pytest.raises(ValueError, match="out of range"):
        storage_mod.encode_neighbors(nbrs, 5, StorageConfig.compact())


def test_decode_neighbors_jnp_in_trace():
    import jax

    nbrs = jnp.asarray(np.array([[-1, 3, 7]], np.int16))
    out = jax.jit(storage_mod.decode_neighbors)(nbrs)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), [[-1, 3, 7]])


def test_vector_codec_dtypes_and_nbytes():
    rng = np.random.default_rng(1)
    x = rng.standard_normal((32, 16)).astype(np.float32)
    for name in ("bfloat16", "float16"):
        enc = storage_mod.encode_vectors(x, StorageConfig.compact(name))
        assert str(enc.dtype) == name
        assert enc.nbytes == x.nbytes // 2
        dec = storage_mod.decode_vectors(enc)
        assert dec.dtype == np.float32
        # bf16/f16 round once; decode is exact on the rounded values
        np.testing.assert_array_equal(dec, np.asarray(enc, np.float32))


def test_storage_config_validation(monkeypatch):
    with pytest.raises(ValueError, match="vector_dtype"):
        StorageConfig(vector_dtype="float64")
    with pytest.raises(ValueError, match="neighbor_dtype"):
        StorageConfig(neighbor_dtype="int8")
    monkeypatch.setenv("REPRO_STORAGE", "bogus")
    with pytest.raises(ValueError, match="REPRO_STORAGE"):
        storage_mod.default_config()


def test_default_config_env(monkeypatch):
    monkeypatch.setenv("REPRO_STORAGE", "compact")
    assert storage_mod.default_config() == StorageConfig.compact()
    monkeypatch.setenv("REPRO_STORAGE", "f16")
    assert storage_mod.default_config().vector_dtype == "float16"
    monkeypatch.setenv("REPRO_STORAGE", "f32")
    assert storage_mod.default_config() == StorageConfig()


# ---------------------------------------------------------------------------
# index threading
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def built_pair():
    rng = np.random.default_rng(5)
    n, d = 512, 16
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 100, n)
    cfg = BuildConfig(m=8, ef_construction=32, brute_threshold=32)
    # pin the baseline explicitly: the CI compact leg sets
    # REPRO_STORAGE=compact, which would otherwise move the default
    idx32 = RangeGraphIndex.build(vectors, attrs, cfg,
                                  storage=StorageConfig())
    idxc = idx32.astype_storage(StorageConfig.compact())
    return idx32, idxc, rng


def test_compact_index_footprint_halves(built_pair):
    idx32, idxc, _ = built_pair
    assert idxc.vectors.dtype == np.dtype(jnp.bfloat16)
    assert idxc.neighbors.dtype == np.int16
    assert idxc.nbytes <= 0.55 * idx32.nbytes


def test_neighbor_codec_search_ids_bit_identical(built_pair):
    """int16 vs int32 neighbor storage, identical vectors: identical ids."""
    idx32, _, rng = built_pair
    idx16 = idx32.astype_storage(StorageConfig(neighbor_dtype="int16"))
    q = rng.standard_normal((8, idx32.dim)).astype(np.float32)
    L = np.arange(8, dtype=np.int32) * 16
    R = L + 300
    a = idx32.search_ranks(q, L, R, k=5, ef=32)
    b = idx16.search_ranks(q, L, R, k=5, ef=32)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))


def test_compact_index_recall_close_to_f32(built_pair):
    idx32, idxc, rng = built_pair
    q = rng.standard_normal((32, idx32.dim)).astype(np.float32)
    L = np.zeros(32, np.int32)
    R = np.full(32, idx32.n - 1, np.int32)
    # one f32 ground truth for both: the delta must count quantization loss
    gt, _ = idx32.brute_force(q, L, R, k=10)
    r32 = recall(np.asarray(idx32.search_ranks(q, L, R, k=10, ef=64).ids),
                 gt)
    rc = recall(np.asarray(idxc.search_ranks(q, L, R, k=10, ef=64).ids), gt)
    assert abs(rc - r32) <= 0.05


def test_compact_results_in_range(built_pair):
    _, idxc, rng = built_pair
    q = rng.standard_normal((16, idxc.dim)).astype(np.float32)
    L = np.full(16, 100, np.int32)
    R = np.full(16, 300, np.int32)
    ids = np.asarray(idxc.search_ranks(q, L, R, k=10, ef=32).ids)
    got = ids[ids >= 0]
    assert ((got >= 100) & (got <= 300)).all()


def test_build_with_compact_storage_emits_compact_tables():
    rng = np.random.default_rng(9)
    vectors = rng.standard_normal((256, 8)).astype(np.float32)
    attrs = rng.uniform(0, 1, 256)
    idx = RangeGraphIndex.build(
        vectors, attrs, BuildConfig(m=4, ef_construction=16),
        storage=StorageConfig.compact(),
    )
    assert idx.neighbors.dtype == np.int16
    assert idx.vectors.dtype == np.dtype(jnp.bfloat16)
    # same build under f32 storage yields the same graph (construction math
    # is storage-independent)
    idx32 = RangeGraphIndex.build(
        vectors, attrs, BuildConfig(m=4, ef_construction=16),
        storage=StorageConfig(),
    )
    np.testing.assert_array_equal(
        storage_mod.decode_neighbors(idx.neighbors), idx32.neighbors
    )


def test_save_load_roundtrip_compact(tmp_path, built_pair):
    """Loaded index == built one: values, dtypes, writeability."""
    _, idxc, rng = built_pair
    p = str(tmp_path / "compact.rg")
    idxc.save(p)
    got = RangeGraphIndex.load(p)
    for name in ("vectors", "attrs", "perm", "neighbors"):
        a, b = getattr(idxc, name), getattr(got, name)
        assert b.dtype == a.dtype, name
        np.testing.assert_array_equal(np.asarray(b, np.float64),
                                      np.asarray(a, np.float64))
        assert b.flags.writeable, f"{name} must be writeable after load"
    assert got.storage == idxc.storage
    # a loaded index must behave like the built one, including for in-place
    # consumers (the read-only frombuffer regression)
    got.neighbors[0, 0, 0] = got.neighbors[0, 0, 0]
    q = rng.standard_normal((4, idxc.dim)).astype(np.float32)
    L = np.array([0, 8, 16, 24], np.int32)
    R = L + 200
    np.testing.assert_array_equal(
        np.asarray(idxc.search_ranks(q, L, R, k=5, ef=32).ids),
        np.asarray(got.search_ranks(q, L, R, k=5, ef=32).ids),
    )


def test_save_load_roundtrip_f32_writeable(tmp_path, built_pair):
    idx32, _, _ = built_pair
    p = str(tmp_path / "f32.rg")
    idx32.save(p)
    got = RangeGraphIndex.load(p)
    assert got.vectors.flags.writeable and got.neighbors.flags.writeable
    got.vectors[0, 0] = got.vectors[0, 0]  # must not raise
    np.testing.assert_array_equal(got.neighbors, idx32.neighbors)


# ---------------------------------------------------------------------------
# kernel-level parity: bf16 storage in, f32 math out
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_gather_dist_bf16_matches_f32_oracle(impl):
    """bf16-in/f32-math parity: both backends on a bf16 table vs the f32
    oracle evaluated on the (exactly) upcast table. The jnp path is the same
    f32 expansion, so it is bit-identical; the kernel reassociates the dot,
    so it is pinned to f32 tolerance."""
    rng = np.random.default_rng(3)
    B, n, d, M = 4, 64, 24, 9
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    xc = jnp.asarray(rng.standard_normal((n, d)), jnp.bfloat16)
    ids = rng.integers(-1, n, (B, M)).astype(np.int32)
    ids = jnp.asarray(ids)
    want = np.asarray(ref.gather_dist(q, xc.astype(jnp.float32), ids))
    if impl == "xla":
        got = np.asarray(ref.gather_dist(q, xc, ids))
        np.testing.assert_array_equal(got, want)
    else:
        got = np.asarray(gather_distance_kernel_call(q, xc, ids,
                                                     interpret=True))
        assert (np.isinf(got) == np.isinf(want)).all()
        fin = np.isfinite(want)
        np.testing.assert_allclose(got[fin], want[fin],
                                   rtol=1e-4, atol=1e-4)


def test_prune_bf16_table_backend_parity():
    """Construction prune on a bf16 table: every backend upcasts in-register
    and must keep the same ids as the f32 table holding the same values."""
    rng = np.random.default_rng(7)
    B, C, d, n, m = 4, 12, 8, 32, 4
    table = rng.standard_normal((n, d)).astype(np.float32)
    table_bf = table.astype(jnp.bfloat16)
    table_up = np.asarray(table_bf, np.float32)  # the values all paths see
    ids = rng.integers(0, n, (B, C)).astype(np.int32)
    ids[rng.random((B, C)) < 0.2] = -1
    u = rng.standard_normal((B, d)).astype(np.float32)
    du = ((table_up[np.maximum(ids, 0)] - u[:, None, :]) ** 2).sum(-1)
    du = np.where(ids < 0, np.inf, du).astype(np.float32)
    want = np.asarray(ops.prune(
        jnp.asarray(ids), jnp.asarray(du), jnp.asarray(table_up),
        m=m, impl="xla",
    ))
    for impl in ("xla", "pallas", "legacy"):
        got = np.asarray(ops.prune(
            jnp.asarray(ids), jnp.asarray(du), jnp.asarray(table_bf),
            m=m, impl=impl,
        ))
        np.testing.assert_array_equal(got, want, err_msg=impl)


def test_select_edges_int16_table_all_backends():
    """Compact neighbor tables through every edge-selection backend."""
    rng = np.random.default_rng(4)
    n, logn, m = 64, 6, 4
    layers = logn + 1
    nbrs = rng.integers(-1, n, (n, layers, m)).astype(np.int32)
    us = jnp.asarray(rng.integers(0, n, 8).astype(np.int32))
    L = jnp.zeros(8, jnp.int32)
    R = jnp.full(8, n - 1, jnp.int32)
    want = np.asarray(ops.select_edges(
        jnp.asarray(nbrs), us, L, R, logn=logn, m_out=m, impl="xla"
    ))
    nbrs16 = jnp.asarray(nbrs.astype(np.int16))
    for impl in ("xla", "pallas", "argsort"):
        got = ops.select_edges(
            nbrs16, us, L, R, logn=logn, m_out=m, impl=impl
        )
        assert got.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_serving_engine_compact_index(built_pair):
    from repro.serve.engine import Request, ServingEngine

    idx32, idxc, rng = built_pair
    eng = ServingEngine(idxc, ef=32, max_batch=4)
    assert eng.stats["index_bytes"] == idxc.nbytes
    assert eng.stats["index_bytes"] <= 0.55 * idx32.nbytes
    attrs_orig = np.empty(idxc.n)
    attrs_orig[idxc.perm] = idxc.attrs
    reqs = []
    for _ in range(6):
        lo, hi = sorted(rng.uniform(0, 100, 2))
        reqs.append(Request(
            vector=rng.standard_normal(idxc.dim).astype(np.float32),
            lo=lo, hi=hi, k=5,
        ))
        eng.submit(reqs[-1])
    for req, res in zip(reqs, eng.flush()):
        got = res.ids[res.ids >= 0]
        assert ((attrs_orig[got] >= req.lo)
                & (attrs_orig[got] <= req.hi)).all()
