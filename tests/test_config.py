"""SearchConfig + bucket math + build-chunk auto-tuner unit laws."""
import dataclasses

import numpy as np
import pytest

from repro.core import BuildConfig, SearchConfig
from repro.core import build as build_mod
from repro.core import config as config_mod


def test_config_hashable_and_static():
    a = SearchConfig(ef=32, k_bucket=10)
    b = SearchConfig(ef=32, k_bucket=10)
    assert a == b and hash(a) == hash(b)
    assert {a: 1}[b] == 1  # equal configs share one cache slot
    c = a.replace(expand_width=2)
    assert c != a and c.ef == 32


@pytest.mark.parametrize("field,value", [
    ("ef", 0), ("k_bucket", 0), ("expand_width", 0), ("metric", "cosine"),
    ("dist_impl", "argsort"), ("edge_impl", "legacy"), ("max_iters", 0),
])
def test_config_validation(field, value):
    with pytest.raises(ValueError):
        SearchConfig(**{field: value})


def test_bucket_k_rule():
    cfg = SearchConfig(ef=64, k_bucket=10)
    assert [cfg.bucket_k(k) for k in (1, 10, 11, 20, 55, 64)] == \
        [10, 10, 20, 20, 60, 64]
    assert SearchConfig(ef=16, k_bucket=10).bucket_k(15) == 16  # ef clamp
    with pytest.raises(ValueError):
        cfg.bucket_k(0)


def test_k_buckets_enumerates_every_reachable_bucket():
    cfg = SearchConfig(ef=64, k_bucket=10)
    assert cfg.k_buckets() == (10, 20, 30, 40, 50, 60, 64)
    assert SearchConfig(ef=32, k_bucket=10).k_buckets() == (10, 20, 30, 32)
    assert SearchConfig(ef=20, k_bucket=10).k_buckets() == (10, 20)
    # closure: bucket_k can only ever emit values from k_buckets()
    for cfg in (SearchConfig(ef=64, k_bucket=10),
                SearchConfig(ef=48, k_bucket=7)):
        got = {cfg.bucket_k(k) for k in range(1, cfg.ef + 1)}
        assert got == set(cfg.k_buckets())


def test_batch_buckets_ladder():
    assert config_mod.batch_buckets(64) == (1, 2, 4, 8, 16, 32, 64)
    assert config_mod.batch_buckets(48) == (1, 2, 4, 8, 16, 32, 48)
    assert config_mod.batch_buckets(1) == (1,)
    assert config_mod.batch_bucket(5, 64) == 8
    assert config_mod.batch_bucket(33, 48) == 48
    assert config_mod.batch_bucket(8, 64) == 8
    with pytest.raises(ValueError):
        config_mod.batch_bucket(65, 64)
    with pytest.raises(ValueError):
        config_mod.batch_bucket(0, 64)


def test_merge_shim_semantics():
    base = SearchConfig(ef=32)
    # None overrides are no-ops; non-None refine the given config
    assert config_mod.merge(base, ef=None, metric=None) is base
    assert config_mod.merge(base, expand_width=2).expand_width == 2
    # config=None + loose kwargs is the deprecated path
    got = config_mod.merge(None, ef=48, edge_impl="xla")
    assert got == SearchConfig(ef=48, edge_impl="xla")


def test_merge_warns_once_per_entry_point():
    import warnings

    where = "test-entry-point-unique"
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        config_mod.merge(None, ef=8, _warn_where=where)
        config_mod.merge(None, ef=8, _warn_where=where)
    assert sum(issubclass(x.category, DeprecationWarning) for x in w) == 1


# ---------------------------------------------------------------------------
# build-chunk auto-tuner
# ---------------------------------------------------------------------------

def test_auto_chunk_budget_math():
    budget = 16 << 20
    # chunk * C * d * 4 stays inside the budget (power of two, clamped)
    for C, d in [(80, 64), (128, 64), (48, 64), (80, 128), (1024, 1024)]:
        chunk = build_mod.auto_chunk(C, d, budget_bytes=budget)
        assert chunk & (chunk - 1) == 0 or chunk in (256, 8192)
        if chunk not in (256, 8192):  # unclamped: tight fit
            assert chunk * C * d * 4 <= budget < 2 * chunk * C * d * 4
    # monotone: wider candidate sets get smaller chunks
    assert build_mod.auto_chunk(48, 64) >= build_mod.auto_chunk(80, 64) >= \
        build_mod.auto_chunk(128, 128)
    # clamps
    assert build_mod.auto_chunk(1, 1, budget_bytes=1 << 30) == 8192
    assert build_mod.auto_chunk(4096, 4096, budget_bytes=1 << 20) == 256


def test_resolve_chunk_override():
    assert build_mod.resolve_chunk(BuildConfig(chunk=777), 80, 64) == 777
    auto = build_mod.resolve_chunk(BuildConfig(), 80, 64)
    assert auto == build_mod.auto_chunk(80, 64)


def test_auto_chunk_build_matches_explicit(tmp_path):
    """cfg.chunk=None (auto) builds the exact same table as any explicit
    chunk (chunk invariance), and the level_times record carries the
    chunks actually used."""
    rng = np.random.default_rng(3)
    vectors = rng.standard_normal((256, 8)).astype(np.float32)
    base = dict(m=4, ef_construction=16, brute_threshold=16)
    times: list = []
    auto = build_mod.build_neighbor_table(
        vectors, BuildConfig(**base), level_times=times
    )
    explicit = build_mod.build_neighbor_table(
        vectors, BuildConfig(**base, chunk=64)
    )
    np.testing.assert_array_equal(auto, explicit)
    assert times and all(
        lt["chunk"] >= 1 and lt["chunk_reverse"] >= 1 for lt in times
    )
    # BuildConfig(chunk=None) round-trips through save/load serialization
    import dataclasses as dc
    cfg2 = BuildConfig(**dc.asdict(BuildConfig(**base)))
    assert cfg2.chunk is None
