"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret),
plus the dispatch guard: unknown backend tokens must raise instead of
silently routing through the interpreted Pallas path on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.distance as dist_k
import repro.kernels.flash_attention as flash_k
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# pairwise distance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize(
    "bq,n,d",
    [(8, 8, 8), (16, 32, 24), (37, 65, 40), (128, 128, 64), (3, 200, 130)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_dist_sweep(metric, bq, n, d, dtype):
    rng = np.random.default_rng(bq * 1000 + n + d)
    q = jnp.asarray(rng.standard_normal((bq, d)), dtype)
    x = jnp.asarray(rng.standard_normal((n, d)), dtype)
    got = dist_k.pairwise_dist_kernel_call(
        q, x, metric=metric, block_q=16, block_n=32, block_k=16,
        interpret=True,
    )
    want = ref.pairwise_dist(q, x, metric=metric)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_pairwise_dist_ordering_preserved():
    """Distances drive top-k choices; ordering must match the oracle."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((256, 32)), jnp.float32)
    got = dist_k.pairwise_dist_kernel_call(q, x, interpret=True)
    want = ref.pairwise_dist(q, x)
    np.testing.assert_array_equal(
        np.argsort(np.asarray(got), axis=1)[:, :10],
        np.argsort(np.asarray(want), axis=1)[:, :10],
    )


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _mk(B, Hq, Hkv, Sq, Skv, Dh, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, Hq, Sq, Dh)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Skv, Dh)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Skv, Dh)), dtype)
    return q, k, v


@pytest.mark.parametrize(
    "B,Hq,Hkv,S,Dh",
    [(1, 2, 2, 32, 16), (2, 4, 2, 64, 32), (1, 8, 1, 48, 16),
     (1, 2, 2, 100, 24)],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_causal_gqa_sweep(B, Hq, Hkv, S, Dh, causal):
    q, k, v = _mk(B, Hq, Hkv, S, S, Dh, jnp.float32, seed=S)
    got = flash_k.flash_attention_kernel_call(
        q, k, v, causal=causal, block_q=16, block_k=16, interpret=True
    )
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("window", [8, 16, 64])
def test_flash_attention_local_window(window):
    q, k, v = _mk(1, 2, 2, 64, 64, 16, jnp.float32, seed=window)
    got = flash_k.flash_attention_kernel_call(
        q, k, v, causal=True, window=window, block_q=16, block_k=16,
        interpret=True,
    )
    want = ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


def test_flash_attention_softcap():
    q, k, v = _mk(1, 4, 4, 32, 32, 16, jnp.float32, seed=9)
    got = flash_k.flash_attention_kernel_call(
        q, k, v, causal=True, softcap=20.0, block_q=16, block_k=16,
        interpret=True,
    )
    want = ref.attention(q, k, v, causal=True, softcap=20.0)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


def test_flash_attention_decode_offset():
    """Decode: Sq=1 with a long KV and q_offset = Skv - 1."""
    q, k, v = _mk(2, 4, 2, 1, 128, 32, jnp.float32, seed=11)
    got = flash_k.flash_attention_kernel_call(
        q, k, v, causal=True, q_offset=127, block_q=8, block_k=32,
        interpret=True,
    )
    want = ref.attention(q, k, v, causal=True, q_offset=127)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


def test_flash_attention_bf16():
    q, k, v = _mk(1, 2, 2, 64, 64, 32, jnp.bfloat16, seed=4)
    got = flash_k.flash_attention_kernel_call(
        q, k, v, causal=True, block_q=32, block_k=32, interpret=True
    )
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2,
    )


def _dispatch_calls():
    """One tiny call per public op, keyed by name, for the guard tests."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 8)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 16, (2, 4)).astype(np.int32))
    nbrs = jnp.asarray(rng.integers(-1, 16, (16, 5, 4)).astype(np.int32))
    us = jnp.asarray(np.array([0, 1], np.int32))
    L = jnp.zeros(2, jnp.int32)
    R = jnp.full(2, 15, jnp.int32)
    du = jnp.asarray(rng.standard_normal((2, 6)) ** 2, jnp.float32)
    cand = jnp.asarray(rng.integers(0, 16, (2, 6)).astype(np.int32))
    aq, ak, av = (jnp.asarray(rng.standard_normal((1, 2, 8, 4)), jnp.float32)
                  for _ in range(3))
    from repro.core import bitset

    vis = bitset.make(2, 16)
    exp = jnp.ones((2, 1), bool)
    return {
        "pairwise_dist": lambda impl: ops.pairwise_dist(q, x, impl=impl),
        "gather_dist": lambda impl: ops.gather_dist(q, x, ids, impl=impl),
        "select_edges": lambda impl: ops.select_edges(
            nbrs, us, L, R, logn=4, m_out=4, impl=impl),
        "prune": lambda impl: ops.prune(cand, du, x, m=4, impl=impl),
        "hop": lambda impl: ops.hop(
            q, x, nbrs, us[:, None], L, R, vis, exp, logn=4, m_out=4,
            impl=impl),
        "flash_attention": lambda impl: ops.flash_attention(
            aq, ak, av, impl=impl),
    }


@pytest.mark.parametrize("op", ["pairwise_dist", "gather_dist",
                                "select_edges", "prune", "hop",
                                "flash_attention"])
def test_unknown_impl_token_rejected(op):
    with pytest.raises(ValueError, match=f"{op}: unknown impl"):
        _dispatch_calls()[op]("bogus")


def test_flash_attention_rejects_foreign_tokens():
    """The PR-3 regression: a global REPRO_IMPL=legacy (the prune-only
    token) or "argsort" (edge-only) must error on flash_attention, not
    silently run the interpreted Pallas kernel on CPU."""
    calls = _dispatch_calls()
    for tok in ("legacy", "argsort"):
        with pytest.raises(ValueError, match="flash_attention: unknown"):
            calls["flash_attention"](tok)


def test_flash_attention_global_env_checked(monkeypatch):
    calls = _dispatch_calls()
    monkeypatch.setenv("REPRO_IMPL", "legacy")
    with pytest.raises(ValueError, match="flash_attention: unknown"):
        calls["flash_attention"]("auto")
    # the op-specific var wins over the global, like every other dispatch
    monkeypatch.setenv("REPRO_FLASH_IMPL", "xla")
    out = calls["flash_attention"]("auto")
    assert out.shape == (1, 2, 8, 4)


def test_flash_attention_matches_unmasked_softmax_rows():
    """Numerical property: each output row is a convex combination of V."""
    q, k, v = _mk(1, 1, 1, 16, 16, 8, jnp.float32, seed=2)
    v = jnp.abs(v)
    got = np.asarray(
        flash_k.flash_attention_kernel_call(
            q, k, v, causal=False, block_q=8, block_k=8, interpret=True
        )
    )
    vmin = np.asarray(v).min(axis=2, keepdims=True)
    vmax = np.asarray(v).max(axis=2, keepdims=True)
    assert (got >= vmin - 1e-5).all() and (got <= vmax + 1e-5).all()
