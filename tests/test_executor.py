"""SearchExecutor: compile cache, batch/k buckets, AOT warmup, parity.

The acceptance contract of the executor layer:

  * a warmed executor serves a mixed workload (batch sizes 1..max_batch,
    mixed k) with ZERO post-warmup compiles — exact, because the executor
    compiles executables itself instead of trusting the jit cache;
  * results are bit-identical to the pre-refactor kwarg path
    (``RangeGraphIndex.search_ranks`` with loose kwargs) on the xla and
    pallas(interpret) backends — padding to batch buckets and k rounding
    can never leak into real rows.
"""
import numpy as np
import pytest

from repro.core import BuildConfig, RangeGraphIndex, SearchConfig
from repro.serve.executor import SearchExecutor


@pytest.fixture(scope="module")
def small_index():
    rng = np.random.default_rng(11)
    n, d = 256, 12
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 100, n)
    cfg = BuildConfig(m=8, ef_construction=32, brute_threshold=32)
    return RangeGraphIndex.build(vectors, attrs, cfg), rng


def _workload(rng, index, B):
    q = rng.standard_normal((B, index.dim)).astype(np.float32)
    L = rng.integers(0, index.n // 2, B).astype(np.int32)
    R = (L + rng.integers(8, index.n // 2, B)).astype(np.int32)
    return q, L, np.minimum(R, index.n - 1).astype(np.int32)


def test_warmup_then_zero_compiles(small_index):
    """warmup() compiles the full grid; a mixed workload spanning every
    batch size 1..max_batch and random k <= ef then hits only the cache."""
    idx, rng = small_index
    ex = SearchExecutor(idx, SearchConfig(ef=32, k_bucket=10), max_batch=8,
                        warmup=False)
    compiled = ex.warmup()
    assert compiled == ex.program_grid() == \
        len(ex.batch_buckets) * len(ex.config.k_buckets())
    assert ex.stats["warmup_compiles"] == compiled
    for B in list(range(1, 9)) * 2:
        q, L, R = _workload(rng, idx, B)
        k = int(rng.integers(1, 33))
        res = ex.search_ranks(q, L, R, k=k)
        assert res.ids.shape == (B, k)
    assert ex.stats["compiles"] == compiled  # zero post-warmup
    assert ex.stats["cache_hits"] == ex.stats["batches"]


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_bit_identical_to_kwarg_path(small_index, impl):
    """Executor results == the direct loose-kwarg search_ranks call, per
    kernel backend (pallas runs interpreted on CPU)."""
    idx, rng = small_index
    cfg = SearchConfig(ef=32, k_bucket=10, dist_impl=impl, edge_impl=impl)
    ex = SearchExecutor(idx, cfg, max_batch=8, warmup=False)
    for B, k in [(1, 3), (5, 10), (8, 7)]:
        q, L, R = _workload(rng, idx, B)
        got = ex.search_ranks(q, L, R, k=k)
        want = idx.search_ranks(q, L, R, k=cfg.bucket_k(k), ef=32,
                                dist_impl=impl, edge_impl=impl)
        np.testing.assert_array_equal(
            np.asarray(got.ids), np.asarray(want.ids)[:, :k]
        )
        np.testing.assert_array_equal(
            np.asarray(got.dists), np.asarray(want.dists)[:, :k]
        )


def test_padding_parity_exact_bucket(small_index):
    """B=5 (padded to the 8 bucket) is bit-identical to the same 5 rows
    inside an exact B=8 call."""
    idx, rng = small_index
    ex = SearchExecutor(idx, SearchConfig(ef=32), max_batch=8, warmup=False)
    q, L, R = _workload(rng, idx, 8)
    part = ex.search_ranks(q[:5], L[:5], R[:5], k=10)
    full = ex.search_ranks(q, L, R, k=10)
    np.testing.assert_array_equal(np.asarray(part.ids),
                                  np.asarray(full.ids)[:5])
    np.testing.assert_array_equal(np.asarray(part.dists),
                                  np.asarray(full.dists)[:5])


def test_oversize_batch_splits(small_index):
    """B > max_batch splits into max_batch chunks and concatenates — same
    results as one unsplit call at a bigger executor."""
    idx, rng = small_index
    q, L, R = _workload(rng, idx, 11)
    small = SearchExecutor(idx, SearchConfig(ef=32), max_batch=4,
                           warmup=False)
    big = SearchExecutor(idx, SearchConfig(ef=32), max_batch=16,
                         warmup=False)
    a = small.search_ranks(q, L, R, k=5)
    b = big.search_ranks(q, L, R, k=5)
    assert a.ids.shape == (11, 5)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    # 11 = 4 + 4 + 3; the 3-row tail pads to the 4 bucket
    assert small.stats["batches"] == 3 and small.stats["queries"] == 11


def test_pad_to_max_mode(small_index):
    """batch_buckets=(max_batch,) reproduces the historical always-pad-
    to-max engine: every batch runs at one shape."""
    idx, rng = small_index
    ex = SearchExecutor(idx, SearchConfig(ef=32), max_batch=8,
                        batch_buckets=(8,), warmup=False)
    for B in (1, 5, 8):
        q, L, R = _workload(rng, idx, B)
        ex.search_ranks(q, L, R, k=10)
    assert ex.stats["compiles"] == 1
    with pytest.raises(ValueError, match="end at max_batch"):
        SearchExecutor(idx, max_batch=8, batch_buckets=(4,))


def test_per_call_config_is_own_cache_axis(small_index):
    """A second config compiles its own programs; re-running either
    config's workload adds none."""
    idx, rng = small_index
    cfg_a = SearchConfig(ef=32, k_bucket=10)
    cfg_b = cfg_a.replace(expand_width=1)
    ex = SearchExecutor(idx, cfg_a, max_batch=4, warmup=False)
    q, L, R = _workload(rng, idx, 4)
    ex.search_ranks(q, L, R, k=10)
    ex.search_ranks(q, L, R, k=10, config=cfg_b)
    assert ex.stats["compiles"] == 2
    ex.search_ranks(q, L, R, k=10)
    ex.search_ranks(q, L, R, k=10, config=cfg_b)
    assert ex.stats["compiles"] == 2
    assert ex.stats["cache_hits"] == 2


def test_k_exceeding_ef_rejected(small_index):
    idx, rng = small_index
    ex = SearchExecutor(idx, SearchConfig(ef=16), max_batch=4, warmup=False)
    q, L, R = _workload(rng, idx, 2)
    with pytest.raises(ValueError, match="exceeds the config's ef"):
        ex.search_ranks(q, L, R, k=17)


def test_compact_index_serves(small_index):
    """A compact-storage index flows through the executor unchanged (the
    decode happens inside the compiled program) with bit-identical ids
    across neighbor codecs."""
    from repro.core import storage as storage_mod

    idx, rng = small_index
    idx16 = idx.astype_storage(
        storage_mod.StorageConfig(neighbor_dtype="int16")
    )
    q, L, R = _workload(rng, idx, 4)
    a = SearchExecutor(idx, SearchConfig(ef=32), max_batch=4,
                       warmup=False).search_ranks(q, L, R, k=5)
    b = SearchExecutor(idx16, SearchConfig(ef=32), max_batch=4,
                       warmup=False).search_ranks(q, L, R, k=5)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


def test_close_semantics(small_index):
    """close() makes further searches fail fast with ShutdownError (typed,
    not an attribute error off a cleared cache), keeps stats readable for
    post-mortem, and is idempotent."""
    from repro.serve.errors import ShutdownError

    idx, rng = small_index
    ex = SearchExecutor(idx, SearchConfig(ef=32, k_bucket=10), max_batch=4,
                        warmup=False)
    q, L, R = _workload(rng, idx, 2)
    ex.search_ranks(q, L, R, k=5)
    served_compiles = ex.stats["compiles"]
    ex.close()
    assert ex.closed
    with pytest.raises(ShutdownError):
        ex.search_ranks(q, L, R, k=5)
    assert ex.stats["compiles"] == served_compiles  # stats survive close
    ex.close()                                      # idempotent
