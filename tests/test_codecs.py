"""Quantized vector codecs end-to-end: laws, persistence, kernel parity.

The contract under test (``core/storage.py`` + DESIGN.md §9): int8 and PQ
vector tables decode *inside* the kernels (and inside ``kernels/ref.py``'s
jnp contracts) from the narrow representation — the widened f32 table never
exists in device memory — while all distance math stays f32. Persistence
flattens the codec structs into named, crc32-checked payload fields
(``vec_scales``, ``vec_codebook``, ``neighbors_lo``, ``rerank_scales``) so
a bit flip in any sidecar is caught and NAMED at load time.
"""
import hashlib

import msgpack
import numpy as np
import pytest

import jax.numpy as jnp

from repro import compressio
from repro.core import (
    BuildConfig, IndexCorruptionError, RangeGraphIndex, SearchConfig,
    StorageConfig, recall,
)
from repro.core import storage as storage_mod
from repro.kernels import ops, ref
from repro.kernels.gather_distance import gather_distance_kernel_call


# ---------------------------------------------------------------------------
# codec laws
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bound():
    """Symmetric round-to-nearest: |decode(x) - x| <= scale/2 per element,
    with scale = max|row| / 127."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 24)).astype(np.float32) * 3.0
    x[7] = 0.0  # all-zero row must not divide by zero
    enc = storage_mod.encode_vectors(x, StorageConfig.int8())
    assert isinstance(enc, storage_mod.Int8Vectors)
    assert enc.codes.dtype == np.int8
    assert enc.scales.dtype == np.float32
    dec = storage_mod.decode_vectors(enc)
    assert dec.dtype == np.float32
    bound = enc.scales[:, None] * 0.5 + 1e-6
    assert (np.abs(dec - x) <= bound).all()
    np.testing.assert_array_equal(dec[7], 0.0)
    # footprint: d int8 + one f32 scale vs d f32
    assert storage_mod.table_nbytes(enc) == x.shape[0] * (x.shape[1] + 4)


def test_pq_roundtrip_reconstruction():
    """PQ is lossy but must beat the trivial (all-zero) reconstruction by a
    wide margin on clusterable data, and be deterministic per seed."""
    rng = np.random.default_rng(1)
    centers = rng.standard_normal((8, 32)).astype(np.float32) * 4
    x = (centers[rng.integers(0, 8, 512)]
         + rng.standard_normal((512, 32)).astype(np.float32) * 0.1)
    enc = storage_mod.encode_vectors(x, StorageConfig.pq())
    assert isinstance(enc, storage_mod.PQVectors)
    assert enc.codes.dtype == np.uint8
    M = storage_mod.resolve_pq_m(32)
    assert enc.codebook.shape == (M, storage_mod.PQ_CENTROIDS, 32 // M)
    dec = storage_mod.decode_vectors(enc)
    assert dec.shape == x.shape and dec.dtype == np.float32
    mse = ((dec - x) ** 2).mean()
    assert mse < 0.25 * (x ** 2).mean()
    enc2 = storage_mod.encode_vectors(x, StorageConfig.pq())
    np.testing.assert_array_equal(enc2.codes, enc.codes)
    np.testing.assert_array_equal(enc2.codebook, enc.codebook)


def test_pq_m_validation():
    with pytest.raises(ValueError, match="does not divide"):
        storage_mod.resolve_pq_m(30, 7)
    assert storage_mod.resolve_pq_m(32, 8) == 8
    assert storage_mod.resolve_pq_m(32) == 8


def test_decode_rows_matches_full_decode():
    """``decode_rows(table, ids)`` — the jnp contract the refs and the
    legacy prune use — must agree with gathering from the full decode."""
    rng = np.random.default_rng(2)
    x = rng.standard_normal((128, 16)).astype(np.float32)
    ids = jnp.asarray(rng.integers(0, 128, (4, 9)).astype(np.int32))
    for cfg in (StorageConfig.int8(), StorageConfig.pq()):
        enc = storage_mod.encode_vectors(x, cfg)
        dev = storage_mod.as_device(enc)
        want = storage_mod.decode_vectors(enc)[np.asarray(ids)]
        got = np.asarray(storage_mod.decode_rows(dev, ids))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# index threading
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def codec_indexes():
    rng = np.random.default_rng(5)
    n, d = 1024, 32
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 100, n)
    cfg = BuildConfig(m=8, ef_construction=32, brute_threshold=32)
    idx32 = RangeGraphIndex.build(vectors, attrs, cfg,
                                  storage=StorageConfig())
    idx8 = idx32.astype_storage(StorageConfig.int8())
    idxpq = idx32.astype_storage(StorageConfig.pq())
    return idx32, idx8, idxpq, rng


def test_int8_index_footprint(codec_indexes):
    idx32, idx8, _, _ = codec_indexes
    assert isinstance(idx8.vectors, storage_mod.Int8Vectors)
    assert isinstance(idx8.neighbors, storage_mod.SplitNeighbors)
    assert idx8.rerank is None
    assert idx8.nbytes <= 0.40 * idx32.nbytes


def test_pq_index_footprint(codec_indexes):
    idx32, _, idxpq, _ = codec_indexes
    assert isinstance(idxpq.vectors, storage_mod.PQVectors)
    # navigation tables alone (codes + codebook + split ids + attrs) must
    # undercut int8; the int8 rerank sidecar rides on top
    nav = (storage_mod.table_nbytes(idxpq.vectors)
           + storage_mod.table_nbytes(idxpq.neighbors)
           + idxpq.attrs.nbytes)
    assert nav <= 0.35 * idx32.nbytes
    assert isinstance(idxpq.rerank, storage_mod.Int8Vectors)
    assert idxpq.nbytes <= 0.55 * idx32.nbytes


def test_split_neighbors_decode_exact(codec_indexes):
    """Segment-offset neighbor ids are a lossless codec on a real table."""
    idx32, idx8, _, _ = codec_indexes
    dec = storage_mod.decode_neighbors(idx8.neighbors)
    np.testing.assert_array_equal(np.asarray(dec), idx32.neighbors)


def test_rerank_recall_floor(codec_indexes):
    """PQ navigation + exact-sidecar rerank must recover the recall the
    lossy codes give up: rerank recall may not trail the no-rerank PQ
    search, and must land within 0.02 of the f32 baseline."""
    idx32, _, idxpq, rng = codec_indexes
    B, k = 32, 10
    q = rng.standard_normal((B, idx32.dim)).astype(np.float32)
    L = np.zeros(B, np.int32)
    R = np.full(B, idx32.n - 1, np.int32)
    gt, _ = idx32.brute_force(q, L, R, k=k)
    plain = SearchConfig(ef=64)
    rr = SearchConfig(ef=64, rerank=48)
    r32 = recall(np.asarray(idx32.search_ranks(q, L, R, k=k,
                                               config=plain).ids), gt)
    rpq = recall(np.asarray(idxpq.search_ranks(q, L, R, k=k,
                                               config=plain).ids), gt)
    rrr = recall(np.asarray(idxpq.search_ranks(q, L, R, k=k,
                                               config=rr).ids), gt)
    assert rrr >= rpq - 1e-9
    assert rrr >= r32 - 0.02


def test_int8_recall_close_to_f32(codec_indexes):
    idx32, idx8, _, rng = codec_indexes
    B, k = 32, 10
    q = rng.standard_normal((B, idx32.dim)).astype(np.float32)
    L = np.zeros(B, np.int32)
    R = np.full(B, idx32.n - 1, np.int32)
    gt, _ = idx32.brute_force(q, L, R, k=k)
    cfg = SearchConfig(ef=64)
    r32 = recall(np.asarray(idx32.search_ranks(q, L, R, k=k,
                                               config=cfg).ids), gt)
    r8 = recall(np.asarray(idx8.search_ranks(q, L, R, k=k,
                                             config=cfg).ids), gt)
    assert r8 >= r32 - 0.02


def test_degenerate_ranges_under_int8_env(monkeypatch):
    """REPRO_STORAGE=int8 build + empty / single-element ranges with
    expand_width > 1 through the full engine (the CI storage leg's shape)."""
    monkeypatch.setenv("REPRO_STORAGE", "int8")
    rng = np.random.default_rng(11)
    n, d = 256, 16
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 100, n)
    idx = RangeGraphIndex.build(
        vectors, attrs, BuildConfig(m=8, ef_construction=32,
                                    brute_threshold=32))
    assert isinstance(idx.vectors, storage_mod.Int8Vectors)
    q = rng.standard_normal((4, d)).astype(np.float32)
    cfg = SearchConfig(ef=16, expand_width=2)
    # empty ranges: all padding, zero hops
    L = np.array([10, 100, 255, 1], np.int32)
    res = idx.search_ranks(q, L, L - 1, k=5, config=cfg)
    assert (np.asarray(res.ids) == -1).all()
    assert (np.asarray(res.n_hops) == 0).all()
    # single-element ranges: the element itself, at its int8-decoded dist
    L = np.array([0, 17, 128, 255], np.int32)
    res = idx.search_ranks(q, L, L, k=4, config=cfg)
    ids = np.asarray(res.ids)
    np.testing.assert_array_equal(ids[:, 0], L)
    assert (ids[:, 1:] == -1).all()
    dec = storage_mod.decode_vectors(idx.vectors)
    want = ((dec[L] - q) ** 2).sum(1)
    np.testing.assert_allclose(np.asarray(res.dists)[:, 0], want,
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# kernel parity: fused in-kernel decode vs the jnp contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [StorageConfig.int8(), StorageConfig.pq()],
                         ids=["int8", "pq"])
def test_gather_dist_kernel_decodes_in_vmem(cfg):
    """Pallas gather+distance on a codec table vs ``ref.gather_dist`` on
    the same struct: identical inf/pad structure, f32-tolerance values."""
    rng = np.random.default_rng(3)
    B, n, d, M = 4, 128, 32, 9
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    table = storage_mod.as_device(storage_mod.encode_vectors(
        rng.standard_normal((n, d)).astype(np.float32), cfg))
    ids = jnp.asarray(rng.integers(-1, n, (B, M)).astype(np.int32))
    want = np.asarray(ref.gather_dist(q, table, ids))
    got = np.asarray(gather_distance_kernel_call(q, table, ids,
                                                 interpret=True))
    assert (np.isinf(got) == np.isinf(want)).all()
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cfg", [StorageConfig.int8(), StorageConfig.pq()],
                         ids=["int8", "pq"])
def test_prune_codec_table_backend_parity(cfg):
    """Construction prune on a codec table: xla / pallas / legacy must keep
    the same ids (the decode happens in-kernel for pallas, via
    ``decode_rows`` for the jnp paths)."""
    rng = np.random.default_rng(7)
    B, C, d, n, m = 4, 12, 16, 64, 4
    table = storage_mod.as_device(storage_mod.encode_vectors(
        rng.standard_normal((n, d)).astype(np.float32), cfg))
    dec = storage_mod.decode_vectors(table)
    ids = rng.integers(0, n, (B, C)).astype(np.int32)
    ids[rng.random((B, C)) < 0.2] = -1
    # external query points (not table rows): keep decisions away from the
    # f32-reassociation near-ties a self-distance fixture manufactures
    u = rng.standard_normal((B, d)).astype(np.float32)
    du = ((dec[np.maximum(ids, 0)] - u[:, None, :]) ** 2).sum(-1)
    du = np.where(ids < 0, np.inf, du).astype(np.float32)
    want = np.asarray(ops.prune(
        jnp.asarray(ids), jnp.asarray(du), table, m=m, impl="xla"))
    for impl in ("pallas", "legacy"):
        got = np.asarray(ops.prune(
            jnp.asarray(ids), jnp.asarray(du), table, m=m, impl=impl))
        np.testing.assert_array_equal(got, want, err_msg=impl)


# ---------------------------------------------------------------------------
# persistence: codec sidecars are named, checksummed payload fields
# ---------------------------------------------------------------------------

def _read_payload(path):
    with open(path, "rb") as f:
        outer = msgpack.unpackb(compressio.decompress(f.read()))
    return msgpack.unpackb(outer["payload"])


def _flip_field(src, dst, field):
    payload = _read_payload(src)
    data = bytearray(payload[field]["data"])
    data[len(data) // 2] ^= 0x40
    payload[field]["data"] = bytes(data)
    raw = msgpack.packb(payload)
    blob = msgpack.packb(
        {"sha256": hashlib.sha256(raw).hexdigest(), "payload": raw})
    with open(dst, "wb") as f:
        f.write(compressio.compress(blob, level=3))


@pytest.fixture(scope="module")
def saved_codecs(codec_indexes, tmp_path_factory):
    _, idx8, idxpq, _ = codec_indexes
    root = tmp_path_factory.mktemp("codecs")
    p8, ppq = str(root / "int8.bin"), str(root / "pq.bin")
    idx8.save(p8)
    idxpq.save(ppq)
    return p8, ppq


def test_save_load_roundtrip_int8(codec_indexes, saved_codecs):
    _, idx8, _, _ = codec_indexes
    loaded = RangeGraphIndex.load(saved_codecs[0])
    np.testing.assert_array_equal(loaded.vectors.codes, idx8.vectors.codes)
    np.testing.assert_array_equal(loaded.vectors.scales, idx8.vectors.scales)
    np.testing.assert_array_equal(loaded.neighbors.hi, idx8.neighbors.hi)
    np.testing.assert_array_equal(loaded.neighbors.lo, idx8.neighbors.lo)
    assert loaded.rerank is None


def test_save_load_roundtrip_pq(codec_indexes, saved_codecs):
    _, _, idxpq, _ = codec_indexes
    loaded = RangeGraphIndex.load(saved_codecs[1])
    np.testing.assert_array_equal(loaded.vectors.codes, idxpq.vectors.codes)
    np.testing.assert_array_equal(loaded.vectors.codebook,
                                  idxpq.vectors.codebook)
    np.testing.assert_array_equal(loaded.rerank.codes, idxpq.rerank.codes)
    np.testing.assert_array_equal(loaded.rerank.scales, idxpq.rerank.scales)
    assert loaded.nbytes == idxpq.nbytes


@pytest.mark.parametrize("which,field", [
    ("int8", "vectors"),
    ("int8", "vec_scales"),
    ("int8", "neighbors_lo"),
    ("pq", "vec_codebook"),
    ("pq", "rerank"),
    ("pq", "rerank_scales"),
])
def test_codec_bit_flip_names_the_field(saved_codecs, tmp_path, which, field):
    src = saved_codecs[0] if which == "int8" else saved_codecs[1]
    bad = str(tmp_path / f"flip_{which}_{field}.bin")
    _flip_field(src, bad, field)
    with pytest.raises(IndexCorruptionError, match="checksum mismatch") \
            as ei:
        RangeGraphIndex.load(bad)
    assert ei.value.field == field
    assert field in str(ei.value)


def test_loaded_codec_index_searches(codec_indexes, saved_codecs):
    """A reloaded PQ index (struct tables + rerank sidecar) answers
    queries identically to the in-memory one."""
    _, _, idxpq, rng = codec_indexes
    loaded = RangeGraphIndex.load(saved_codecs[1])
    q = rng.standard_normal((6, idxpq.dim)).astype(np.float32)
    L = np.zeros(6, np.int32)
    R = np.full(6, idxpq.n - 1, np.int32)
    cfg = SearchConfig(ef=32, rerank=16)
    a = idxpq.search_ranks(q, L, R, k=5, config=cfg)
    b = loaded.search_ranks(q, L, R, k=5, config=cfg)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
