"""Hypothesis compatibility shim for the property tests.

When ``hypothesis`` is installed the real library is used unchanged. When it
is absent (this container does not ship it) a minimal deterministic fallback
runs the same oracle checks over a fixed seed grid: ``@given`` re-runs the
test body ``min(max_examples, 25)`` times, drawing values from a seeded
``numpy`` Generator. Only the API surface the tests use is implemented
(``st.integers``, ``st.booleans``, ``st.sampled_from``, ``st.data``,
positional/keyword ``@given``, ``@settings``).
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect

    import numpy as _np

    HAVE_HYPOTHESIS = False

    _MAX_FALLBACK_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def example_from(self, rng):
            return self._draw_fn(rng)

    class _Data:
        """Stand-in for hypothesis' interactive ``data()`` object."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy):
            return strategy.example_from(self._rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))]
            )

        @staticmethod
        def data():
            return _Strategy(lambda rng: _Data(rng))

    st = _Strategies()

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # like hypothesis, drawn positionals fill the *last* parameter
            # slots; bind them by name so pytest fixtures (passed as
            # keywords) can occupy the leading slots without collision
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            pos_names = []
            if arg_strategies:
                pos_names = [p.name for p in params[-len(arg_strategies):]]
                params = params[: -len(arg_strategies)]
            params = [p for p in params if p.name not in kw_strategies]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(
                    getattr(wrapper, "_max_examples", 20),
                    _MAX_FALLBACK_EXAMPLES,
                )
                for example in range(n):
                    rng = _np.random.default_rng(0xC0FFEE + 7919 * example)
                    drawn = {
                        name: s.example_from(rng)
                        for name, s in zip(pos_names, arg_strategies)
                    }
                    kdrawn = {
                        k: s.example_from(rng)
                        for k, s in kw_strategies.items()
                    }
                    fn(*args, **kwargs, **drawn, **kdrawn)

            # hide drawn parameters from pytest's fixture resolution
            wrapper.__signature__ = sig.replace(parameters=params)
            return wrapper

        return deco
