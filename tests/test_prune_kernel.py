"""Fused construction prune must match the eager oracle bit-for-bit.

Three formulations are pinned against each other:

  * ``core/rng.py::prune``       — eager [C, C] matrix + C-step scan (the
    historical build path, kept as the oracle);
  * ``kernels/ref.py::prune``    — lazy-column jnp formulation (impl="xla");
  * ``kernels/prune.py``         — the Pallas kernel in interpret mode
    (impl="pallas"). Kept ids must be *bit-identical* across all of them —
    including duplicate candidates, all-invalid rows, ``alpha > 1`` and
    ``fill=False`` — and the full ``build_neighbor_table`` output must be
    invariant to both the prune backend and the chunk size.
"""
import numpy as np
from _hypo import given, settings, st

import jax.numpy as jnp

from repro.core import rng as rng_mod
from repro.core.build import BuildConfig, build_neighbor_table
from repro.kernels import ops


def oracle_prune(ids, du, table_np, m, alpha, fill):
    """rng.prune per row, fed the eager [C, C] matrix it expects."""
    cvec = table_np[np.maximum(ids, 0)]
    cc = rng_mod.pairwise_sq_dists(jnp.asarray(cvec))
    return np.stack([
        np.asarray(rng_mod.prune(
            jnp.asarray(ids[i]), jnp.asarray(du[i]), cc[i],
            m=m, alpha=alpha, fill=fill,
        ))
        for i in range(ids.shape[0])
    ])


def _draw_case(data):
    """Random (ids, du, table) with duplicate candidates + invalid slots."""
    B = data.draw(st.integers(1, 6))
    C = data.draw(st.integers(2, 24))
    d = data.draw(st.integers(2, 12))
    m = data.draw(st.integers(1, 8))
    n = data.draw(st.integers(C, 64))
    seed = data.draw(st.integers(0, 2**31))
    rng = np.random.default_rng(seed)
    table = rng.standard_normal((n, d)).astype(np.float32)
    ids = rng.integers(0, n, (B, C)).astype(np.int32)
    # duplicate a slot per row (same id -> same vector -> same distance)
    src = rng.integers(0, C, B)
    dst = rng.integers(0, C, B)
    ids[np.arange(B), dst] = ids[np.arange(B), src]
    ids = np.where(rng.random((B, C)) < 0.25, -1, ids).astype(np.int32)
    u = rng.standard_normal((B, d)).astype(np.float32)
    cvec = table[np.maximum(ids, 0)]
    du = ((cvec - u[:, None, :]) ** 2).sum(-1).astype(np.float32)
    du = np.where(ids < 0, np.inf, du)
    return ids, du, table, m


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_lazy_xla_bit_identical_to_oracle(data):
    ids, du, table, m = _draw_case(data)
    alpha = data.draw(st.sampled_from([1.0, 1.25, 2.0]))
    fill = data.draw(st.booleans())
    want = oracle_prune(ids, du, table, m, alpha, fill)
    got = np.asarray(ops.prune(
        jnp.asarray(ids), jnp.asarray(du), jnp.asarray(table),
        m=m, alpha=alpha, fill=fill, impl="xla",
    ))
    np.testing.assert_array_equal(got, want)


@given(st.data())
@settings(max_examples=10, deadline=None)
def test_pallas_kernel_bit_identical_to_oracle(data):
    ids, du, table, m = _draw_case(data)
    alpha = data.draw(st.sampled_from([1.0, 1.25]))
    fill = data.draw(st.booleans())
    want = oracle_prune(ids, du, table, m, alpha, fill)
    got = np.asarray(ops.prune(
        jnp.asarray(ids), jnp.asarray(du), jnp.asarray(table),
        m=m, alpha=alpha, fill=fill, impl="pallas",
    ))
    np.testing.assert_array_equal(got, want)


@given(st.data())
@settings(max_examples=15, deadline=None)
def test_legacy_dispatch_bit_identical_to_oracle(data):
    """ops.prune(impl="legacy") is the oracle path modulo the in-jit gather."""
    ids, du, table, m = _draw_case(data)
    want = oracle_prune(ids, du, table, m, 1.0, True)
    got = np.asarray(ops.prune(
        jnp.asarray(ids), jnp.asarray(du), jnp.asarray(table),
        m=m, alpha=1.0, fill=True, impl="legacy",
    ))
    np.testing.assert_array_equal(got, want)


def test_all_invalid_rows_and_short_candidate_lists():
    table = np.eye(4, dtype=np.float32)
    ids = np.array([[-1, -1, -1], [2, -1, 1]], np.int32)
    du = np.where(ids < 0, np.inf, 1.0).astype(np.float32)
    for impl in ("xla", "pallas", "legacy"):
        got = np.asarray(ops.prune(
            jnp.asarray(ids), jnp.asarray(du), jnp.asarray(table),
            m=5, alpha=1.0, fill=True, impl=impl,
        ))
        assert got.shape == (2, 5)
        assert (got[0] == -1).all()
        # fewer valid candidates than m: kept ids then -1 padding
        assert set(got[1][got[1] >= 0].tolist()) <= {1, 2}
        np.testing.assert_array_equal(got[1][2:], [-1, -1, -1])


def test_fill_pads_with_nearest_pruned_all_backends():
    # three collinear points: the middle one prunes the far one
    table = np.array([[1, 0], [2, 0], [10, 0], [0, 0]], np.float32)
    ids = np.array([[0, 1, 2]], np.int32)
    du = ((table[:3] - table[3]) ** 2).sum(1)[None].astype(np.float32)
    for impl in ("xla", "pallas", "legacy"):
        nofill = np.asarray(ops.prune(
            jnp.asarray(ids), jnp.asarray(du), jnp.asarray(table),
            m=3, fill=False, impl=impl,
        ))[0]
        fl = np.asarray(ops.prune(
            jnp.asarray(ids), jnp.asarray(du), jnp.asarray(table),
            m=3, fill=True, impl=impl,
        ))[0]
        assert [int(x) for x in nofill] == [0, -1, -1], impl
        assert [int(x) for x in fl] == [0, 1, 2], impl


def test_build_table_invariant_to_backend_and_chunk():
    """The full build output is bit-identical across prune backends and
    chunk sizes (chunking must not leak into per-node results)."""
    rng = np.random.default_rng(7)
    vectors = rng.standard_normal((256, 16)).astype(np.float32)
    cfg = dict(m=6, ef_construction=16, brute_threshold=32)
    want = build_neighbor_table(
        vectors, BuildConfig(**cfg, chunk=128, prune_impl="legacy")
    )
    for impl, chunk in (("xla", 128), ("xla", 48), ("legacy", 48)):
        got = build_neighbor_table(
            vectors, BuildConfig(**cfg, chunk=chunk, prune_impl=impl)
        )
        np.testing.assert_array_equal(got, want, err_msg=f"{impl}/{chunk}")


def test_dispatch_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PRUNE_IMPL", "legacy")
    assert ops.default_impl("prune") == "legacy"
    monkeypatch.setenv("REPRO_IMPL", "xla")
    assert ops.default_impl("prune") == "legacy"  # specific var wins
    assert ops.default_impl("edge") == "xla"
    monkeypatch.delenv("REPRO_PRUNE_IMPL")
    assert ops.default_impl("prune") == "xla"
