"""Vectorized Algorithm 1 must match the literal paper transcription."""
import numpy as np
from _hypo import given, settings, st

from repro.core import edge_select


def make_nbrs(rng, n, layers, m, logn):
    """Random but structurally valid neighbor table: edges at layer lay stay
    within the segment of their source node."""
    nbrs = np.full((n, layers, m), -1, np.int32)
    for u in range(n):
        for lay in range(layers):
            s = logn - lay
            lo = (u >> s) << s
            hi = min(lo + (1 << s) - 1, n - 1)
            if hi <= lo:
                continue
            deg = rng.integers(0, m + 1)
            if deg:
                cands = rng.integers(lo, hi + 1, deg)
                cands = cands[cands != u]
                nbrs[u, lay, : len(cands)] = cands
    return nbrs


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_select_edges_matches_reference(data):
    logn = data.draw(st.integers(2, 6))
    n = 1 << logn
    m = data.draw(st.integers(2, 6))
    layers = logn + 1
    seed = data.draw(st.integers(0, 2**31))
    rng = np.random.default_rng(seed)
    nbrs = make_nbrs(rng, n, layers, m, logn)
    L = data.draw(st.integers(0, n - 1))
    R = data.draw(st.integers(L, n - 1))
    u = data.draw(st.integers(L, R))
    for skip in (True, False):
        got = np.asarray(
            edge_select.select_edges(
                nbrs[u], u, L, R, logn=logn, m_out=m, skip_layers=skip
            )
        )
        want = edge_select.select_edges_reference(
            nbrs[u], u, L, R, logn=logn, m_out=m, skip_layers=skip
        )
        got = [int(x) for x in got if x >= 0]
        assert got == want, (u, L, R, skip, got, want)


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_selected_edges_always_in_range(data):
    logn = data.draw(st.integers(2, 6))
    n = 1 << logn
    m = 4
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    nbrs = make_nbrs(rng, n, logn + 1, m, logn)
    L = data.draw(st.integers(0, n - 1))
    R = data.draw(st.integers(L, n - 1))
    us = np.arange(L, R + 1, dtype=np.int32)
    out = np.asarray(
        edge_select.select_edges_batch(
            nbrs, us, np.int32(L), np.int32(R), logn=logn, m_out=m
        )
    )
    sel = out[out >= 0]
    assert ((sel >= L) & (sel <= R)).all()
    # no self loops, no duplicates per row
    for i, row in enumerate(out):
        row = row[row >= 0]
        assert (row != us[i]).all()
        assert len(set(row.tolist())) == len(row)


def test_full_range_uses_root_only():
    logn, m = 4, 3
    n = 1 << logn
    rng = np.random.default_rng(0)
    nbrs = make_nbrs(rng, n, logn + 1, m, logn)
    u = 5
    got = np.asarray(
        edge_select.select_edges(
            nbrs[u], u, 0, n - 1, logn=logn, m_out=m
        )
    )
    root = set(int(x) for x in nbrs[u, 0] if x >= 0 and x != u)
    assert set(int(x) for x in got if x >= 0) <= root
