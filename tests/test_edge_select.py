"""Vectorized Algorithm 1 must match the literal paper transcription.

Three formulations are pinned against each other:

  * ``select_edges_reference`` — literal Python transcription (the oracle);
  * ``core/edge_select.py``    — historical stable-argsort formulation;
  * ``kernels/ops.select_edges`` — production sort-free paths: the jnp
    formulation (impl="xla") and the Pallas kernel in interpret mode
    (impl="pallas"). Ids must be *bit-identical* across all of them,
    including degenerate ranges (L > R, L == R) and -1 frontier slots.
"""
import numpy as np
from _hypo import given, settings, st

from repro.core import edge_select
from repro.kernels import ops, ref as kref


def make_nbrs(rng, n, layers, m, logn):
    """Random but structurally valid neighbor table: edges at layer lay stay
    within the segment of their source node."""
    nbrs = np.full((n, layers, m), -1, np.int32)
    for u in range(n):
        for lay in range(layers):
            s = logn - lay
            lo = (u >> s) << s
            hi = min(lo + (1 << s) - 1, n - 1)
            if hi <= lo:
                continue
            deg = rng.integers(0, m + 1)
            if deg:
                cands = rng.integers(lo, hi + 1, deg)
                cands = cands[cands != u]
                nbrs[u, lay, : len(cands)] = cands
    return nbrs


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_select_edges_matches_reference(data):
    logn = data.draw(st.integers(2, 6))
    n = 1 << logn
    m = data.draw(st.integers(2, 6))
    layers = logn + 1
    seed = data.draw(st.integers(0, 2**31))
    rng = np.random.default_rng(seed)
    nbrs = make_nbrs(rng, n, layers, m, logn)
    L = data.draw(st.integers(0, n - 1))
    R = data.draw(st.integers(L, n - 1))
    u = data.draw(st.integers(L, R))
    for skip in (True, False):
        got = np.asarray(
            edge_select.select_edges(
                nbrs[u], u, L, R, logn=logn, m_out=m, skip_layers=skip
            )
        )
        want = edge_select.select_edges_reference(
            nbrs[u], u, L, R, logn=logn, m_out=m, skip_layers=skip
        )
        got = [int(x) for x in got if x >= 0]
        assert got == want, (u, L, R, skip, got, want)


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_selected_edges_always_in_range(data):
    logn = data.draw(st.integers(2, 6))
    n = 1 << logn
    m = 4
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    nbrs = make_nbrs(rng, n, logn + 1, m, logn)
    L = data.draw(st.integers(0, n - 1))
    R = data.draw(st.integers(L, n - 1))
    us = np.arange(L, R + 1, dtype=np.int32)
    out = np.asarray(
        edge_select.select_edges_batch(
            nbrs, us, np.int32(L), np.int32(R), logn=logn, m_out=m
        )
    )
    sel = out[out >= 0]
    assert ((sel >= L) & (sel <= R)).all()
    # no self loops, no duplicates per row
    for i, row in enumerate(out):
        row = row[row >= 0]
        assert (row != us[i]).all()
        assert len(set(row.tolist())) == len(row)


def test_full_range_uses_root_only():
    logn, m = 4, 3
    n = 1 << logn
    rng = np.random.default_rng(0)
    nbrs = make_nbrs(rng, n, logn + 1, m, logn)
    u = 5
    got = np.asarray(
        edge_select.select_edges(
            nbrs[u], u, 0, n - 1, logn=logn, m_out=m
        )
    )
    root = set(int(x) for x in nbrs[u, 0] if x >= 0 and x != u)
    assert set(int(x) for x in got if x >= 0) <= root


# ---------------------------------------------------------------------------
# sort-free formulations (XLA + Pallas interpret) vs the argsort path
# ---------------------------------------------------------------------------

def _draw_case(data):
    """Random (nbrs, us, L, R, logn, m, m_out) incl. degenerate ranges."""
    logn = data.draw(st.integers(2, 6))
    n = data.draw(st.integers((1 << (logn - 1)) + 1, 1 << logn))
    m = data.draw(st.integers(2, 6))
    layers = logn + 1
    m_out = data.draw(st.integers(1, min(8, layers * m)))
    seed = data.draw(st.integers(0, 2**31))
    rng = np.random.default_rng(seed)
    nbrs = make_nbrs(rng, n, layers, m, logn)
    kind = data.draw(st.integers(0, 3))
    if kind == 0:     # ordinary
        L = data.draw(st.integers(0, n - 1))
        R = data.draw(st.integers(L, n - 1))
    elif kind == 1:   # empty: L > R
        L = data.draw(st.integers(1, n - 1))
        R = L - 1
    elif kind == 2:   # single element
        L = R = data.draw(st.integers(0, n - 1))
    else:             # whole domain
        L, R = 0, n - 1
    F = data.draw(st.integers(1, 12))
    us = rng.integers(-1, n, F).astype(np.int32)  # -1 = inactive slot
    return nbrs, us, L, R, logn, m, m_out


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_sort_free_xla_bit_identical_to_argsort(data):
    nbrs, us, L, R, logn, m, m_out = _draw_case(data)
    for skip in (True, False):
        want = np.asarray(edge_select.select_edges_batch(
            nbrs, us, np.int32(L), np.int32(R),
            logn=logn, m_out=m_out, skip_layers=skip,
        ))
        got = np.asarray(ops.select_edges(
            nbrs, us, np.int32(L), np.int32(R),
            logn=logn, m_out=m_out, skip_layers=skip, impl="xla",
        ))
        np.testing.assert_array_equal(got, want)


@given(st.data())
@settings(max_examples=10, deadline=None)
def test_pallas_kernel_bit_identical_to_argsort(data):
    nbrs, us, L, R, logn, m, m_out = _draw_case(data)
    for skip in (True, False):
        want = np.asarray(edge_select.select_edges_batch(
            nbrs, us, np.int32(L), np.int32(R),
            logn=logn, m_out=m_out, skip_layers=skip,
        ))
        got = np.asarray(ops.select_edges(
            nbrs, us, np.int32(L), np.int32(R),
            logn=logn, m_out=m_out, skip_layers=skip, impl="pallas",
        ))
        np.testing.assert_array_equal(got, want)


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_sort_free_matches_python_reference(data):
    """The jnp sort-free path against the literal Algorithm 1 oracle."""
    logn = data.draw(st.integers(2, 6))
    n = 1 << logn
    m = data.draw(st.integers(2, 6))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    nbrs = make_nbrs(rng, n, logn + 1, m, logn)
    L = data.draw(st.integers(0, n - 1))
    R = data.draw(st.integers(L, n - 1))
    u = data.draw(st.integers(L, R))
    for skip in (True, False):
        got = np.asarray(kref.select_edges(
            nbrs, np.array([u], np.int32), np.int32(L), np.int32(R),
            logn=logn, m_out=m, skip_layers=skip,
        ))[0]
        want = edge_select.select_edges_reference(
            nbrs[u], u, L, R, logn=logn, m_out=m, skip_layers=skip
        )
        assert [int(x) for x in got if x >= 0] == want


def test_sort_free_per_row_ranges():
    """ops.select_edges takes per-row L/R (the flattened-frontier contract)."""
    logn, m = 4, 4
    n = 1 << logn
    rng = np.random.default_rng(5)
    nbrs = make_nbrs(rng, n, logn + 1, m, logn)
    us = np.array([3, 7, 12, -1], np.int32)
    L = np.array([0, 4, 12, 0], np.int32)
    R = np.array([7, 11, 12, 15], np.int32)  # row 2: L == R (empty after !=u)
    for impl in ("xla", "pallas"):
        got = np.asarray(ops.select_edges(
            nbrs, us, L, R, logn=logn, m_out=m, impl=impl,
        ))
        want = np.stack([
            np.asarray(edge_select.select_edges_batch(
                nbrs, us[i:i + 1], L[i], R[i], logn=logn, m_out=m,
            ))[0]
            for i in range(4)
        ])
        np.testing.assert_array_equal(got, want)


def test_dispatch_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_EDGE_IMPL", "xla")
    assert ops.default_impl("edge") == "xla"
    monkeypatch.setenv("REPRO_IMPL", "pallas")
    assert ops.default_impl("edge") == "xla"   # specific var wins
    assert ops.default_impl("dist") == "pallas"
    monkeypatch.delenv("REPRO_EDGE_IMPL")
    assert ops.default_impl("edge") == "pallas"
