"""ServingEngine: batching, k-bucketing, and retrace accounting.

``k`` is a static argument of the jitted search, so every distinct value
the engine forwards is a full retrace. The engine therefore rounds each
batch's max requested k up to the next ``k_bucket`` multiple; mixed-k
workloads must hit a bounded set of compiles, tracked by
``stats["compiles"]``.
"""
import numpy as np
import pytest

from repro.core import BuildConfig, RangeGraphIndex
from repro.serve.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def small_index():
    rng = np.random.default_rng(23)
    n, d = 256, 12
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 100, n)
    cfg = BuildConfig(m=8, ef_construction=32, brute_threshold=32)
    return RangeGraphIndex.build(vectors, attrs, cfg), rng


def _requests(rng, index, ks):
    reqs = []
    for k in ks:
        v = rng.standard_normal(index.dim).astype(np.float32)
        lo, hi = sorted(rng.uniform(0, 100, 2))
        reqs.append(Request(vector=v, lo=lo, hi=hi, k=k))
    return reqs


def test_mixed_k_single_bucket(small_index):
    """Every k <= k_bucket rounds to one bucket: exactly one trace."""
    idx, rng = small_index
    eng = ServingEngine(idx, ef=32, max_batch=4, k_bucket=10)
    for r in _requests(rng, idx, [3, 7, 10, 1, 9, 10, 2, 5]):
        eng.submit(r)
    results = eng.flush()
    assert len(results) == 8
    assert eng.stats["compiles"] == 1
    assert eng.stats["served"] == 8


def test_k_buckets_bound_compiles(small_index):
    """ks spanning two buckets produce exactly two traces, rounded up."""
    idx, rng = small_index
    eng = ServingEngine(idx, ef=32, max_batch=2, k_bucket=10)
    for r in _requests(rng, idx, [3, 7, 12, 15, 20, 9]):
        eng.submit(r)
    eng.flush()
    # batches [3,7] -> 10, [12,15] -> 20, [20,9] -> 20: two buckets
    assert eng.stats["compiles"] == 2
    assert eng._k_buckets == {10, 20}


def test_bucket_rounding_preserves_requested_k(small_index):
    """Each result is cut back to the request's own k."""
    idx, rng = small_index
    eng = ServingEngine(idx, ef=32, max_batch=8, k_bucket=10)
    ks = [3, 12, 7]
    for r in _requests(rng, idx, ks):
        eng.submit(r)
    results = eng.flush()
    for r, k in zip(results, ks):
        assert r.ids.shape == (k,)
        assert r.dists.shape == (k,)


def test_results_respect_value_range(small_index):
    idx, rng = small_index
    eng = ServingEngine(idx, ef=32, max_batch=4, k_bucket=5)
    reqs = _requests(rng, idx, [5] * 6)
    for r in reqs:
        eng.submit(r)
    results = eng.flush()
    attrs_orig = np.empty(idx.n)
    attrs_orig[idx.perm] = idx.attrs  # attribute value per original id
    for req, res in zip(reqs, results):
        got = res.ids[res.ids >= 0]
        assert ((attrs_orig[got] >= req.lo) & (attrs_orig[got] <= req.hi)).all()


def test_bucketed_k_clamps_to_ef(small_index):
    """Bucketing must never push the static k past ef (top_k limit), and
    k > ef requests are rejected at submit time."""
    idx, rng = small_index
    eng = ServingEngine(idx, ef=16, max_batch=4, k_bucket=10)
    for r in _requests(rng, idx, [15, 11]):  # bucket would be 20 > ef
        eng.submit(r)
    results = eng.flush()
    assert eng._k_buckets == {16}
    assert results[0].ids.shape == (15,)
    with pytest.raises(ValueError, match="exceeds the engine's ef"):
        eng.submit(Request(vector=np.zeros(idx.dim, np.float32),
                           lo=0.0, hi=1.0, k=17))
