"""ServingEngine over SearchExecutor: batching, bucketing, compile gates.

``k`` and the batch shape are static for the jitted search, so every
distinct (config, batch_bucket, k_bucket) is one compiled program. The
engine rounds each request's k up to the next ``k_bucket`` multiple and
groups the flush per k bucket; the executor pads each batch to a
power-of-two bucket and serves it from an AOT compile cache. Compile
counts are exact (the executor compiles executables itself), so the tests
gate them hard:

  * mixed workloads compile at most ``len(k_buckets) * len(batch_buckets)
    * len(configs)`` programs (the compile-count gate, also enforced in
    ``benchmarks/ci_gate.py``);
  * padding parity: a flush of B < bucket requests is bit-identical to the
    same B requests served inside an exactly-bucket-sized flush;
  * per-request latency percentiles (p50/p95/p99) come from each request's
    own queue+batch time, not the whole-batch wall time.

Engines that assert exact compile counts pass ``warmup=False`` so the CI
executor-warmup leg (``REPRO_SERVE_WARMUP=1``) cannot skew them.
"""
import numpy as np
import pytest

from repro.core import BuildConfig, RangeGraphIndex, SearchConfig
from repro.core import config as config_mod
from repro.serve.engine import Request, ServingEngine, bucket_k


@pytest.fixture(scope="module")
def small_index():
    rng = np.random.default_rng(23)
    n, d = 256, 12
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 100, n)
    cfg = BuildConfig(m=8, ef_construction=32, brute_threshold=32)
    return RangeGraphIndex.build(vectors, attrs, cfg), rng


def _requests(rng, index, ks):
    reqs = []
    for k in ks:
        v = rng.standard_normal(index.dim).astype(np.float32)
        lo, hi = sorted(rng.uniform(0, 100, 2))
        reqs.append(Request(vector=v, lo=lo, hi=hi, k=k))
    return reqs


def test_mixed_k_single_bucket(small_index):
    """Every k <= k_bucket rounds to one bucket; 8 requests at max_batch=4
    flush as two full batches -> exactly one compiled program."""
    idx, rng = small_index
    eng = ServingEngine(idx, config=SearchConfig(ef=32, k_bucket=10),
                        max_batch=4, warmup=False)
    for r in _requests(rng, idx, [3, 7, 10, 1, 9, 10, 2, 5]):
        eng.submit(r)
    results = eng.flush()
    assert len(results) == 8
    assert eng.stats["compiles"] == 1     # one (config, B=4, k=10) program
    assert eng.stats["served"] == 8
    assert eng._k_buckets == {10}


def test_k_buckets_bound_compiles(small_index):
    """ks spanning two k buckets sub-batch per bucket: each bucket's group
    cuts into a full batch and a remainder, so the program count is
    exactly len(k_buckets) * len(batch_buckets seen)."""
    idx, rng = small_index
    eng = ServingEngine(idx, config=SearchConfig(ef=32, k_bucket=10),
                        max_batch=2, warmup=False)
    for r in _requests(rng, idx, [3, 7, 12, 15, 20, 9]):
        eng.submit(r)
    eng.flush()
    # groups: k=10 -> [3, 7, 9], k=20 -> [12, 15, 20]; each runs as a
    # B=2 batch + a B=1 remainder -> 2 k buckets x 2 batch buckets
    assert eng._k_buckets == {10, 20}
    assert eng.stats["compiles"] == 4
    assert eng.stats["compiles"] <= (
        len(eng.config.k_buckets()) * len(eng.executor.batch_buckets)
    )


def test_compile_count_gate(small_index):
    """The hard gate: a mixed workload (random k <= ef, random batch
    sizes, two configs) compiles at most len(k_buckets) * len(batch_buckets)
    * len(configs) programs — the same bound benchmarks/ci_gate.py
    enforces on the hotpath serve-latency record."""
    idx, rng = small_index
    cfg_a = SearchConfig(ef=32, k_bucket=10)
    cfg_b = SearchConfig(ef=32, k_bucket=10, expand_width=2)
    eng = ServingEngine(idx, config=cfg_a, max_batch=8, warmup=False)
    workload = np.random.default_rng(7)
    for config in (cfg_a, cfg_b):
        for _ in range(12):
            B = int(workload.integers(1, eng.max_batch + 1))
            q = workload.standard_normal((B, idx.dim)).astype(np.float32)
            L = np.zeros(B, np.int32)
            R = np.full(B, idx.n - 1, np.int32)
            k = int(workload.integers(1, config.ef + 1))
            eng.executor.search_ranks(q, L, R, k=k, config=config)
    bound = eng.executor.program_grid(configs=(cfg_a, cfg_b))
    assert bound == (len(cfg_a.k_buckets()) + len(cfg_b.k_buckets())) * \
        len(eng.executor.batch_buckets)
    assert eng.stats["compiles"] <= bound


def test_zero_post_warmup_compiles(small_index):
    """A warmed engine serves any in-grid mixed workload without a single
    additional compile."""
    idx, rng = small_index
    eng = ServingEngine(idx, config=SearchConfig(ef=32, k_bucket=10),
                        max_batch=4, warmup=True)
    warm = eng.stats["compiles"]
    assert warm == eng.stats["warmup_compiles"] > 0
    for r in _requests(rng, idx, [1, 9, 12, 32, 4, 20, 31]):
        eng.submit(r)
    results = eng.flush()
    assert len(results) == 7
    assert eng.stats["compiles"] == warm  # zero post-warmup compiles


def test_warmup_applies_to_prebuilt_executor(small_index):
    """warmup=True warms a shared executor too, not only a fresh one."""
    from repro.serve.executor import SearchExecutor

    idx, rng = small_index
    ex = SearchExecutor(idx, SearchConfig(ef=32, k_bucket=10), max_batch=4,
                        warmup=False)
    eng = ServingEngine(idx, executor=ex, warmup=True)
    assert ex.stats["warmup_compiles"] == ex.stats["compiles"] == \
        ex.program_grid()
    for r in _requests(rng, idx, [3, 20]):
        eng.submit(r)
    eng.flush()
    assert eng.stats["compiles"] == eng.stats["warmup_compiles"]


def test_padding_parity(small_index):
    """A flush of B < bucket requests returns bit-identical results to the
    same B requests served at exactly bucket size: pads can never leak
    into real rows."""
    idx, rng = small_index
    reqs = _requests(rng, idx, [5] * 5)       # B=5 pads to the 8 bucket
    fillers = _requests(rng, idx, [5] * 3)    # completes an exact bucket
    cfg = SearchConfig(ef=32, k_bucket=5)
    eng_pad = ServingEngine(idx, config=cfg, max_batch=8, warmup=False)
    eng_full = ServingEngine(idx, config=cfg, max_batch=8, warmup=False)
    for r in reqs:
        eng_pad.submit(r)
        eng_full.submit(r)
    for r in fillers:
        eng_full.submit(r)
    got = eng_pad.flush()
    want = eng_full.flush()[:5]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g.ids, w.ids)
        np.testing.assert_array_equal(g.dists, w.dists)


def test_bucket_rounding_preserves_requested_k(small_index):
    """Each result is cut back to the request's own k."""
    idx, rng = small_index
    eng = ServingEngine(idx, config=SearchConfig(ef=32, k_bucket=10),
                        max_batch=8, warmup=False)
    ks = [3, 12, 7]
    for r in _requests(rng, idx, ks):
        eng.submit(r)
    results = eng.flush()
    for r, k in zip(results, ks):
        assert r.ids.shape == (k,)
        assert r.dists.shape == (k,)


def test_results_respect_value_range(small_index):
    idx, rng = small_index
    eng = ServingEngine(idx, config=SearchConfig(ef=32, k_bucket=5),
                        max_batch=4)
    reqs = _requests(rng, idx, [5] * 6)
    for r in reqs:
        eng.submit(r)
    results = eng.flush()
    attrs_orig = np.empty(idx.n)
    attrs_orig[idx.perm] = idx.attrs  # attribute value per original id
    for req, res in zip(reqs, results):
        got = res.ids[res.ids >= 0]
        assert ((attrs_orig[got] >= req.lo) & (attrs_orig[got] <= req.hi)).all()


def test_bucketed_k_clamps_to_ef(small_index):
    """Bucketing must never push the static k past ef (top_k limit), and
    k > ef requests are rejected at submit time."""
    idx, rng = small_index
    eng = ServingEngine(idx, config=SearchConfig(ef=16, k_bucket=10),
                        max_batch=4, warmup=False)
    for r in _requests(rng, idx, [15, 11]):  # bucket would be 20 > ef
        eng.submit(r)
    results = eng.flush()
    assert eng._k_buckets == {16}
    assert results[0].ids.shape == (15,)
    with pytest.raises(ValueError, match="exceeds the engine's ef"):
        eng.submit(Request(vector=np.zeros(idx.dim, np.float32),
                           lo=0.0, hi=1.0, k=17))
    # invalid k is rejected at the request boundary, never from flush —
    # a bad request must not be able to take the queued ones down with it
    with pytest.raises(ValueError, match="must be >= 1"):
        eng.submit(Request(vector=np.zeros(idx.dim, np.float32),
                           lo=0.0, hi=1.0, k=0))


def test_latency_percentiles(small_index):
    """Result.latency_s is the request's own queue+batch time and stats
    exposes ordered percentiles over all served requests."""
    idx, rng = small_index
    eng = ServingEngine(idx, config=SearchConfig(ef=32, k_bucket=10),
                        max_batch=4)
    for r in _requests(rng, idx, [5] * 6):
        eng.submit(r)
    results = eng.flush()
    for r in results:
        assert r.latency_s > 0.0
    s = eng.stats
    assert 0.0 < s["latency_p50"] <= s["latency_p95"] <= s["latency_p99"]
    assert s["latency_p99"] <= max(r.latency_s for r in results) + 1e-9
    # the whole-batch wall time is shared; per-request latencies are not
    assert len({r.latency_s for r in results}) >= 2  # two batches flushed


def test_validation_typed_errors(small_index):
    """Edge validation raises InvalidRequestError (a ValueError) for every
    malformed-request class BEFORE queueing — the queue never holds a
    request flush can't serve."""
    from repro.serve.errors import InvalidRequestError

    idx, rng = small_index
    eng = ServingEngine(idx, config=SearchConfig(ef=32, k_bucket=10),
                        max_batch=4, warmup=False)
    v = np.zeros(idx.dim, np.float32)
    cases = [
        (Request(np.zeros(idx.dim + 2, np.float32), 0.0, 1.0, k=5),
         "does not match index dim"),
        (Request(np.zeros((2, idx.dim), np.float32), 0.0, 1.0, k=5),
         "does not match index dim"),
        (Request(np.full(idx.dim, np.inf, np.float32), 0.0, 1.0, k=5),
         "NaN/Inf"),
        (Request(v, 5.0, 1.0, k=5), "inverted range"),
        (Request(v, np.nan, 1.0, k=5), "must not be NaN"),
        (Request(v, 0.0, np.nan, k=5), "must not be NaN"),
    ]
    for req, match in cases:
        with pytest.raises(InvalidRequestError, match=match):
            eng.submit(req)
    assert isinstance(InvalidRequestError("x"), ValueError)
    # open ranges are legal; the queue holds only servable requests
    eng.submit(Request(v, -np.inf, np.inf, k=5))
    assert len(eng.flush()) == 1


def test_flush_error_isolation(small_index):
    """An exception inside one batch fails only that batch's requests
    (their slots hold the exception) and the engine stays serviceable —
    the regression is submitting AFTER the failed flush."""
    from repro.serve.errors import InjectedFaultError
    from repro.serve.faults import FaultConfig, FaultInjector

    idx, rng = small_index
    inj = FaultInjector(FaultConfig(kinds=("flush_error",),
                                    flush_error_rate=1.0))
    eng = ServingEngine(idx, config=SearchConfig(ef=32, k_bucket=10),
                        max_batch=4, warmup=False, faults=inj)
    for r in _requests(rng, idx, [5, 5, 5]):
        eng.submit(r)
    out = eng.flush()
    assert len(out) == 3
    assert all(isinstance(o, InjectedFaultError) for o in out)
    assert eng.stats["failed"] == 3
    assert eng.stats["flush_failures"] == 1
    inj.armed = False
    for r in _requests(rng, idx, [5, 5]):    # engine still serviceable
        eng.submit(r)
    out = eng.flush()
    assert all(o.latency_s > 0 for o in out)
    assert eng.stats["served"] == 2


def test_flush_error_isolated_per_batch(small_index):
    """Two k-bucket groups flush as separate batches: a failure injected
    into the first leaves the second's results intact."""
    from repro.serve.errors import InjectedFaultError
    from repro.serve.faults import FaultConfig, FaultInjector

    idx, rng = small_index
    inj = FaultInjector(FaultConfig(kinds=("flush_error",),
                                    flush_error_rate=1.0))
    eng = ServingEngine(idx, config=SearchConfig(ef=32, k_bucket=10),
                        max_batch=4, warmup=False, faults=inj)
    orig = inj.maybe_flush_error

    def one_shot():   # fire on the first batch only, then disarm
        try:
            orig()
        finally:
            inj.armed = False

    inj.maybe_flush_error = one_shot
    for r in _requests(rng, idx, [5, 5, 15]):   # buckets 10 and 20
        eng.submit(r)
    out = eng.flush()
    fails = [o for o in out if isinstance(o, InjectedFaultError)]
    oks = [o for o in out if not isinstance(o, Exception)]
    assert len(fails) == 2 and len(oks) == 1    # only bucket-10 batch died
    assert eng.stats["flush_failures"] == 1


def test_close_drains_pending(small_index):
    from repro.serve.errors import ShutdownError

    idx, rng = small_index
    eng = ServingEngine(idx, config=SearchConfig(ef=32, k_bucket=10),
                        max_batch=4, warmup=False)
    for r in _requests(rng, idx, [5, 5]):
        eng.submit(r)
    out = eng.close(drain=True)
    assert len(out) == 2 and all(not isinstance(o, Exception) for o in out)
    with pytest.raises(ShutdownError):
        eng.submit(_requests(rng, idx, [5])[0])
    assert eng.close() == []   # idempotent


def test_close_no_drain_fails_pending_fast(small_index):
    from repro.serve.errors import ShutdownError

    idx, rng = small_index
    eng = ServingEngine(idx, config=SearchConfig(ef=32, k_bucket=10),
                        max_batch=4, warmup=False)
    for r in _requests(rng, idx, [5, 5, 5]):
        eng.submit(r)
    out = eng.close(drain=False)
    assert len(out) == 3
    assert all(isinstance(o, ShutdownError) for o in out)
    assert eng.stats["failed"] == 3
    assert eng.stats["served"] == 0


def test_close_leaves_shared_executor_open(small_index):
    from repro.serve.errors import ShutdownError
    from repro.serve.executor import SearchExecutor

    idx, rng = small_index
    ex = SearchExecutor(idx, SearchConfig(ef=32, k_bucket=10), max_batch=4,
                        warmup=False)
    eng = ServingEngine(idx, executor=ex)
    eng.close()
    assert not ex.closed                 # shared: caller owns its lifetime
    eng2 = ServingEngine(idx, config=SearchConfig(ef=32, k_bucket=10),
                         max_batch=4, warmup=False)
    eng2.close()
    assert eng2.executor.closed          # owned: closed with the engine
    with pytest.raises(ShutdownError):
        eng2.executor.search_ranks(
            np.zeros((1, idx.dim), np.float32),
            np.zeros(1, np.int32), np.full(1, idx.n - 1, np.int32), k=5,
        )


def test_legacy_kwargs_shim(small_index):
    """The historical loose-kwarg constructor still works (deprecation
    shim) and lands on the same config."""
    idx, rng = small_index
    eng = ServingEngine(idx, ef=32, max_batch=4, k_bucket=10, warmup=False)
    assert eng.config == SearchConfig(ef=32, k_bucket=10)
    assert eng.ef == 32 and eng.k_bucket == 10 and eng.max_batch == 4
    for r in _requests(rng, idx, [3, 7]):
        eng.submit(r)
    assert len(eng.flush()) == 2
    assert bucket_k(13, 10, 64) == 20 == SearchConfig(ef=64).bucket_k(13)
