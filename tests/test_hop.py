"""Whole-hop megakernel: bit-identity vs the composed three-op oracle.

The fused hop (``kernels/hop.py`` / ``ref.hop``) must reproduce the
composed select_edges -> bitset.test_and_set -> gather_dist path exactly:
integer outputs (edges, newly-visited mask, bitset words) bit-for-bit on
both the xla and pallas(interpret) backends, distances to f32 tolerance —
including compact (bf16 vectors + int16 neighbor) storage, degenerate
ranges, expand_width > 1, and bitset boundaries at n not a multiple of 32.
Plus the dispatch guards: unknown tokens raise, ``REPRO_HOP_IMPL`` wins
over ``REPRO_IMPL``, and a global ``REPRO_IMPL=legacy`` falls back to the
composed path instead of erroring.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SearchConfig, bitset
from repro.core import storage as storage_mod
from repro.core.search import beam_search
from repro.kernels import ops
from repro.kernels.edge_select import edge_select_kernel_call
from repro.kernels.hop import hop_kernel_call


def _mk(n=300, d=24, m=4, B=6, W=3, m_out=8, seed=0, full_range=False):
    """A structurally unconstrained hop problem (edges may be junk ids or
    -1; the hop must mask them identically on every backend)."""
    rng = np.random.default_rng(seed)
    logn = max(int(np.ceil(np.log2(max(n, 2)))), 1)
    layers = logn + 1
    table = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    nbrs = jnp.asarray(
        rng.integers(-1, n, size=(n, layers, m)).astype(np.int32))
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    u = jnp.asarray(rng.integers(-1, n, size=(B, W)).astype(np.int32))
    if full_range:
        L = jnp.zeros((B,), jnp.int32)
        R = jnp.full((B,), n - 1, jnp.int32)
    else:
        L = jnp.asarray(rng.integers(0, n // 2, size=(B,)).astype(np.int32))
        R = L + jnp.asarray(
            rng.integers(0, n // 2, size=(B,)).astype(np.int32))
    Lw, Rw = jnp.repeat(L, W), jnp.repeat(R, W)
    visited = bitset.make(B, n)
    pre = jnp.asarray(rng.integers(0, n, size=(B, 9)).astype(np.int32))
    visited, _ = bitset.test_and_set(visited, pre, jnp.ones((B, 9), bool))
    exp_ok = jnp.asarray(rng.integers(0, 2, size=(B, W)).astype(bool))
    return dict(args=(q, table, nbrs, u, Lw, Rw, visited, exp_ok),
                kw=dict(logn=logn, m_out=m_out))


def _assert_hop_equal(got, want, dist_tol=1e-5):
    """Integer outputs bit-identical; distances f32-close (inf-masked
    slots must agree exactly, so compare the mask first)."""
    nbr_g, nd_g, nv_g, vis_g = (np.asarray(x) for x in got)
    nbr_w, nd_w, nv_w, vis_w = (np.asarray(x) for x in want)
    np.testing.assert_array_equal(nbr_g, nbr_w)
    np.testing.assert_array_equal(nv_g, nv_w)
    np.testing.assert_array_equal(vis_g, vis_w)
    np.testing.assert_array_equal(np.isfinite(nd_g), np.isfinite(nd_w))
    fin = np.isfinite(nd_w)
    np.testing.assert_allclose(nd_g[fin], nd_w[fin],
                               rtol=dist_tol, atol=dist_tol)


# ---------------------------------------------------------------------------
# bit-identity vs the composed oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_hop_matches_composed(impl, metric):
    p = _mk()
    want = ops.hop(*p["args"], metric=metric, impl="composed",
                   edge_impl="xla", dist_impl="xla", **p["kw"])
    got = ops.hop(*p["args"], metric=metric, impl=impl, **p["kw"])
    _assert_hop_equal(got, want)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_hop_compact_storage(impl):
    """bf16 vectors + int16 neighbor ids: the neighbor codec decodes at
    the dispatch layer, the vector table stays compact into the kernel."""
    p = _mk(seed=3)
    q, table, nbrs, u, Lw, Rw, visited, exp_ok = p["args"]
    tb = table.astype(jnp.bfloat16)
    nb = jnp.asarray(storage_mod.encode_neighbors(
        np.asarray(nbrs), table.shape[0],
        storage_mod.StorageConfig(neighbor_dtype="int16")))
    assert nb.dtype == jnp.int16
    args_c = (q, tb, nb, u, Lw, Rw, visited, exp_ok)
    want = ops.hop(*args_c, impl="composed", edge_impl="xla",
                   dist_impl="xla", **p["kw"])
    got = ops.hop(*args_c, impl=impl, **p["kw"])
    # bf16 quantizes the table identically on both sides: ids stay exact
    _assert_hop_equal(got, want, dist_tol=1e-4)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_hop_degenerate_ranges(impl):
    """L == R (single-point range) and L > R (empty range) per query."""
    p = _mk(B=4, W=2, seed=5)
    q, table, nbrs, u, _, _, visited, exp_ok = p["args"]
    n = table.shape[0]
    L = jnp.asarray([10, n - 1, 50, 40], jnp.int32)
    R = jnp.asarray([10, n - 1, 20, 39], jnp.int32)   # rows 2,3: empty
    Lw, Rw = jnp.repeat(L, 2), jnp.repeat(R, 2)
    args = (q, table, nbrs, u, Lw, Rw, visited, exp_ok)
    want = ops.hop(*args, impl="composed", edge_impl="xla",
                   dist_impl="xla", **p["kw"])
    got = ops.hop(*args, impl=impl, **p["kw"])
    _assert_hop_equal(got, want)
    # empty ranges select nothing: every edge slot of rows 2,3 is -1
    nbr = np.asarray(got[0])
    assert (nbr[2:] == -1).all()


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("W", [1, 4])
def test_hop_expand_width(impl, W):
    p = _mk(B=3, W=W, seed=7)
    want = ops.hop(*p["args"], impl="composed", edge_impl="xla",
                   dist_impl="xla", **p["kw"])
    got = ops.hop(*p["args"], impl=impl, **p["kw"])
    _assert_hop_equal(got, want)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("n", [63, 65, 70])
def test_hop_bitset_saturation_at_n_boundary(impl, n):
    """n not a multiple of 32, frontier/edges clustered at n-1, visited
    nearly saturated: the packed test-and-set must not touch bits past n
    and must dedup ids already present in the (almost full) bitset."""
    p = _mk(n=n, B=4, W=2, m_out=6, seed=11, full_range=True)
    q, table, nbrs, u, Lw, Rw, visited, exp_ok = p["args"]
    # point the frontier at the top ids and pre-visit everything but the
    # last few, so most candidate edges hit already-set bits
    u = jnp.full_like(u, n - 1).at[:, 0].set(n - 2)
    all_ids = jnp.broadcast_to(jnp.arange(n - 3, dtype=jnp.int32),
                               (u.shape[0], n - 3))
    visited, _ = bitset.test_and_set(
        visited, all_ids, jnp.ones(all_ids.shape, bool))
    exp_ok = jnp.ones_like(exp_ok)
    args = (q, table, nbrs, u, Lw, Rw, visited, exp_ok)
    want = ops.hop(*args, impl="composed", edge_impl="xla",
                   dist_impl="xla", **p["kw"])
    got = ops.hop(*args, impl=impl, **p["kw"])
    _assert_hop_equal(got, want)
    # no bit at an index >= n may ever be set
    words = np.asarray(got[3])
    tail_bits = words[:, -1] >> (n % 32 if n % 32 else 32)
    if n % 32:
        assert (tail_bits == 0).all()


def test_hop_kernel_block_sizes():
    """Tile/pipeline knobs change scheduling, never results."""
    p = _mk(B=5, seed=13)
    base = hop_kernel_call(*p["args"], interpret=True, **p["kw"])
    for bb, w in ((1, 2), (2, 4), (8, 16)):
        got = hop_kernel_call(*p["args"], block_b=bb, window=w,
                              interpret=True, **p["kw"])
        _assert_hop_equal(got, base)


# ---------------------------------------------------------------------------
# dispatch guards
# ---------------------------------------------------------------------------

def test_hop_unknown_impl_rejected():
    p = _mk(B=2, W=1, seed=1)
    with pytest.raises(ValueError, match="hop: unknown impl"):
        ops.hop(*p["args"], impl="bogus", **p["kw"])


def test_hop_global_legacy_falls_back_to_composed(monkeypatch):
    """REPRO_IMPL=legacy (the prune-only token) must not error the hop:
    it falls back to the composed path, inner autos resolving
    backend-default so they don't see the foreign token either."""
    p = _mk(B=2, W=1, seed=2)
    want = ops.hop(*p["args"], impl="composed", **p["kw"])
    monkeypatch.delenv("REPRO_HOP_IMPL", raising=False)
    monkeypatch.setenv("REPRO_IMPL", "legacy")
    got = ops.hop(*p["args"], **p["kw"])
    _assert_hop_equal(got, want)
    # explicit impl="legacy" maps the same way
    got = ops.hop(*p["args"], impl="legacy", **p["kw"])
    _assert_hop_equal(got, want)


def test_hop_env_override_precedence(monkeypatch):
    """REPRO_HOP_IMPL beats REPRO_IMPL, and bogus env tokens still raise."""
    p = _mk(B=2, W=1, seed=4)
    want = ops.hop(*p["args"], impl="composed", **p["kw"])
    monkeypatch.setenv("REPRO_IMPL", "xla")
    monkeypatch.setenv("REPRO_HOP_IMPL", "pallas")
    got = ops.hop(*p["args"], **p["kw"])
    _assert_hop_equal(got, want)
    monkeypatch.setenv("REPRO_HOP_IMPL", "bogus")
    with pytest.raises(ValueError, match="hop: unknown impl"):
        ops.hop(*p["args"], **p["kw"])


def test_hop_global_impl_keeps_hop_composed(monkeypatch):
    """REPRO_IMPL targets the per-op kernels: with it set (and no
    REPRO_HOP_IMPL) the hop's auto must stay composed, so the inner ops
    see the forced backend — e.g. the REPRO_IMPL=pallas CI leg runs the
    per-op interpreted kernels, never an interpreted whole-hop inside
    every serving test."""
    p = _mk(B=2, W=1, seed=5)
    want = ops.hop(*p["args"], impl="composed", **p["kw"])
    monkeypatch.delenv("REPRO_HOP_IMPL", raising=False)
    for glob in ("xla", "pallas"):
        monkeypatch.setenv("REPRO_IMPL", glob)
        got = ops.hop(*p["args"], **p["kw"])
        _assert_hop_equal(got, want)


def test_hop_per_op_pin_beats_forced_pallas(monkeypatch):
    """An explicit edge_impl/dist_impl pin must survive REPRO_HOP_IMPL:
    the megakernel has no per-op backends, so a pinned call routes
    through the composed path and reproduces it bit-for-bit — distances
    included (the beam-search per-backend bit-exactness tests rely on
    dist_impl="xla" holding under every env)."""
    p = _mk(B=2, W=1, seed=6)
    want = ops.hop(*p["args"], impl="composed", edge_impl="xla",
                   dist_impl="xla", **p["kw"])
    monkeypatch.setenv("REPRO_HOP_IMPL", "pallas")
    got = ops.hop(*p["args"], edge_impl="xla", dist_impl="xla", **p["kw"])
    _assert_hop_equal(got, want)
    gd, wd = np.asarray(got[1]), np.asarray(want[1])
    assert ((gd == wd) | (np.isinf(gd) & np.isinf(wd))).all()


def test_search_config_hop_impl_validated():
    assert SearchConfig(hop_impl="pallas").hop_impl == "pallas"
    with pytest.raises(ValueError, match="hop_impl"):
        SearchConfig(hop_impl="bogus")


def test_beam_search_hop_fn_excludes_result_filter():
    with pytest.raises(ValueError, match="hop_fn is incompatible"):
        beam_search(
            jnp.zeros((8, 4)), jnp.zeros((2, 4)),
            jnp.zeros((2, 2), jnp.int32), None, k=1,
            hop_fn=lambda u, e, v: None,
            result_filter_fn=lambda ids: ids >= 0,
        )


# ---------------------------------------------------------------------------
# end-to-end: the jitted improvised search is backend-invariant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hop_impl", ["xla", "pallas"])
def test_search_improvised_hop_impl_equivalent(hop_impl):
    """The whole jitted search returns identical ids/dists whether the hop
    runs composed, as the jnp fusion, or as the Pallas megakernel."""
    from repro.core import BuildConfig, RangeGraphIndex

    rng = np.random.default_rng(21)
    n, d = 128, 8
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 100, n)
    idx = RangeGraphIndex.build(
        vectors, attrs, BuildConfig(m=4, ef_construction=16,
                                    brute_threshold=8))
    B = 3
    q = rng.standard_normal((B, d)).astype(np.float32)
    L = np.asarray([0, 20, 60], np.int32)
    R = np.asarray([n - 1, 90, 61], np.int32)
    base_cfg = SearchConfig(ef=16, expand_width=2, dist_impl="xla",
                            edge_impl="xla", hop_impl="composed")
    want = idx.search_ranks(q, L, R, k=5, config=base_cfg)
    got = idx.search_ranks(q, L, R, k=5,
                           config=base_cfg.replace(hop_impl=hop_impl))
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    np.testing.assert_allclose(
        np.asarray(got.dists), np.asarray(want.dists), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(got.n_dists), np.asarray(want.n_dists))


# ---------------------------------------------------------------------------
# edge-select lazy dedup (the standalone kernel's new default)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K_big", [False, True])
def test_edge_select_lazy_matches_eager(K_big):
    """Lazy O(m_out*K) dedup == eager [K,K] matrix, including K > 384
    where lazy keeps the full bf=8 row tile (the lifted VMEM cap)."""
    rng = np.random.default_rng(17)
    if K_big:
        n, m = 2000, 36         # logn=11, layers=12 -> K=432 > 384
    else:
        n, m = 500, 4
    logn = max(int(np.ceil(np.log2(max(n, 2)))), 1)
    layers = logn + 1
    K = layers * m
    if K_big:
        assert K > 384
    nbrs = jnp.asarray(
        rng.integers(-1, n, size=(n, layers, m)).astype(np.int32))
    F = 10
    us = jnp.asarray(rng.integers(-1, n, size=(F,)).astype(np.int32))
    L = jnp.asarray(rng.integers(0, n // 2, size=(F,)).astype(np.int32))
    R = L + 200
    kw = dict(logn=logn, m_out=8, interpret=True)
    lazy = edge_select_kernel_call(nbrs, us, L, R, dedup="lazy", **kw)
    eager = edge_select_kernel_call(nbrs, us, L, R, dedup="eager", **kw)
    ref = ops.select_edges(nbrs, us, L, R, logn=logn, m_out=8, impl="xla")
    np.testing.assert_array_equal(np.asarray(lazy), np.asarray(eager))
    np.testing.assert_array_equal(np.asarray(lazy), np.asarray(ref))


def test_edge_select_unknown_dedup_rejected():
    rng = np.random.default_rng(0)
    nbrs = jnp.asarray(rng.integers(-1, 16, (16, 5, 4)).astype(np.int32))
    us = jnp.asarray([0, 1], jnp.int32)
    with pytest.raises(ValueError, match="unknown dedup"):
        edge_select_kernel_call(
            nbrs, us, jnp.zeros(2, jnp.int32), jnp.full(2, 15, jnp.int32),
            logn=4, m_out=4, dedup="nope", interpret=True)
