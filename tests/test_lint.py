"""replint (src/repro/lint) — DESIGN.md §10.

Per rule: one *violating* fixture asserting the rule demonstrably fires
(with the expected stable finding key) and one *clean* fixture asserting
it stays quiet. Plus the framework pieces (inline suppression, baseline
reasons, stable keys) and the self-run: the repo itself must be clean
under ``python -m repro.lint --strict``.

Fixtures are tmp trees handed to :class:`repro.lint.Context` via its
path overrides — no repo copying, and each rule runs against exactly the
files it claims to check.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.lint import (
    Context, Finding, load_baseline, run, save_baseline, suppressed,
)
from repro.lint.rules import (
    ALL_RULES, r1_knob_registry, r2_dispatch_contract, r3_jit_discipline,
    r4_vmem_budget, r5_sentinel_discipline, r6_reachability,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

KNOBS_STUB = textwrap.dedent(
    '''
    class _K:
        def __init__(self, name):
            self.name = name
    REGISTRY = (_K("REPRO_GOOD"), _K("REPRO_MY_IMPL"))
    def generate_markdown():
        return "# knobs\\n"
    '''
)


def write(root, rel, content):
    path = os.path.join(root, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        f.write(textwrap.dedent(content))
    return path


def slugs(findings):
    return {f.slug for f in findings}


# ---------------------------------------------------------------------------
# R1 knob-registry
# ---------------------------------------------------------------------------

def _r1_ctx(tmp_path, module_src, knobs_md="# knobs\n"):
    root = str(tmp_path)
    write(root, "pyproject.toml", "")
    knobs = write(root, "pkg/knobs.py", KNOBS_STUB)
    write(root, "pkg/mod.py", module_src)
    md = write(root, "KNOBS.md", knobs_md)
    return Context(
        root=root, src_dir=os.path.join(root, "pkg"), extra_dirs=(),
        tests_dir=os.path.join(root, "tests"), knobs_path=knobs,
        knobs_md_path=md, sentinel_paths=(),
    )


def test_r1_fires_on_raw_env_and_unregistered_knob(tmp_path):
    ctx = _r1_ctx(
        tmp_path,
        """
        import os
        TOKEN = os.environ.get("REPRO_MYSTERY")
        OTHER = os.environ["REPRO_GOOD"]
        """,
    )
    got = slugs(r1_knob_registry.check(ctx))
    assert "raw-env:REPRO_MYSTERY" in got
    assert "raw-env:REPRO_GOOD" in got  # registered but read raw: still R1
    assert "unregistered:REPRO_MYSTERY" in got
    assert "knobs-md-drift" not in got


def test_r1_fires_on_knobs_md_drift(tmp_path):
    ctx = _r1_ctx(tmp_path, "X = 1\n", knobs_md="# stale, hand-edited\n")
    assert "knobs-md-drift" in slugs(r1_knob_registry.check(ctx))


def test_r1_clean(tmp_path):
    ctx = _r1_ctx(
        tmp_path,
        """
        from pkg import knobs
        LEVEL = knobs.get_int("REPRO_GOOD")
        """,
    )
    assert not list(r1_knob_registry.check(ctx))


# ---------------------------------------------------------------------------
# R2 dispatch-contract
# ---------------------------------------------------------------------------

def _r2_ctx(tmp_path, ops_src, ref_src="", test_src=None):
    root = str(tmp_path)
    write(root, "pyproject.toml", "")
    ops = write(root, "pkg/ops.py", ops_src)
    ref = write(root, "pkg/ref.py", ref_src)
    knobs = write(root, "pkg/knobs.py", KNOBS_STUB)
    if test_src is not None:
        write(root, "tests/test_myop.py", test_src)
    return Context(
        root=root, src_dir=os.path.join(root, "pkg"), extra_dirs=(),
        tests_dir=os.path.join(root, "tests"), ops_path=ops, ref_path=ref,
        knobs_path=knobs, sentinel_paths=(),
    )


def test_r2_fires_on_missing_contract(tmp_path):
    ctx = _r2_ctx(
        tmp_path,
        # exported op with no ref contract, pallas-only tokens, an
        # unregistered knob, and no test naming it
        """
        __all__ = ["myop"]
        def _check_impl(op, impl, allowed):
            if impl not in allowed:
                raise ValueError(impl)
        def myop(x, impl="auto"):
            if impl == "auto":
                impl = "pallas" if x else "REPRO_SECRET_IMPL"
            _check_impl("myop", impl, {"pallas"})
            return x
        """,
    )
    got = slugs(r2_dispatch_contract.check(ctx))
    assert "myop:no-oracle" in got
    assert "myop:no-ref-contract" in got
    assert "myop:unregistered-knob:REPRO_SECRET_IMPL" in got
    assert "myop:no-test" in got


def test_r2_fires_on_missing_check_impl(tmp_path):
    ctx = _r2_ctx(
        tmp_path,
        """
        __all__ = ["myop"]
        def myop(x, impl="auto"):
            return x
        """,
    )
    assert "myop:no-check-impl" in slugs(r2_dispatch_contract.check(ctx))


def test_r2_clean(tmp_path):
    ctx = _r2_ctx(
        tmp_path,
        """
        __all__ = ["myop", "default_impl"]
        from pkg import ref as _ref
        def _check_impl(op, impl, allowed):
            if impl not in allowed:
                raise ValueError(impl)
        def default_impl(kind=None):
            return "xla"
        def myop(x, impl="auto"):
            if impl == "auto":
                impl = default_impl("my")
            _check_impl("myop", impl, {"pallas", "xla"})
            return _ref.myop(x)
        """,
        ref_src="def myop(x):\n    return x\n",
        test_src="def test_myop():\n    assert 'myop'\n",
    )
    assert not list(r2_dispatch_contract.check(ctx))


# ---------------------------------------------------------------------------
# R3 jit-discipline
# ---------------------------------------------------------------------------

def _r3_ctx(tmp_path, src):
    root = str(tmp_path)
    write(root, "pyproject.toml", "")
    write(root, "pkg/core.py", src)
    return Context(
        root=root, src_dir=os.path.join(root, "pkg"), extra_dirs=(),
        sentinel_paths=(),
    )


def test_r3_fires_on_tracer_coercion_and_mutable_static(tmp_path):
    ctx = _r3_ctx(
        tmp_path,
        """
        import functools
        import jax

        @jax.jit
        def _bad_jit(x):
            return float(x) + x.sum().item()

        @functools.partial(jax.jit, static_argnames=("cfg", "ghost"))
        def _bad2_jit(x, cfg=[1]):
            import numpy as np
            return np.asarray(x)
        """,
    )
    got = slugs(r3_jit_discipline.check(ctx))
    assert any(s.startswith("_bad_jit:coerce-float") for s in got)
    assert any(s.startswith("_bad_jit:item") for s in got)
    assert "_bad2_jit:static-mutable:cfg" in got
    assert "_bad2_jit:static-unknown:ghost" in got
    assert any(s.startswith("_bad2_jit:np-asarray") for s in got)


def test_r3_clean_shapes_and_statics(tmp_path):
    ctx = _r3_ctx(
        tmp_path,
        """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("m",))
        def _ok_jit(x, m=4):
            k = int(x.shape[0])          # shape-routed: fine
            scale = float(m)             # static arg: fine
            return x * scale + k

        _also_ok = functools.partial(jax.jit, static_argnames=("m",))(_ok_jit)
        """,
    )
    assert not list(r3_jit_discipline.check(ctx))


# ---------------------------------------------------------------------------
# R4 vmem-budget
# ---------------------------------------------------------------------------

def test_r4_fires_when_budget_shrinks(monkeypatch):
    # the real kernels, the real CANDIDATES grid, a 256 KiB budget: the
    # evaluator must produce totals big enough to trip it — proof the
    # rule genuinely evaluates shapes rather than skipping them
    monkeypatch.setattr(r4_vmem_budget, "BUDGET_BYTES", 256 << 10)
    got = list(r4_vmem_budget.check(Context(root=REPO)))
    assert got, "shrunken budget must trip candidates"
    assert any("hop_kernel_call" in f.slug for f in got)
    assert all("uneval" not in f.slug for f in got)
    # finding keys name the exact candidate so the baseline stays stable
    assert any("block_b=" in f.slug for f in got)


def test_r4_covers_every_candidate_grid():
    from repro.lint import astutil

    ctx = Context(root=REPO)
    grid = astutil.eval_module_constant(
        ctx.tree(ctx.autotune_path), "CANDIDATES", ctx.autotune_path
    )
    mapped = {
        k for _, _, kinds, _, _ in r4_vmem_budget.KERNELS for k in kinds
    }
    assert set(grid) <= mapped


def test_r4_fires_on_unmapped_kind_and_uncovered_kernel(tmp_path,
                                                       monkeypatch):
    root = str(tmp_path)
    write(root, "pyproject.toml", "")
    autotune = write(
        root, "pkg/kernels/autotune.py",
        'CANDIDATES = {"toy": [{"block": 8}]}\n',
    )
    write(
        root, "pkg/kernels/rogue.py",
        """
        import jax.experimental.pallas as pl
        def rogue_kernel_call(x):
            return pl.pallas_call(None)(x)
        """,
    )
    ctx = Context(
        root=root, src_dir=os.path.join(root, "pkg"), extra_dirs=(),
        autotune_path=autotune,
        kernels_dir=os.path.join(root, "pkg/kernels"), sentinel_paths=(),
    )
    got = slugs(r4_vmem_budget.check(ctx))
    assert "unmapped-kind:toy" in got
    assert "uncovered:rogue.py" in got
    # and the rule's own kernel table is missing from this tree
    assert any(s.startswith("missing-module:") for s in got)


def test_r4_clean_on_repo():
    got = [
        f for f in r4_vmem_budget.check(Context(root=REPO))
    ]
    assert not got, [f.render() for f in got]


# ---------------------------------------------------------------------------
# R5 sentinel-discipline
# ---------------------------------------------------------------------------

def _r5_ctx(tmp_path, src):
    root = str(tmp_path)
    write(root, "pyproject.toml", "")
    path = write(root, "pkg/store.py", src)
    return Context(
        root=root, src_dir=os.path.join(root, "pkg"), extra_dirs=(),
        sentinel_paths=(path,),
    )


def test_r5_fires_on_dtype_max_and_magic(tmp_path):
    ctx = _r5_ctx(
        tmp_path,
        """
        import numpy as np

        def invalid(ids):
            lim = ids == np.iinfo(np.int16).max
            magic = ids == 32767
            filled = np.where(ids < 0, 2147483647, ids)
            return lim | magic, filled
        """,
    )
    got = slugs(r5_sentinel_discipline.check(ctx))
    assert "iinfo-max" in got
    assert "magic:32767" in got
    assert "magic-fill:2147483647" in got


def test_r5_clean_minus_one_and_iinfo_min(tmp_path):
    ctx = _r5_ctx(
        tmp_path,
        """
        import numpy as np

        def invalid(ids):
            # -1 is THE sentinel; iinfo(...).min priority masking is fine
            mask = ids == -1
            prio = np.where(mask, np.iinfo(np.int32).min, ids)
            return mask, prio
        """,
    )
    assert not list(r5_sentinel_discipline.check(ctx))


def test_r5_inline_allow_suppresses(tmp_path):
    ctx = _r5_ctx(
        tmp_path,
        """
        import numpy as np

        def fits(n):
            return n <= np.iinfo(np.int16).max  # replint: allow[R5] capacity
        """,
    )
    found = list(r5_sentinel_discipline.check(ctx))
    assert found and all(suppressed(ctx, f) for f in found)


# ---------------------------------------------------------------------------
# R6 import-reachability
# ---------------------------------------------------------------------------

def _r6_ctx(tmp_path, files, entry_points=("pkg",)):
    root = str(tmp_path)
    write(root, "pyproject.toml", "")
    for rel, content in files.items():
        write(root, rel, content)
    return Context(
        root=root, src_dir=os.path.join(root, "pkg"), extra_dirs=(),
        entry_points=entry_points, sentinel_paths=(),
    )


def test_r6_fires_on_dead_module(tmp_path):
    ctx = _r6_ctx(tmp_path, {
        "pkg/__init__.py": "from pkg import used\n",
        "pkg/used.py": "X = 1\n",
        "pkg/dead.py": "Y = 2\n",
    })
    assert slugs(r6_reachability.check(ctx)) == {"pkg.dead"}


def test_r6_clean_when_wired(tmp_path):
    ctx = _r6_ctx(tmp_path, {
        "pkg/__init__.py": "from pkg import used\n",
        "pkg/used.py": "from . import dead\nX = 1\n",  # relative import
        "pkg/dead.py": "Y = 2\n",
    })
    assert not list(r6_reachability.check(ctx))


def test_r6_fires_on_missing_entry_point(tmp_path):
    ctx = _r6_ctx(
        tmp_path, {"pkg/__init__.py": "X = 1\n"},
        entry_points=("pkg", "pkg.ghost"),
    )
    assert "missing-entry:pkg.ghost" in slugs(r6_reachability.check(ctx))


# ---------------------------------------------------------------------------
# framework: keys, baseline, suppression
# ---------------------------------------------------------------------------

def test_finding_keys_are_line_independent():
    a = Finding("R6", "src/x.py", 10, "msg", "pkg.dead")
    b = Finding("R6", "src/x.py", 99, "other msg", "pkg.dead")
    assert a.key == b.key == "R6:src/x.py:pkg.dead"


def test_baseline_requires_reasons(tmp_path):
    path = os.path.join(str(tmp_path), "b.json")
    with open(path, "w") as f:
        json.dump({"entries": [{"key": "R6:x:y", "reason": ""}]}, f)
    with pytest.raises(ValueError, match="no reason"):
        load_baseline(path)
    save_baseline(path, {"R6:x:y": "because"})
    assert load_baseline(path) == {"R6:x:y": "because"}


def test_every_rule_declares_metadata():
    ids = [m.RULE_ID for m in ALL_RULES]
    assert ids == ["R1", "R2", "R3", "R4", "R5", "R6"]
    for mod in ALL_RULES:
        assert mod.TITLE and mod.SUMMARY and callable(mod.check)


# ---------------------------------------------------------------------------
# the self-run: this repo is clean under --strict
# ---------------------------------------------------------------------------

def test_repo_is_clean_in_process():
    ctx = Context(root=REPO)
    baseline = load_baseline(os.path.join(REPO, "lint_baseline.json"))
    findings = run(ctx)
    new = [f for f in findings if f.key not in baseline]
    stale = set(baseline) - {f.key for f in findings}
    assert not new, "new findings:\n" + "\n".join(f.render() for f in new)
    assert not stale, f"stale baseline entries: {sorted(stale)}"


def test_repo_is_clean_strict_cli():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--strict"],
        cwd=REPO,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True, text=True, timeout=120,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 new" in proc.stdout
