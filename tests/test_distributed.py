"""Multi-device tests (subprocess: XLA_FLAGS forces 8 host devices so the
main test process keeps seeing 1 device, per the assignment)."""
import json
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.core import BuildConfig, RangeGraphIndex, recall
from repro.core import distributed as dist
from repro.data.pipeline import vector_dataset

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)

n, d = 1024, 16
vectors, attrs, qv = vector_dataset(n, d, seed=11, queries=32)
cfg = BuildConfig(m=8, ef_construction=32)
sharded = dist.build_sharded(vectors, attrs[:, 0], 4, cfg)

B = 32
rng = np.random.default_rng(0)
L = rng.integers(0, n // 2, B).astype(np.int32)
R = (L + rng.integers(64, n // 2, B)).clip(max=n - 1).astype(np.int32)

ids, dists = dist.rfann_serve_step(
    jnp.asarray(sharded.vectors), jnp.asarray(sharded.neighbors),
    jnp.asarray(sharded.bounds), jnp.asarray(qv), jnp.asarray(L),
    jnp.asarray(R), mesh=mesh, logn=sharded.logn, m=sharded.m, ef=64, k=10,
)
ids = np.asarray(ids)

# ground truth on the globally sorted order
order = np.argsort(attrs[:, 0], kind="stable")
flat = RangeGraphIndex.build(vectors, attrs[:, 0], cfg)
gt, _ = flat.brute_force(qv, L, R, k=10)

in_range = True
for i in range(B):
    got = ids[i][ids[i] >= 0]
    in_range &= bool(((got >= L[i]) & (got <= R[i])).all())
rec = recall(ids, gt)
print(json.dumps({"recall": rec, "in_range": in_range}))
"""


def _run(script, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=_ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_rfann_matches_ground_truth():
    res = _run(_DIST_SCRIPT)
    assert res["in_range"]
    assert res["recall"] >= 0.9, res


_DRYRUN_SCRIPT = r"""
import subprocess, sys, json, os
out = subprocess.run(
    [sys.executable, "-m", "repro.launch.dryrun",
     "--arch", "granite-moe-1b-a400m", "--shape", "decode_32k",
     "--both-meshes"],
    capture_output=True, text=True,
    env={**os.environ, "PYTHONPATH": "src"},
)
print(out.stdout)
sys.exit(out.returncode)
"""


@pytest.mark.slow
def test_dryrun_cell_compiles_both_meshes():
    """One full dry-run cell on 512 placeholder devices, both meshes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "granite-moe-1b-a400m", "--shape", "decode_32k",
         "--both-meshes"],
        capture_output=True, text=True, timeout=1200, env=env, cwd=_ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    recs = [json.loads(l) for l in out.stdout.strip().splitlines()
            if l.startswith("{")]
    assert len(recs) == 2
    assert all(r["status"] == "ok" for r in recs), recs
    meshes = {r["mesh"] for r in recs}
    assert meshes == {"16x16", "2x16x16"}
    single = next(r for r in recs if r["mesh"] == "16x16")
    assert single["hlo_gflops"] > 0
    assert single["collectives"]["total"] > 0
    assert single["bottleneck"] in ("compute", "memory", "collective")
