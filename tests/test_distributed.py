"""Multi-device tests (subprocess: XLA_FLAGS forces 8 host devices so the
main test process keeps seeing 1 device, per the assignment), plus
host-side sharding tests that run the same per-shard code path
(``distributed.shard_topk`` / ``merge_topk``) without a mesh."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# host-side: ragged sharding + compact storage through the serve-step body
# ---------------------------------------------------------------------------

def _host_serve(sharded, qv, L, R, *, ef, k, dist_impl="auto",
                edge_impl="auto"):
    """rfann_serve_step minus the mesh: per-shard ``shard_topk`` + the same
    ``merge_topk`` the all-gather path uses."""
    import jax.numpy as jnp
    from repro.core import distributed as dist

    ids_s, d_s = [], []
    for s in range(sharded.n_shards):
        i, d = dist.shard_topk(
            jnp.asarray(sharded.vectors[s]),
            jnp.asarray(sharded.neighbors[s]),
            jnp.asarray(sharded.bounds[s]),
            jnp.asarray(qv), jnp.asarray(L), jnp.asarray(R),
            logn=sharded.logn, m=sharded.m, ef=ef, k=k,
            dist_impl=dist_impl, edge_impl=edge_impl,
        )
        ids_s.append(i)
        d_s.append(d)
    out_i, out_d = dist.merge_topk(jnp.stack(ids_s), jnp.stack(d_s), k)
    return np.asarray(out_i), np.asarray(out_d)


@pytest.fixture(scope="module")
def ragged_setup():
    from repro.core import BuildConfig, StorageConfig
    from repro.core import distributed as dist
    from repro.data.pipeline import vector_dataset

    n, d, S, B = 1000, 16, 3, 24
    vectors, attrs, qv = vector_dataset(n, d, seed=3, queries=B)
    cfg = BuildConfig(m=8, ef_construction=32)
    # pin f32 storage: the exact-equality assertions below must not move
    # with the REPRO_STORAGE knob
    f32 = StorageConfig()
    sharded = dist.build_sharded(vectors, attrs[:, 0], S, cfg, storage=f32)
    single = dist.build_sharded(vectors, attrs[:, 0], 1, cfg, storage=f32)
    rng = np.random.default_rng(0)
    L = rng.integers(0, n // 2, B).astype(np.int32)
    R = (L + rng.integers(64, n // 2, B)).clip(max=n - 1).astype(np.int32)
    return sharded, single, qv, L, R, vectors, attrs


def test_build_sharded_ragged_shapes_and_bounds(ragged_setup):
    """n=1000 over S=3: ceil-sized shards, padded tail, real bounds."""
    sharded, _, _, _, _, vectors, attrs = ragged_setup
    assert sharded.vectors.shape[:2] == (3, 334)
    assert sharded.neighbors.shape[1] == 334
    np.testing.assert_array_equal(
        sharded.bounds, [[0, 333], [334, 667], [668, 999]]
    )
    # the padded tail repeats the shard's last real row
    order = np.argsort(attrs[:, 0], kind="stable")
    vs = np.asarray(vectors, np.float32)[order]
    np.testing.assert_array_equal(sharded.vectors[2, 331], vs[999])
    np.testing.assert_array_equal(sharded.vectors[2, 332], vs[999])


def test_build_sharded_rejects_bad_shard_counts():
    from repro.core import distributed as dist

    vectors = np.zeros((8, 4), np.float32)
    attrs = np.arange(8.0)
    with pytest.raises(ValueError, match="n_shards"):
        dist.build_sharded(vectors, attrs, 0)
    with pytest.raises(ValueError, match="n_shards"):
        dist.build_sharded(vectors, attrs, 9)


def test_ragged_shards_parity_with_single_shard(ragged_setup):
    """n=1000, S=3 (ragged): padded rows never surface and merged quality
    matches the single-shard result."""
    from repro.core import RangeGraphIndex, BuildConfig, recall

    sharded, single, qv, L, R, vectors, attrs = ragged_setup
    ids3, _ = _host_serve(sharded, qv, L, R, ef=64, k=10)
    ids1, _ = _host_serve(single, qv, L, R, ef=64, k=10)
    # every id is a real in-range rank — the padded tail (local ranks
    # 332..333 of shard 2 -> globals 1000..1001) must never appear
    for i in range(ids3.shape[0]):
        got = ids3[i][ids3[i] >= 0]
        assert ((got >= L[i]) & (got <= R[i])).all()
    assert ids3.max() <= 999
    flat = RangeGraphIndex.build(vectors, attrs[:, 0],
                                 BuildConfig(m=8, ef_construction=32))
    gt, _ = flat.brute_force(qv, L, R, k=10)
    rec3 = recall(ids3, gt)
    rec1 = recall(ids1, gt)
    assert rec3 >= 0.9, (rec3, rec1)
    assert rec3 >= rec1 - 0.05, (rec3, rec1)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_compact_serve_step_ids_bit_identical(impl):
    """The compact decode branch: int16 neighbors + bf16 vectors through the
    sharded serve-step body must return ids bit-identical to the f32 path
    fed the SAME (pre-decoded) data, on every backend — the decode is a
    widening cast and all math is f32 either way."""
    from repro.core import BuildConfig
    from repro.core import distributed as dist
    from repro.core import storage as storage_mod
    from repro.data.pipeline import vector_dataset

    n, d, S, B = 600, 12, 3, 8
    vectors, attrs, qv = vector_dataset(n, d, seed=17, queries=B)
    cfg = BuildConfig(m=8, ef_construction=24)
    compact = dist.build_sharded(vectors, attrs[:, 0], S, cfg,
                                 storage=storage_mod.StorageConfig.compact())
    assert compact.vectors.dtype == np.dtype("bfloat16")
    assert compact.neighbors.dtype == np.int16
    # the f32 reference serves the decoded arrays: same values, wide dtypes
    decoded = dist.ShardedRangeIndex(
        np.asarray(compact.vectors, np.float32),
        storage_mod.decode_neighbors(compact.neighbors),
        compact.bounds, compact.logn, compact.m,
    )
    rng = np.random.default_rng(1)
    L = rng.integers(0, n // 2, B).astype(np.int32)
    R = (L + rng.integers(32, n // 2, B)).clip(max=n - 1).astype(np.int32)
    kw = dict(ef=24, k=5, dist_impl=impl, edge_impl=impl)
    ids_c, d_c = _host_serve(compact, qv, L, R, **kw)
    ids_f, d_f = _host_serve(decoded, qv, L, R, **kw)
    np.testing.assert_array_equal(ids_c, ids_f)
    np.testing.assert_array_equal(d_c, d_f)

_DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.core import BuildConfig, RangeGraphIndex, recall
from repro.core import distributed as dist
from repro.data.pipeline import vector_dataset

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)

n, d = 1024, 16
vectors, attrs, qv = vector_dataset(n, d, seed=11, queries=32)
cfg = BuildConfig(m=8, ef_construction=32)
sharded = dist.build_sharded(vectors, attrs[:, 0], 4, cfg)

B = 32
rng = np.random.default_rng(0)
L = rng.integers(0, n // 2, B).astype(np.int32)
R = (L + rng.integers(64, n // 2, B)).clip(max=n - 1).astype(np.int32)

ids, dists = dist.rfann_serve_step(
    jnp.asarray(sharded.vectors), jnp.asarray(sharded.neighbors),
    jnp.asarray(sharded.bounds), jnp.asarray(qv), jnp.asarray(L),
    jnp.asarray(R), mesh=mesh, logn=sharded.logn, m=sharded.m, ef=64, k=10,
)
ids = np.asarray(ids)

# ground truth on the globally sorted order
order = np.argsort(attrs[:, 0], kind="stable")
flat = RangeGraphIndex.build(vectors, attrs[:, 0], cfg)
gt, _ = flat.brute_force(qv, L, R, k=10)

in_range = True
for i in range(B):
    got = ids[i][ids[i] >= 0]
    in_range &= bool(((got >= L[i]) & (got <= R[i])).all())
rec = recall(ids, gt)
print(json.dumps({"recall": rec, "in_range": in_range}))
"""


def _run(script, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=_ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_rfann_matches_ground_truth():
    res = _run(_DIST_SCRIPT)
    assert res["in_range"]
    assert res["recall"] >= 0.9, res


def _jax_has_shard_map():
    import jax

    return hasattr(jax, "shard_map")


_RAGGED_COMPACT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.core import BuildConfig
from repro.core import distributed as dist
from repro.core import storage as storage_mod
from repro.data.pipeline import vector_dataset

mesh = jax.make_mesh((3, 2), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
n, d, S, B = 1000, 16, 3, 32  # ragged: 334 + 334 + 332
vectors, attrs, qv = vector_dataset(n, d, seed=11, queries=B)
cfg = BuildConfig(m=8, ef_construction=32)
compact = dist.build_sharded(
    vectors, attrs[:, 0], S, cfg,
    storage=storage_mod.StorageConfig.compact(),
)
assert str(compact.vectors.dtype) == "bfloat16"
assert compact.neighbors.dtype == np.int16
decoded = dist.ShardedRangeIndex(
    np.asarray(compact.vectors, np.float32),
    storage_mod.decode_neighbors(compact.neighbors),
    compact.bounds, compact.logn, compact.m,
)
rng = np.random.default_rng(0)
L = rng.integers(0, n // 2, B).astype(np.int32)
R = (L + rng.integers(64, n // 2, B)).clip(max=n - 1).astype(np.int32)
out = {}
for tag, sh in (("compact", compact), ("f32", decoded)):
    ids, dists = dist.rfann_serve_step(
        jnp.asarray(sh.vectors), jnp.asarray(sh.neighbors),
        jnp.asarray(sh.bounds), jnp.asarray(qv), jnp.asarray(L),
        jnp.asarray(R), mesh=mesh, logn=sh.logn, m=sh.m, ef=64, k=10,
    )
    out[tag] = np.asarray(ids)
in_range = True
for i in range(B):
    got = out["compact"][i][out["compact"][i] >= 0]
    in_range &= bool(((got >= L[i]) & (got <= R[i])).all())
print(json.dumps({
    "identical": bool(np.array_equal(out["compact"], out["f32"])),
    "in_range": in_range,
    "max_id": int(out["compact"].max()),
}))
"""


@pytest.mark.skipif(not _jax_has_shard_map(),
                    reason="needs jax.shard_map (jax >= 0.5)")
def test_sharded_serve_step_compact_ragged_bit_identical():
    """Satellite of the compact-storage PR: int16 neighbors + bf16 vectors
    through the REAL shard_map serve step over ragged shards, ids
    bit-identical to the f32 path fed the same pre-decoded data. The
    mesh-free equivalent (``test_compact_serve_step_ids_bit_identical``)
    covers jax builds without shard_map."""
    res = _run(_RAGGED_COMPACT_SCRIPT)
    assert res["identical"], res
    assert res["in_range"], res
    assert res["max_id"] <= 999, res


_DRYRUN_SCRIPT = r"""
import subprocess, sys, json, os
out = subprocess.run(
    [sys.executable, "-m", "repro.launch.dryrun",
     "--arch", "granite-moe-1b-a400m", "--shape", "decode_32k",
     "--both-meshes"],
    capture_output=True, text=True,
    env={**os.environ, "PYTHONPATH": "src"},
)
print(out.stdout)
sys.exit(out.returncode)
"""


@pytest.mark.slow
def test_dryrun_cell_compiles_both_meshes():
    """One full dry-run cell on 512 placeholder devices, both meshes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "granite-moe-1b-a400m", "--shape", "decode_32k",
         "--both-meshes"],
        capture_output=True, text=True, timeout=1200, env=env, cwd=_ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    recs = [json.loads(l) for l in out.stdout.strip().splitlines()
            if l.startswith("{")]
    assert len(recs) == 2
    assert all(r["status"] == "ok" for r in recs), recs
    meshes = {r["mesh"] for r in recs}
    assert meshes == {"16x16", "2x16x16"}
    single = next(r for r in recs if r["mesh"] == "16x16")
    assert single["hlo_gflops"] > 0
    assert single["collectives"]["total"] > 0
    assert single["bottleneck"] in ("compute", "memory", "collective")
