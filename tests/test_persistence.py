"""Checksummed index persistence: corruption is caught and NAMED.

``RangeGraphIndex.save`` writes a crc32 per array inside the
sha256-checksummed msgpack envelope; ``load`` verifies both layers and
raises :class:`IndexCorruptionError` carrying the offending field — a
truncated or bit-flipped file must fail loudly at load time, never
surface as a garbage search result or a reshape error three layers down.
Pre-checksum files (no per-array crc32) still load, with a warning.

The corruption helpers rewrite a real saved file through the same
msgpack+compression envelope the index uses, recomputing the envelope
sha, so each test hits exactly the integrity layer it targets.
"""
import hashlib

import msgpack
import numpy as np
import pytest

from repro import compressio
from repro.core import (
    BuildConfig, IndexCorruptionError, RangeGraphIndex, StorageConfig,
)


@pytest.fixture(scope="module")
def saved(tmp_path_factory):
    rng = np.random.default_rng(5)
    n, d = 128, 8
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 10, n)
    # pin f32 storage: these tests target the integrity envelope and its
    # canonical field set regardless of the CI REPRO_STORAGE leg; the
    # codec sidecar fields have their own corruption tests
    # (tests/test_codecs.py)
    idx = RangeGraphIndex.build(
        vectors, attrs, BuildConfig(m=4, ef_construction=16,
                                    brute_threshold=16),
        storage=StorageConfig(),
    )
    path = tmp_path_factory.mktemp("persist") / "index.bin"
    idx.save(str(path))
    return idx, str(path)


def _read_payload(path):
    with open(path, "rb") as f:
        outer = msgpack.unpackb(compressio.decompress(f.read()))
    return msgpack.unpackb(outer["payload"])


def _write_payload(path, payload, *, sha=None):
    """Re-envelope a (possibly mutated) payload; ``sha`` overrides the
    recomputed digest to fabricate an envelope-level mismatch."""
    raw = msgpack.packb(payload)
    digest = hashlib.sha256(raw).hexdigest() if sha is None else sha
    blob = msgpack.packb({"sha256": digest, "payload": raw})
    with open(path, "wb") as f:
        f.write(compressio.compress(blob, level=3))


def _rewrite(src, dst, mutate):
    payload = _read_payload(src)
    mutate(payload)
    _write_payload(dst, payload)


def test_roundtrip_intact(saved):
    idx, path = saved
    loaded = RangeGraphIndex.load(path)
    np.testing.assert_array_equal(loaded.vectors, idx.vectors)
    np.testing.assert_array_equal(loaded.neighbors, idx.neighbors)
    np.testing.assert_array_equal(loaded.attrs, idx.attrs)
    np.testing.assert_array_equal(loaded.perm, idx.perm)


@pytest.mark.parametrize("field", ["vectors", "neighbors", "attrs", "perm"])
def test_bit_flip_names_the_field(saved, tmp_path, field):
    _, path = saved
    bad = str(tmp_path / f"flip_{field}.bin")

    def flip(payload):
        data = bytearray(payload[field]["data"])
        data[len(data) // 2] ^= 0x40
        payload[field]["data"] = bytes(data)

    _rewrite(path, bad, flip)
    with pytest.raises(IndexCorruptionError, match="checksum mismatch") \
            as ei:
        RangeGraphIndex.load(bad)
    assert ei.value.field == field
    assert field in str(ei.value)


def test_truncation_names_the_field(saved, tmp_path):
    _, path = saved
    bad = str(tmp_path / "trunc.bin")

    def trunc(payload):
        payload["neighbors"]["data"] = payload["neighbors"]["data"][:-8]

    _rewrite(path, bad, trunc)
    with pytest.raises(IndexCorruptionError, match="truncated") as ei:
        RangeGraphIndex.load(bad)
    assert ei.value.field == "neighbors"


def test_pre_checksum_file_loads_with_warning(saved, tmp_path):
    idx, path = saved
    legacy = str(tmp_path / "legacy.bin")

    def strip_crcs(payload):
        for field in ("vectors", "neighbors", "attrs", "perm"):
            payload[field].pop("crc32")

    _rewrite(path, legacy, strip_crcs)
    with pytest.warns(UserWarning, match="predates per-array checksums"):
        loaded = RangeGraphIndex.load(legacy)
    np.testing.assert_array_equal(loaded.vectors, idx.vectors)
    np.testing.assert_array_equal(loaded.neighbors, idx.neighbors)


def test_envelope_sha_mismatch(saved, tmp_path):
    _, path = saved
    bad = str(tmp_path / "sha.bin")
    _write_payload(bad, _read_payload(path), sha="0" * 64)
    with pytest.raises(IndexCorruptionError, match="checksum mismatch") \
            as ei:
        RangeGraphIndex.load(bad)
    assert ei.value.field == "envelope"


def test_garbage_file_is_envelope_corruption(tmp_path):
    bad = str(tmp_path / "garbage.bin")
    with open(bad, "wb") as f:
        f.write(b"this is not an index file at all")
    with pytest.raises(IndexCorruptionError) as ei:
        RangeGraphIndex.load(bad)
    assert ei.value.field == "envelope"


def test_corruption_error_is_ioerror():
    # historical call sites catch IOError around load(); the typed error
    # must keep flowing through them
    e = IndexCorruptionError("vectors", "boom")
    assert isinstance(e, IOError)
    assert e.field == "vectors"
