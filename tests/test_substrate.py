"""Training substrate: optimizer, train loop, checkpointing, data, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs import ARCHS
from repro.core import BuildConfig, RangeGraphIndex
from repro.data.pipeline import TokenPipeline, vector_dataset
from repro.models.api import Model
from repro.runtime.trainer import TrainLoopConfig, run_train_loop
from repro.serve.engine import Request, ServingEngine
from repro.train import compression
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.train.step import build_train_step


def _model():
    cfg = ARCHS["qwen3-0.6b"].reduced(n_layers=2, vocab=128)
    return Model(cfg), cfg


def test_adamw_reduces_loss():
    model, cfg = _model()
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=50)
    pipe = TokenPipeline(cfg.vocab, batch=4, seq=32, seed=0)
    step = jax.jit(build_train_step(model, ocfg))
    losses = []
    b = pipe.next_batch()
    for _ in range(12):
        params, opt, metrics = step(params, opt, b)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses
    assert float(metrics["grad_norm"]) > 0


def test_microbatch_accumulation_matches_full_batch():
    model, cfg = _model()
    params = model.init(jax.random.PRNGKey(1))
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    pipe = TokenPipeline(cfg.vocab, batch=8, seq=16, seed=1)
    b = pipe.next_batch()
    s1 = jax.jit(build_train_step(model, ocfg, microbatches=1))
    s4 = jax.jit(build_train_step(model, ocfg, microbatches=4))
    p1, _, m1 = s1(params, opt, b)
    p4, _, m4 = s4(params, opt, b)
    # losses are means over microbatches; grads averaged — params must agree
    d = jax.tree.map(
        lambda a, c: float(jnp.max(jnp.abs(a - c))), p1, p4
    )
    assert max(jax.tree.leaves(d)) < 2e-4, m1["loss"]


def test_grad_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                          jnp.float32)}
    err = compression.init_error_state(g)
    ghat, err2 = compression.compress_grads(g, err)
    # quantization error is bounded and carried
    q_err = float(jnp.max(jnp.abs(ghat["w"] - g["w"])))
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert q_err <= scale * 0.51 + 1e-6
    np.testing.assert_allclose(
        np.asarray(err2["w"]), np.asarray(g["w"] - ghat["w"]), rtol=1e-6
    )
    # error feedback: next round includes the residual
    ghat2, _ = compression.compress_grads(g, err2)
    two_step = np.asarray(ghat["w"] + ghat2["w"])
    np.testing.assert_allclose(two_step, 2 * np.asarray(g["w"]),
                               atol=2.1 * scale)


def test_train_step_with_compression_runs():
    model, cfg = _model()
    params = model.init(jax.random.PRNGKey(2))
    opt = init_opt_state(params)
    err = compression.init_error_state(params)
    ocfg = AdamWConfig(lr=5e-3, warmup_steps=0, total_steps=10)
    pipe = TokenPipeline(cfg.vocab, batch=4, seq=16, seed=2)
    step = jax.jit(build_train_step(model, ocfg, compress=True))
    b = pipe.next_batch()
    losses = []
    for _ in range(8):
        params, opt, metrics, err = step(params, opt, b, err)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


def test_checkpoint_roundtrip_and_retention(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.bfloat16)},
    }
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree, keep=2)
    assert ckpt.latest_step(d) == 5
    files = sorted(os.listdir(d))
    assert len([f for f in files if f.endswith(".ckpt")]) == 2
    got, step, _ = ckpt.restore(d, tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.ones((3,))}
    ckpt.save(str(tmp_path), 1, tree)
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"a": jnp.ones((4,))})


def test_train_loop_restores_after_crash(tmp_path):
    model, cfg = _model()
    params = model.init(jax.random.PRNGKey(3))
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=100)
    pipe_step = jax.jit(build_train_step(model, ocfg))
    pipe = TokenPipeline(cfg.vocab, batch=2, seq=16, seed=3)
    batches = [pipe.next_batch() for _ in range(40)]

    crashed = {"done": False}

    def step_fn(state, batch):
        p, o = state
        if not crashed["done"] and int(o.step) == 7:
            crashed["done"] = True
            raise RuntimeError("injected device failure")
        p, o, m = pipe_step(p, o, batch)
        return (p, o), m

    cfg_loop = TrainLoopConfig(
        total_steps=12, ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100,
    )
    (p, o), hist = run_train_loop(
        step_fn, (params, opt), lambda s: batches[s], cfg_loop,
        log=lambda *_: None,
    )
    assert hist["restarts"] == 1
    assert int(o.step) == 12
    assert ckpt.latest_step(str(tmp_path)) == 12


def test_elastic_restore_resharding(tmp_path):
    """Checkpoints restore against a different device layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(str(tmp_path), 3, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, step, _ = ckpt.restore(str(tmp_path), tree, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_serving_engine_end_to_end():
    vectors, attrs, qv = vector_dataset(
        512, 16, seed=5, queries=8, attr_kind="uniform"
    )
    idx = RangeGraphIndex.build(
        vectors, attrs[:, 0], BuildConfig(m=8, ef_construction=32)
    )
    eng = ServingEngine(idx, ef=48, max_batch=8)
    lo, hi = np.quantile(attrs[:, 0], [0.2, 0.7])
    for i in range(8):
        eng.submit(Request(qv[i], lo, hi, k=5))
    results = eng.flush()
    assert len(results) == 8
    for r in results:
        got = r.ids[r.ids >= 0]
        assert ((attrs[got, 0] >= lo) & (attrs[got, 0] <= hi)).all()
    assert eng.qps > 0


def test_vector_dataset_deterministic():
    a = vector_dataset(128, 8, seed=9)
    b = vector_dataset(128, 8, seed=9)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
