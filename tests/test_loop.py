"""AsyncServingEngine: deadlines, shedding, backpressure, drain.

Each test drives the loop inside its own ``asyncio.run`` (pytest-asyncio
is not a dependency). Deterministic tests pass ``faults=False`` so the CI
chaos leg (``REPRO_FAULTS=...``) cannot perturb them; the tests that DO
want a stalled flusher build their own injector with ``latency_rate=1.0``
— a deterministic spike, not a probabilistic one.

All engines share one module-scoped warmed executor (compiles once) —
engines never close a shared executor, so every test starts on the same
warmed grid and the module's final test asserts the whole file ran with
zero post-warmup compiles.
"""
import asyncio

import numpy as np
import pytest

from repro.core import BuildConfig, RangeGraphIndex, SearchConfig, ServeConfig
from repro.serve import (
    AsyncServingEngine,
    DeadlineExceededError,
    FaultConfig,
    InvalidRequestError,
    OverloadedError,
    Request,
    Result,
    SearchExecutor,
    ServingEngine,
    ShedError,
    ShutdownError,
)

CFG = SearchConfig(ef=32, k_bucket=10)


@pytest.fixture(scope="module")
def serving():
    rng = np.random.default_rng(31)
    n, d = 256, 12
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 100, n)
    idx = RangeGraphIndex.build(
        vectors, attrs, BuildConfig(m=8, ef_construction=32,
                                    brute_threshold=32)
    )
    ex = SearchExecutor(idx, CFG, max_batch=4, warmup=True)
    return idx, ex, rng


def _req(rng, idx, k=5):
    v = rng.standard_normal(idx.dim).astype(np.float32)
    lo, hi = sorted(rng.uniform(0, 100, 2))
    return Request(vector=v, lo=lo, hi=hi, k=k)


def _stall(latency_s):
    """An injector that stalls EVERY flush by latency_s (deterministic)."""
    return FaultConfig(kinds=("latency",), latency_s=latency_s,
                       latency_rate=1.0)


def test_serves_and_matches_sync_engine(serving):
    idx, ex, rng = serving
    reqs = [_req(rng, idx) for _ in range(6)]

    async def go():
        async with AsyncServingEngine(idx, executor=ex,
                                      faults=False) as eng:
            return await asyncio.gather(*(eng.submit(r) for r in reqs))

    got = asyncio.run(go())
    sync = ServingEngine(idx, executor=ex, faults=False)
    for r in reqs:
        sync.submit(r)
    want = sync.flush()
    for g, w, r in zip(got, want, reqs):
        assert isinstance(g, Result)
        assert g.ids.shape == (r.k,)
        np.testing.assert_array_equal(g.ids, w.ids)
        np.testing.assert_array_equal(g.dists, w.dists)


def test_validation_rejects_before_queueing(serving):
    idx, ex, rng = serving

    async def go():
        async with AsyncServingEngine(idx, executor=ex,
                                      faults=False) as eng:
            bad = [
                Request(np.zeros(idx.dim, np.float32), 0.0, 1.0, k=0),
                Request(np.zeros(idx.dim, np.float32), 0.0, 1.0, k=64),
                Request(np.zeros(idx.dim + 1, np.float32), 0.0, 1.0, k=5),
                Request(np.full(idx.dim, np.nan, np.float32), 0.0, 1.0,
                        k=5),
                Request(np.zeros(idx.dim, np.float32), 5.0, 1.0, k=5),
                Request(np.zeros(idx.dim, np.float32), np.nan, 1.0, k=5),
            ]
            for r in bad:
                with pytest.raises(InvalidRequestError):
                    await eng.submit(r)
            assert eng.stats["submitted"] == 0
            # the engine still serves clean traffic afterwards
            res = await eng.submit(_req(rng, idx))
            assert isinstance(res, Result)

    asyncio.run(go())


def test_expired_queued_requests_shed_before_compute(serving):
    """While a latency spike burns inside one flush (worker thread), a
    short-deadline queued request expires: the reaper sheds it and it
    never reaches the executor (dispatched stays at the first batch)."""
    idx, ex, rng = serving

    async def go():
        eng = AsyncServingEngine(
            idx, executor=ex, faults=_stall(0.6),
            serve=ServeConfig(deadline_s=5.0, max_wait_s=0.0,
                              deadline_margin_s=0.0),
        )
        first = asyncio.ensure_future(eng.submit(_req(rng, idx)))
        await asyncio.sleep(0.2)     # flusher is now asleep in the spike
        with pytest.raises(ShedError):
            await eng.submit(_req(rng, idx), deadline_s=0.1)
        assert eng.stats["shed"] == 1
        assert eng.stats["dispatched"] == 1   # the shed one never ran
        assert isinstance(await first, Result)
        await eng.aclose()
        return eng.stats

    stats = asyncio.run(go())
    assert stats["served"] == 1


def test_shed_expired_false_times_out_instead(serving):
    idx, ex, rng = serving

    async def go():
        eng = AsyncServingEngine(
            idx, executor=ex, faults=_stall(0.6),
            serve=ServeConfig(deadline_s=5.0, max_wait_s=0.0,
                              deadline_margin_s=0.0, shed_expired=False),
        )
        first = asyncio.ensure_future(eng.submit(_req(rng, idx)))
        await asyncio.sleep(0.2)
        with pytest.raises(DeadlineExceededError):
            await eng.submit(_req(rng, idx), deadline_s=0.1)
        await first
        await eng.aclose()

    asyncio.run(go())


def test_inflight_deadline_fires_during_latency_spike(serving):
    """The reaper delivers DeadlineExceededError while the flush is still
    running in its worker thread — an executor stall cannot freeze timeout
    delivery. The late result is counted, not double-delivered."""
    idx, ex, rng = serving

    async def go():
        eng = AsyncServingEngine(
            idx, executor=ex, faults=_stall(0.5),
            serve=ServeConfig(deadline_s=0.15, max_wait_s=0.0,
                              deadline_margin_s=0.0),
        )
        with pytest.raises(DeadlineExceededError):
            await eng.submit(_req(rng, idx))
        assert eng.stats["timeouts"] == 1
        # let the spiking flush finish: its result must be counted late,
        # not delivered into the already-failed future
        await asyncio.sleep(0.6)
        assert eng.stats["late_results"] == 1
        assert eng.stats["served"] == 0
        await eng.aclose()

    asyncio.run(go())


def test_backpressure_reject(serving):
    idx, ex, rng = serving

    async def go():
        eng = AsyncServingEngine(
            idx, executor=ex, faults=_stall(0.5),
            serve=ServeConfig(deadline_s=5.0, max_queue=1, max_wait_s=0.0,
                              deadline_margin_s=0.0, backpressure="reject"),
        )
        # 1st occupies the flusher (spike), 2nd fills the queue, 3rd must
        # be rejected at admission without ever queueing
        t1 = asyncio.ensure_future(eng.submit(_req(rng, idx)))
        await asyncio.sleep(0.2)
        t2 = asyncio.ensure_future(eng.submit(_req(rng, idx)))
        await asyncio.sleep(0.05)
        with pytest.raises(OverloadedError):
            await eng.submit(_req(rng, idx))
        assert eng.stats["rejected"] == 1
        assert isinstance(await t1, Result)
        assert isinstance(await t2, Result)
        await eng.aclose()

    asyncio.run(go())


def test_backpressure_block_waits_for_space(serving):
    idx, ex, rng = serving

    async def go():
        eng = AsyncServingEngine(
            idx, executor=ex, faults=_stall(0.3),
            serve=ServeConfig(deadline_s=5.0, max_queue=1, max_wait_s=0.0,
                              deadline_margin_s=0.0, backpressure="block"),
        )
        t1 = asyncio.ensure_future(eng.submit(_req(rng, idx)))
        await asyncio.sleep(0.1)
        t2 = asyncio.ensure_future(eng.submit(_req(rng, idx)))
        await asyncio.sleep(0.05)
        # blocks while the queue is full, then admits once it drains
        t3 = asyncio.ensure_future(eng.submit(_req(rng, idx)))
        out = await asyncio.gather(t1, t2, t3)
        assert all(isinstance(r, Result) for r in out)
        assert eng.stats["rejected"] == 0
        await eng.aclose()

    asyncio.run(go())


def test_backpressure_block_respects_deadline(serving):
    idx, ex, rng = serving

    async def go():
        eng = AsyncServingEngine(
            idx, executor=ex, faults=_stall(0.6),
            serve=ServeConfig(deadline_s=5.0, max_queue=1, max_wait_s=0.0,
                              deadline_margin_s=0.0, backpressure="block"),
        )
        t1 = asyncio.ensure_future(eng.submit(_req(rng, idx)))
        await asyncio.sleep(0.2)
        t2 = asyncio.ensure_future(eng.submit(_req(rng, idx)))
        await asyncio.sleep(0.05)
        with pytest.raises(DeadlineExceededError):
            await eng.submit(_req(rng, idx), deadline_s=0.1)
        await asyncio.gather(t1, t2)
        await eng.aclose()

    asyncio.run(go())


def test_aclose_drains_pending(serving):
    idx, ex, rng = serving

    async def go():
        eng = AsyncServingEngine(
            idx, executor=ex, faults=False,
            serve=ServeConfig(deadline_s=5.0, max_wait_s=5.0),
        )
        # long max_wait: these would linger, but aclose must flush them
        tasks = [asyncio.ensure_future(eng.submit(_req(rng, idx)))
                 for _ in range(3)]
        await asyncio.sleep(0.05)
        await eng.aclose(drain=True)
        out = await asyncio.gather(*tasks)
        assert all(isinstance(r, Result) for r in out)
        assert eng.stats["shutdown"] == 0
        with pytest.raises(ShutdownError):
            await eng.submit(_req(rng, idx))

    asyncio.run(go())


def test_aclose_no_drain_fails_fast(serving):
    idx, ex, rng = serving

    async def go():
        eng = AsyncServingEngine(
            idx, executor=ex, faults=_stall(0.5),
            serve=ServeConfig(deadline_s=5.0, max_wait_s=0.0,
                              deadline_margin_s=0.0),
        )
        t1 = asyncio.ensure_future(eng.submit(_req(rng, idx)))
        await asyncio.sleep(0.2)   # t1 in flight (spiking), t2 queued
        t2 = asyncio.ensure_future(eng.submit(_req(rng, idx)))
        await asyncio.sleep(0.05)
        await eng.aclose(drain=False)
        with pytest.raises(ShutdownError):
            await t2
        # the in-flight request fails fast too: exactly one outcome each
        with pytest.raises(ShutdownError):
            await t1
        assert eng.stats["shutdown"] == 2

    asyncio.run(go())


def test_deadline_margin_flushes_early(serving):
    """With a huge max_wait the loop would linger forever; the deadline
    margin forces the flush in time to serve the request."""
    idx, ex, rng = serving

    async def go():
        eng = AsyncServingEngine(
            idx, executor=ex, faults=False,
            serve=ServeConfig(deadline_s=0.5, max_wait_s=30.0,
                              deadline_margin_s=0.4),
        )
        res = await eng.submit(_req(rng, idx))
        assert isinstance(res, Result)
        await eng.aclose()

    asyncio.run(go())


def test_full_batch_flushes_immediately(serving):
    idx, ex, rng = serving

    async def go():
        eng = AsyncServingEngine(
            idx, executor=ex, faults=False,
            serve=ServeConfig(deadline_s=30.0, max_wait_s=30.0,
                              deadline_margin_s=0.1),
        )
        # max_batch (4) submissions: the loop must not wait out max_wait_s
        out = await asyncio.wait_for(
            asyncio.gather(*(eng.submit(_req(rng, idx))
                             for _ in range(ex.max_batch))),
            timeout=10.0,
        )
        assert all(isinstance(r, Result) for r in out)
        assert eng.stats["flushes"] >= 1
        await eng.aclose()

    asyncio.run(go())


def test_zero_post_warmup_compiles_across_module(serving):
    """Runs last (file order): every flush in this file — partial batches,
    mixed arrival patterns, spikes, drains — stayed on the warmed grid."""
    idx, ex, rng = serving
    assert ex.stats["compiles"] == ex.stats["warmup_compiles"] > 0
