"""Per-architecture smoke tests: reduced config, one forward/train/decode
step on CPU, asserting shapes + finiteness (the assignment's smoke contract).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.api import Model

ARCH_IDS = sorted(ARCHS)


def _tiny(name):
    cfg = ARCHS[name].reduced()
    return Model(cfg), cfg


def _batch(model, cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    tgt = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tok, "targets": tgt}
    if model.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("name", ARCH_IDS)
def test_loss_forward(name):
    model, cfg = _tiny(name)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(model, cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{name}: loss {loss}"
    assert float(loss) > 0
    assert np.isfinite(float(metrics["nll"]))


@pytest.mark.parametrize("name", ARCH_IDS)
def test_train_step_decreases_loss(name):
    """A couple of SGD steps on one batch must reduce the loss."""
    model, cfg = _tiny(name)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(model, cfg, B=2, S=16, seed=1)

    @jax.jit
    def step(p):
        (l, _), g = jax.value_and_grad(model.loss, has_aux=True)(p, batch)
        p = jax.tree.map(lambda a, b: a - 0.5 * b, p, g)
        return p, l

    losses = []
    for _ in range(4):
        params, l = step(params)
        losses.append(float(l))
    assert all(np.isfinite(losses)), (name, losses)
    assert losses[-1] < losses[0], (name, losses)


@pytest.mark.parametrize("name", ARCH_IDS)
def test_decode_step_shapes(name):
    model, cfg = _tiny(name)
    params = model.init(jax.random.PRNGKey(2))
    B, S = 2, 16
    cache = model.init_cache(B, S)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, t, c: model.decode(p, t, c, jnp.int32(3))
    )(params, tok, cache)
    assert logits.shape[-1] == cfg.padded_vocab
    assert np.isfinite(np.asarray(logits)).all(), name
    # cache structure preserved
    jax.tree.map(lambda a, b: None, cache, cache2)


@pytest.mark.parametrize(
    "name",
    ["qwen3-0.6b", "gemma2-9b", "xlstm-125m", "zamba2-1.2b",
     "granite-moe-1b-a400m"],
)
def test_decode_matches_forward(name):
    """Token-by-token decode from an empty cache must reproduce the
    full-sequence forward logits (teacher forcing). Capacity factor is
    raised so MoE token-dropping (a train-time-only semantics) is off."""
    cfg = ARCHS[name].reduced(moe_capacity_factor=8.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    B, S = 1, 8
    rng = np.random.default_rng(3)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    from repro.models import transformer

    hidden, _, _ = transformer.forward_seq(params, cfg, tok)
    full_logits = transformer.compute_logits(params, cfg, hidden)

    cache = model.init_cache(B, S)
    outs = []
    dec = jax.jit(model.decode)
    for t in range(S):
        logits, cache = dec(params, tok[:, t : t + 1], cache, jnp.int32(t))
        outs.append(np.asarray(logits).reshape(B, -1))
    got = np.stack(outs, axis=1)
    want = np.asarray(full_logits)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("name", ["qwen3-0.6b", "phi3-mini-3.8b"])
def test_prefill_then_decode(name):
    """Prefill cache + one decode step == forward over S+1 tokens."""
    model, cfg = _tiny(name)
    params = model.init(jax.random.PRNGKey(4))
    B, S = 1, 8
    rng = np.random.default_rng(4)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)

    from repro.models import transformer

    hidden, _, _ = transformer.forward_seq(params, cfg, tok)
    want = np.asarray(transformer.compute_logits(params, cfg, hidden))[:, -1]

    logits_p, caches = model.prefill(params, tokens=tok[:, :S])
    # prefill caches are [L, B, H, S, Dh]; decode expects capacity >= S+1
    def grow(a):
        pad = [(0, 0)] * a.ndim
        pad[-2] = (0, 8)
        return jnp.pad(a, pad)

    cache = jax.tree.map(grow, caches)
    logits, _ = model.decode(params, tok[:, S:], cache, jnp.int32(S))
    got = np.asarray(logits)[:, 0]
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_embed_produces_vectors():
    model, cfg = _tiny("qwen3-0.6b")
    params = model.init(jax.random.PRNGKey(5))
    tok = jnp.zeros((3, 16), jnp.int32)
    emb = model.embed(params, tok)
    assert emb.shape == (3, cfg.d_model)
    assert np.isfinite(np.asarray(emb)).all()
