"""End-to-end behaviour tests for the iRangeGraph system."""
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import BuildConfig, RangeGraphIndex, recall
from repro.core import baselines, multiattr
from repro.core import storage as storage_mod


@pytest.fixture(scope="module")
def small_index():
    rng = np.random.default_rng(7)
    n, d = 512, 16
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 100, n)
    cfg = BuildConfig(m=8, ef_construction=32, brute_threshold=32)
    return RangeGraphIndex.build(vectors, attrs, cfg), rng


def test_build_invariants(small_index):
    idx, _ = small_index
    # decode first: under the CI storage legs the neighbor table may be a
    # codec (int16 array or SplitNeighbors struct) rather than raw int32
    nbrs = np.asarray(storage_mod.decode_neighbors(idx.neighbors))
    n, layers, m = nbrs.shape
    assert n == 512 and m == 8 and layers == idx.logn + 1
    # every edge stays inside its layer's segment and points to a real node
    for lay in range(layers):
        s = idx.logn - lay
        lo = (np.arange(n) >> s) << s
        hi = lo + (1 << s) - 1
        nb = nbrs[:, lay, :]
        ok = nb < 0
        inseg = (nb >= lo[:, None]) & (nb <= hi[:, None]) & (nb < n)
        assert (ok | inseg).all(), f"edge out of segment at layer {lay}"
        # no self-loops
        assert (nb != np.arange(n)[:, None]).all()


def test_search_results_always_in_range(small_index):
    idx, rng = small_index
    B = 32
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    L = rng.integers(0, idx.n - 64, B).astype(np.int32)
    R = (L + rng.integers(8, 64, B)).astype(np.int32)
    res = idx.search_ranks(q, L, R, k=5, ef=32)
    ids = np.asarray(res.ids)
    for i in range(B):
        got = ids[i][ids[i] >= 0]
        assert ((got >= L[i]) & (got <= R[i])).all()


def test_search_recall_beats_threshold(small_index):
    idx, rng = small_index
    B = 48
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    for span in (32, 128, 511):
        L = rng.integers(0, idx.n - span, B).astype(np.int32)
        R = (L + span - 1).astype(np.int32)
        res = idx.search_ranks(q, L, R, k=10, ef=64)
        gt, _ = idx.brute_force(q, L, R, k=10)
        rec = recall(res.ids, gt)
        assert rec >= 0.85, f"span {span}: recall {rec}"


def test_skip_layers_close_to_naive(small_index):
    """Layer skipping is an optimization; recall must stay comparable."""
    idx, rng = small_index
    B = 32
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    L = rng.integers(0, idx.n // 2, B).astype(np.int32)
    R = (L + idx.n // 4).astype(np.int32)
    gt, _ = idx.brute_force(q, L, R, k=10)
    r_skip = recall(idx.search_ranks(q, L, R, k=10, ef=48).ids, gt)
    r_naive = recall(
        idx.search_ranks(q, L, R, k=10, ef=48, skip_layers=False).ids, gt
    )
    assert abs(r_skip - r_naive) < 0.12


def test_duplicate_attribute_values():
    rng = np.random.default_rng(3)
    n, d = 256, 8
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.integers(0, 10, n).astype(np.float64)  # heavy duplication
    idx = RangeGraphIndex.build(
        vectors, attrs, BuildConfig(m=8, ef_construction=32)
    )
    q = rng.standard_normal((8, d)).astype(np.float32)
    L, R = idx.ranks_of(np.full(8, 3.0), np.full(8, 6.0))
    # value range [3, 6] must cover exactly the objects with attr in [3, 6]
    want = np.sort(np.where((attrs >= 3) & (attrs <= 6))[0])
    got = np.sort(idx.perm[L[0] : R[0] + 1])
    np.testing.assert_array_equal(got, want)
    res = idx.search_ranks(q, L, R, k=5, ef=32)
    ids = np.asarray(res.ids)
    orig = idx.original_ids(ids)
    sel = orig[ids >= 0]
    assert ((attrs[sel] >= 3) & (attrs[sel] <= 6)).all()


def test_build_chunk_size_invariant():
    """cfg.chunk is a batching knob only: small chunks (exercising the
    chunked loops in _build_search_level, _build_brute_level and the
    reverse pass) must reproduce the default-chunk table exactly."""
    rng = np.random.default_rng(17)
    n, d = 256, 8
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 1, n)
    base = dict(m=6, ef_construction=24, brute_threshold=32)
    big = RangeGraphIndex.build(vectors, attrs, BuildConfig(**base))
    small = RangeGraphIndex.build(
        vectors, attrs, BuildConfig(**base, chunk=64)
    )
    np.testing.assert_array_equal(
        np.asarray(storage_mod.decode_neighbors(big.neighbors)),
        np.asarray(storage_mod.decode_neighbors(small.neighbors)),
    )


def test_save_load_roundtrip(tmp_path, small_index):
    idx, rng = small_index
    p = str(tmp_path / "index.rg")
    idx.save(p)
    idx2 = RangeGraphIndex.load(p)
    np.testing.assert_array_equal(
        np.asarray(storage_mod.decode_neighbors(idx.neighbors)),
        np.asarray(storage_mod.decode_neighbors(idx2.neighbors)),
    )
    np.testing.assert_array_equal(
        storage_mod.decode_vectors(idx.vectors),
        storage_mod.decode_vectors(idx2.vectors),
    )
    q = rng.standard_normal((4, idx.dim)).astype(np.float32)
    L = np.array([10, 20, 30, 40], np.int32)
    R = np.array([200, 210, 220, 230], np.int32)
    a = idx.search_ranks(q, L, R, k=5, ef=32)
    b = idx2.search_ranks(q, L, R, k=5, ef=32)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


def test_baselines_in_range_and_reasonable(small_index):
    idx, rng = small_index
    B = 24
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    span = 128
    L = rng.integers(0, idx.n - span, B).astype(np.int32)
    R = (L + span - 1).astype(np.int32)
    gt, _ = idx.brute_force(q, L, R, k=10)
    for name, fn in [
        ("pre", baselines.prefilter),
        ("post", baselines.postfilter),
        ("in", baselines.infilter),
        ("basic", baselines.basic_search),
        ("superpost", baselines.super_postfilter),
    ]:
        res = fn(idx, q, L, R, k=10, ef=96)
        ids = np.asarray(res.ids)
        for i in range(B):
            got = ids[i][ids[i] >= 0]
            assert ((got >= L[i]) & (got <= R[i])).all(), name
        rec = recall(ids, gt)
        floor = 1.0 if name == "pre" else 0.5
        assert rec >= floor, f"{name}: recall {rec}"
    # BasicSearch must be exact-range like ours and get decent recall
    rec_basic = recall(
        np.asarray(baselines.basic_search(idx, q, L, R, k=10, ef=96).ids), gt
    )
    assert rec_basic >= 0.8


def test_oracle_search_high_recall(small_index):
    idx, rng = small_index
    B = 8
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    L = np.full(B, 100, np.int32)
    R = np.full(B, 355, np.int32)
    gt, _ = idx.brute_force(q, L, R, k=10)
    res = baselines.oracle_search(idx, q, L, R, k=10, ef=64)
    assert recall(np.asarray(res.ids), gt) >= 0.9


@given(st.integers(0, 2**31))
@settings(max_examples=5, deadline=None)
def test_property_search_never_out_of_range(seed):
    rng = np.random.default_rng(seed)
    n, d = 128, 8
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.standard_normal(n)
    idx = RangeGraphIndex.build(
        vectors, attrs, BuildConfig(m=6, ef_construction=16)
    )
    B = 8
    q = rng.standard_normal((B, d)).astype(np.float32)
    L = rng.integers(0, n - 1, B).astype(np.int32)
    R = (L + rng.integers(0, n - 1, B)).clip(max=n - 1).astype(np.int32)
    res = idx.search_ranks(q, L, R, k=5, ef=16)
    ids = np.asarray(res.ids)
    for i in range(B):
        got = ids[i][ids[i] >= 0]
        assert ((got >= L[i]) & (got <= R[i])).all()
        assert len(set(got.tolist())) == len(got)


def test_multiattr_modes(small_index):
    idx, rng = small_index
    n = idx.n
    attr2 = rng.uniform(0, 1, n).astype(np.float32)
    B = 24
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    L = np.zeros(B, np.int32)
    R = np.full(B, n // 2, np.int32)
    lo2 = np.full(B, 0.2, np.float32)
    hi2 = np.full(B, 0.8, np.float32)
    gt, _ = multiattr.brute_force_multiattr(
        idx, attr2, q, L, R, lo2, hi2, k=10
    )
    recs = {}
    for mode in ("post", "in", "adaptive"):
        res = multiattr.search_multiattr(
            idx, attr2, q, L, R, lo2, hi2, k=10, ef=96, mode=mode
        )
        ids = np.asarray(res.ids)
        ok = ids >= 0
        # conjunctive predicates hold on every result
        sel = ids[ok]
        assert ((sel >= 0) & (sel <= n // 2)).all()
        assert ((attr2[sel] >= 0.2) & (attr2[sel] <= 0.8)).all()
        recs[mode] = recall(ids, gt)
    assert recs["post"] >= 0.85
    assert recs["adaptive"] >= 0.7
