"""Chaos soak: the serving loop's contracts must hold under fire.

ISSUE acceptance pin: under injected latency spikes, flush exceptions and
queue-full bursts at overload QPS, (1) every request resolves with exactly
one typed terminal outcome, (2) expired requests are shed before they
reach compute, (3) the post-warmup compile count stays 0 — batch
formation never leaves the warmed bucket grid, whatever the arrival
pattern the faults produce.

The injector is seeded, so a failure here replays deterministically.
"""
import asyncio

import numpy as np
import pytest

from repro.core import BuildConfig, RangeGraphIndex, SearchConfig, ServeConfig
from repro.serve import (
    AsyncServingEngine,
    DeadlineExceededError,
    FaultConfig,
    FaultInjector,
    InjectedFaultError,
    OverloadedError,
    Request,
    Result,
    SearchExecutor,
    ShedError,
    ShutdownError,
)

CFG = SearchConfig(ef=32, k_bucket=10)


@pytest.fixture(scope="module")
def serving():
    rng = np.random.default_rng(47)
    n, d = 256, 12
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 100, n)
    idx = RangeGraphIndex.build(
        vectors, attrs, BuildConfig(m=8, ef_construction=32,
                                    brute_threshold=32)
    )
    ex = SearchExecutor(idx, CFG, max_batch=4, warmup=True)
    return idx, ex, rng


def _req(rng, idx, k=5):
    v = rng.standard_normal(idx.dim).astype(np.float32)
    lo, hi = sorted(rng.uniform(0, 100, 2))
    return Request(vector=v, lo=lo, hi=hi, k=k)


def test_chaos_soak_exactly_once(serving):
    idx, ex, rng = serving
    faults = FaultInjector(FaultConfig(
        kinds=("latency", "flush_error", "queue_full"),
        latency_s=0.1, latency_rate=0.3,
        flush_error_rate=0.2, queue_full_rate=0.2, seed=11,
    ))
    N = 120
    reqs = [_req(rng, idx) for _ in range(N)]

    async def fire(eng, r):
        try:
            res = await eng.submit(r, deadline_s=0.12)
            assert isinstance(res, Result)
            return "ok"
        except OverloadedError:
            return "rejected"
        except ShedError:
            return "shed"
        except DeadlineExceededError:
            return "timeout"
        except ShutdownError:
            return "shutdown"
        except InjectedFaultError:
            return "failed"
        # anything else propagates and fails the test: outcomes are typed

    async def go():
        eng = AsyncServingEngine(
            idx, executor=ex, faults=faults,
            serve=ServeConfig(deadline_s=0.12, max_queue=32,
                              max_wait_s=0.005, deadline_margin_s=0.02,
                              backpressure="reject"),
        )
        tasks = []
        for r in reqs:
            tasks.append(asyncio.ensure_future(fire(eng, r)))
            await asyncio.sleep(0.002)   # ~500 qps offered: overload
        outcomes = await asyncio.gather(*tasks)
        await eng.aclose(drain=True)
        return outcomes, eng.stats

    outcomes, stats = asyncio.run(go())

    # exactly-once: every submit produced one typed outcome
    assert len(outcomes) == N
    counts = {o: outcomes.count(o) for o in set(outcomes)}
    assert sum(counts.values()) == N
    # caller-observed outcomes reconcile with the engine's own accounting
    assert counts.get("ok", 0) == stats["served"]
    assert counts.get("shed", 0) == stats["shed"]
    assert counts.get("rejected", 0) == stats["rejected"]
    assert counts.get("failed", 0) == stats["failed"]
    assert counts.get("timeout", 0) == stats["timeouts"]
    assert counts.get("shutdown", 0) == stats["shutdown"]
    # shed before compute: a shed request was never part of a dispatch
    assert stats["shed"] + stats["dispatched"] <= stats["submitted"]
    # the chaos actually happened (seeded, so this is stable)
    assert faults.counts["latency"] > 0
    assert faults.counts["flush_error"] > 0
    assert faults.counts["queue_full"] > 0
    assert stats["flush_failures"] > 0
    # and through all of it, batch formation stayed on the warmed grid
    assert ex.stats["compiles"] == ex.stats["warmup_compiles"]


def test_flush_error_isolation_async(serving):
    """An injected flush failure fails only its own flush's requests; the
    next submit on the same engine serves normally."""
    idx, ex, rng = serving
    faults = FaultInjector(FaultConfig(kinds=("flush_error",),
                                       flush_error_rate=1.0))

    async def go():
        eng = AsyncServingEngine(
            idx, executor=ex, faults=faults,
            serve=ServeConfig(deadline_s=5.0, max_wait_s=0.0,
                              deadline_margin_s=0.0),
        )
        with pytest.raises(InjectedFaultError):
            await eng.submit(_req(rng, idx))
        assert eng.stats["flush_failures"] == 1
        faults.armed = False
        res = await eng.submit(_req(rng, idx))   # regression: still alive
        assert isinstance(res, Result)
        await eng.aclose()
        assert eng.stats["served"] == 1
        assert eng.stats["failed"] == 1

    asyncio.run(go())


def test_env_faults_reach_only_the_async_loop(serving, monkeypatch):
    """REPRO_FAULTS (the CI chaos leg) arms the async loop by default but
    never the sync engine/executor — deterministic suites stay green."""
    from repro.serve.engine import ServingEngine

    idx, ex, rng = serving
    monkeypatch.setenv("REPRO_FAULTS", "flush_error")
    monkeypatch.setenv("REPRO_FAULT_FLUSH_ERROR_RATE", "1.0")

    async def go():
        eng = AsyncServingEngine(idx, executor=ex)   # faults=None: env
        with pytest.raises(InjectedFaultError):
            await eng.submit(_req(rng, idx))
        await eng.aclose()

    asyncio.run(go())
    sync = ServingEngine(idx, executor=ex)           # env must NOT attach
    assert sync.faults is None
    sync.submit(_req(rng, idx))
    assert isinstance(sync.flush()[0], Result)
