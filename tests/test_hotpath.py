"""Fused beam-search hot path: kernel parity, bitset, multi-expansion.

Covers the three legs of the fused expansion step:
  * gather-distance Pallas kernel (interpret mode) vs the jnp oracle, the
    historical inline ``_pairdist`` composition, and the tiled pairwise
    kernel;
  * packed uint32 visited bitset vs a dense bool visited map;
  * ``expand_width`` generalization: W=1 is bit-identical to the reference
    engine (``core/search_ref.py``); W>1 keeps recall on a saturating index.
"""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import BuildConfig, RangeGraphIndex, bitset, edge_select, recall
from repro.core import search as search_mod
from repro.core import storage as storage_mod
from repro.core import search_ref
from repro.kernels import ref
from repro.kernels.distance import pairwise_dist_kernel_call
from repro.kernels.gather_distance import gather_distance_kernel_call


# ---------------------------------------------------------------------------
# gather-distance kernel parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("B,n,d,M", [(3, 64, 16, 9), (8, 128, 48, 16)])
def test_gather_distance_matches_oracle(metric, B, n, d, M):
    rng = np.random.default_rng(B * 100 + M)
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    ids = rng.integers(0, n, (B, M)).astype(np.int32)
    ids[rng.random((B, M)) < 0.3] = -1
    ids = jnp.asarray(ids)

    got = np.asarray(
        gather_distance_kernel_call(q, x, ids, metric=metric, interpret=True)
    )
    want = np.asarray(ref.gather_dist(q, x, ids, metric=metric))
    assert (np.isinf(got) == np.isinf(want)).all()
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4)


def test_gather_distance_matches_pairdist_composition():
    """Oracle == the historical gather + _pairdist inline formulation."""
    rng = np.random.default_rng(0)
    B, n, d, M = 6, 100, 24, 11
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, n, (B, M)).astype(np.int32))
    want = search_mod._pairdist(q, x[jnp.maximum(ids, 0)], "l2")
    got = ref.gather_dist(q, x, ids)
    # all ids valid -> bit-identical math path
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gather_distance_matches_pairwise_kernel():
    """Gathering every row reproduces the tiled pairwise-distance kernel."""
    rng = np.random.default_rng(1)
    B, n, d = 4, 72, 32
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (B, n))
    got = np.asarray(
        gather_distance_kernel_call(q, x, ids, interpret=True)
    )
    want = np.asarray(
        pairwise_dist_kernel_call(
            q, x, block_q=8, block_n=16, block_k=16, interpret=True
        )
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gather_distance_bf16_table():
    rng = np.random.default_rng(2)
    B, n, d, M = 3, 50, 16, 7
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.bfloat16)
    ids = jnp.asarray(rng.integers(0, n, (B, M)).astype(np.int32))
    got = np.asarray(gather_distance_kernel_call(q, x, ids, interpret=True))
    want = np.asarray(ref.gather_dist(q, x, ids))
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# packed visited bitset
# ---------------------------------------------------------------------------

def _dense_test_and_set(dense, ids, valid):
    """Oracle: same contract as bitset.test_and_set on a bool[B, n] map."""
    B, K = ids.shape
    seen = np.zeros((B, K), bool)
    for b in range(B):
        for j in range(K):
            if not valid[b, j] or ids[b, j] < 0:
                continue
            v = ids[b, j]
            if dense[b, v]:
                seen[b, j] = True
            else:
                dense[b, v] = True
    return dense, seen


def test_bitset_matches_dense_bool():
    rng = np.random.default_rng(3)
    B, n, K = 7, 200, 23
    bits = bitset.make(B, n)
    dense = np.zeros((B, n), bool)
    for step in range(6):
        ids = rng.integers(-1, n, (B, K)).astype(np.int32)
        valid = rng.random((B, K)) < 0.8
        bits, seen = bitset.test_and_set(bits, jnp.asarray(ids),
                                         jnp.asarray(valid))
        dense, want_seen = _dense_test_and_set(dense, ids, valid)
        np.testing.assert_array_equal(np.asarray(seen), want_seen)
        # membership agrees on every id afterwards
        probe = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (B, n))
        np.testing.assert_array_equal(
            np.asarray(bitset.lookup(bits, probe)), dense
        )


def test_bitset_in_row_duplicates_exactly_once():
    bits = bitset.make(2, 64)
    ids = jnp.asarray([[5, 5, 9, 5], [63, 0, 63, -1]], jnp.int32)
    valid = jnp.ones((2, 4), bool)
    bits, seen = bitset.test_and_set(bits, ids, valid)
    # note: the -1 slot is *invalid*, not "seen" — callers mask by validity
    np.testing.assert_array_equal(
        np.asarray(seen),
        [[False, True, False, True], [False, False, True, False]],
    )
    # exactly the distinct ids are set
    probe = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32)[None], (2, 64))
    member = np.asarray(bitset.lookup(bits, probe))
    assert sorted(np.where(member[0])[0].tolist()) == [5, 9]
    assert sorted(np.where(member[1])[0].tolist()) == [0, 63]


def test_bitset_word_count():
    assert bitset.num_words(1) == 1
    assert bitset.num_words(32) == 1
    assert bitset.num_words(33) == 2
    assert bitset.make(4, 100).shape == (4, 4)
    assert bitset.make(4, 100).dtype == jnp.uint32


# ---------------------------------------------------------------------------
# expand_width generalization
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_index():
    rng = np.random.default_rng(7)
    n, d = 512, 16
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 100, n)
    cfg = BuildConfig(m=8, ef_construction=32, brute_threshold=32)
    return RangeGraphIndex.build(vectors, attrs, cfg), rng


def test_expand_width1_bit_identical_to_reference(small_index):
    """Acceptance: W=1 reproduces the seed engine's ids AND dists exactly.

    The reference runs under jit like the seed's ``search_improvised`` did;
    eager evaluation changes XLA's FMA fusion and drifts by 1 ulp. The seed
    engine computes distances with the inline XLA formulation, so the pin
    holds at dist_impl="xla" (bit-exactness is per-backend; the Pallas
    kernel's parity with the oracle is covered to f32 tolerance above).
    """
    idx, rng = small_index
    n = idx.n
    B = 32
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    L = rng.integers(0, n - 64, B).astype(np.int32)
    R = (L + rng.integers(8, 64, B)).astype(np.int32)

    got = idx.search_ranks(q, L, R, k=10, ef=48, expand_width=1,
                           dist_impl="xla", edge_impl="xla")

    @functools.partial(jax.jit, static_argnames=("ef", "k"))
    def ref_search(vec, nbrs, qj, Lj, Rj, *, ef, k):
        entries = search_mod.range_entry_ids(Lj, jnp.minimum(Rj, n - 1), n)
        ok = (entries >= Lj[:, None]) & (entries <= Rj[:, None])
        entries = jnp.where(ok, entries, -1)

        def nbr_fn(u):
            return edge_select.select_edges_batch(
                nbrs, u, Lj, Rj, logn=idx.logn, m_out=idx.m, skip_layers=True
            )

        return search_ref.beam_search_reference(
            vec, qj, entries, nbr_fn, ef=ef, k=k
        )

    # decode for the reference: under the CI storage legs the engine reads
    # codec tables (bf16 / Int8Vectors / SplitNeighbors) and expands them
    # to exactly these f32 values in its own distance path
    want = ref_search(
        jnp.asarray(storage_mod.decode_vectors(idx.vectors)),
        jnp.asarray(storage_mod.decode_neighbors(idx.neighbors)),
        jnp.asarray(q), jnp.asarray(L), jnp.asarray(R), ef=48, k=10,
    )
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    gd, wd = np.asarray(got.dists), np.asarray(want.dists)
    assert ((gd == wd) | (np.isinf(gd) & np.isinf(wd))).all()
    np.testing.assert_array_equal(
        np.asarray(got.n_hops), np.asarray(want.n_hops)
    )
    np.testing.assert_array_equal(
        np.asarray(got.n_dists), np.asarray(want.n_dists)
    )


def test_expand_width1_bit_identical_filtered(small_index):
    """Two-list (post-filtering) path: W=1 matches the reference too."""
    idx, rng = small_index
    n = idx.n
    B = 16
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    L = rng.integers(0, n // 2, B).astype(np.int32)
    R = (L + 128).astype(np.int32)

    # both sides read the same decoded f32 tables: this test pins the
    # two-list traversal against the seed engine, not the storage codec
    vec = jnp.asarray(storage_mod.decode_vectors(idx.vectors))
    nbrs_dec = jnp.asarray(storage_mod.decode_neighbors(idx.neighbors))
    got = search_mod.search_filtered(
        vec, nbrs_dec,
        jnp.asarray(q), jnp.asarray(L), jnp.asarray(R),
        mode="post", ef=48, k=10, expand_width=1, dist_impl="xla",
    )

    @functools.partial(jax.jit, static_argnames=("ef", "k"))
    def ref_search(vec, nbrs, qj, Lj, Rj, *, ef, k):
        mid = jnp.clip((Lj + Rj) // 2, 0, n - 1)
        entries = jnp.stack([mid, jnp.zeros_like(mid) + n // 2], axis=1)

        def filt(ids):
            return (ids >= Lj[:, None]) & (ids <= Rj[:, None])

        def nbr_fn(u):
            row = nbrs[jnp.maximum(u, 0), 0, :]
            ok = (row >= 0) & (u >= 0)[:, None]
            return jnp.where(ok, row, -1)

        return search_ref.beam_search_reference(
            vec, qj, entries, nbr_fn, ef=ef, k=k, result_filter_fn=filt,
        )

    want = ref_search(
        vec, nbrs_dec,
        jnp.asarray(q), jnp.asarray(L), jnp.asarray(R), ef=48, k=10,
    )
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    gd, wd = np.asarray(got.dists), np.asarray(want.dists)
    assert ((gd == wd) | (np.isinf(gd) & np.isinf(wd))).all()


def test_expand_width_identical_recall_when_saturating(small_index):
    """On ranges the beam can fully hold, every W reaches the same recall."""
    idx, rng = small_index
    B = 24
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    span = 48  # span < ef: search saturates the range for every W
    L = rng.integers(0, idx.n - span, B).astype(np.int32)
    R = (L + span - 1).astype(np.int32)
    gt, _ = idx.brute_force(q, L, R, k=10)
    recs = {
        w: recall(
            np.asarray(idx.search_ranks(q, L, R, k=10, ef=64,
                                        expand_width=w).ids), gt
        )
        for w in (1, 2, 4, 8)
    }
    assert recs[1] == 1.0
    assert all(r == recs[1] for r in recs.values()), recs


def test_expand_width_recall_holds_on_wide_ranges(small_index):
    """W>1 must not cost recall on ranges wider than the beam."""
    idx, rng = small_index
    B = 32
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    span = 256
    L = rng.integers(0, idx.n - span, B).astype(np.int32)
    R = (L + span - 1).astype(np.int32)
    gt, _ = idx.brute_force(q, L, R, k=10)
    r1 = recall(np.asarray(idx.search_ranks(q, L, R, k=10, ef=64,
                                            expand_width=1).ids), gt)
    r4 = recall(np.asarray(idx.search_ranks(q, L, R, k=10, ef=64,
                                            expand_width=4).ids), gt)
    assert r4 >= r1 - 0.02, (r1, r4)
    assert r4 >= 0.85


def test_expand_width_fewer_iterations(small_index):
    """The point of W>1: same work in fewer while_loop trips (hops/W)."""
    idx, rng = small_index
    B = 16
    q = rng.standard_normal((B, idx.dim)).astype(np.float32)
    L = np.zeros(B, np.int32)
    R = np.full(B, idx.n // 2, np.int32)
    r1 = idx.search_ranks(q, L, R, k=10, ef=64, expand_width=1)
    r4 = idx.search_ranks(q, L, R, k=10, ef=64, expand_width=4)
    # hops count expanded nodes; per-iteration W=4 expands up to 4, so the
    # iteration count (hops ceil-div W) must shrink substantially
    assert np.mean(np.asarray(r4.n_hops)) / 4 < np.mean(np.asarray(r1.n_hops))
