"""Paper Fig. 4 / §5.2.4: gap to Oracle (dedicated graph built from scratch
per query range). The paper finds Oracle <= 2x faster at 0.9 recall; we
measure qps at matched recall on a mixed workload with a small number of
distinct ranges (as the paper does, to keep Oracle builds feasible)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import SearchConfig, baselines

EFS = (16, 48, 96)


def run(quick=False):
    rows = []
    ds = list(common.BENCH_DATASETS)[0]
    index = common.build_index(ds)
    rng = np.random.default_rng(4)
    n = index.n
    # 4 distinct ranges, 24 queries each (paper: 10 ranges x 100 queries)
    n_ranges = 2 if quick else 4
    per = 16 if quick else 24
    Ls, Rs = [], []
    for i in range(n_ranges):
        span = max(n >> rng.integers(0, 6), 64)
        lo = int(rng.integers(0, n - span))
        Ls += [lo] * per
        Rs += [lo + span - 1] * per
    wl = common.Workload(
        "oracle-mixed", np.asarray(Ls, np.int32), np.asarray(Rs, np.int32),
        common.make_workload(index, "mixed", n_queries=n_ranges * per).queries,
    )
    cache: dict = {}
    for ef in EFS[:2] if quick else EFS:
        m = common.measure(
            lambda q, L, R, k, _ef=ef: index.search_ranks(
                q, L, R, k=k, config=SearchConfig(ef=_ef)
            ), wl, index,
        )
        rows.append(("fig4", ds, "iRangeGraph", ef,
                     round(m["qps"], 1), round(m["recall"], 4)))
        m = common.measure(
            lambda q, L, R, k, _ef=ef: baselines.oracle_search(
                index, q, L, R, k=k, config=SearchConfig(ef=_ef),
                cache=cache
            ), wl, index,
        )
        rows.append(("fig4", ds, "Oracle", ef,
                     round(m["qps"], 1), round(m["recall"], 4)))
    return rows


if __name__ == "__main__":
    common.emit(run())
