"""Render the §Roofline table and §Dry-run summary into EXPERIMENTS.md.

Reads artifacts/dryrun_all.jsonl (+ dryrun_paper.jsonl, + optional
dryrun_variants.jsonl for §Perf) and replaces the <!-- ROOFLINE_TABLE -->
marker. Idempotent.
"""
from __future__ import annotations

import json
import os
import sys

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "artifacts")
EXP = os.path.join(os.path.dirname(ART), "EXPERIMENTS.md")


def load(name):
    p = os.path.join(ART, name)
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return [json.loads(l) for l in f if l.strip()]


def fmt_cell(r):
    if r.get("status") == "skipped":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | "
                f"{r['reason'][:58]} |")
    if r.get("status") != "ok":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | ERROR | "
                f"{r.get('error', '')[:58]} |")
    if "t_compute" not in r:
        return None
    uf = r.get("useful_flop_frac")
    mb = r.get("microbatches", "")
    note = f"mb={mb}" if mb and mb != 1 else ""
    bpd = r.get("bytes_per_device")
    bpd = f"{bpd / 1e9:.1f}" if bpd else "—"
    return (
        f"| {r['arch']} | {r['shape']} | {r['t_compute']:.2e} | "
        f"{r['t_memory']:.2e} | {r['t_collective']:.2e} | {bpd} | "
        f"{r['bottleneck']} ({(uf or 0):.2f}) | {note} |"
    )


def main():
    recs = load("dryrun_all.jsonl") + load("dryrun_paper.jsonl")
    single = [r for r in recs if r.get("mesh") == "16x16"]
    multi = [r for r in recs if r.get("mesh") == "2x16x16"]

    lines = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) |"
        " GB/dev | bottleneck (useful-FLOP frac) | notes |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in single:
        row = fmt_cell(r)
        if row:
            lines.append(row)
    n_ok_s = sum(1 for r in single if r.get("status") == "ok")
    n_skip = sum(1 for r in single if r.get("status") == "skipped")
    n_err = sum(1 for r in single if r.get("status") == "error")
    n_ok_m = sum(1 for r in multi if r.get("status") == "ok")
    lines.append("")
    lines.append(
        f"Single-pod 16x16: **{n_ok_s} compiled**, {n_skip} skipped "
        f"(policy), {n_err} errors. Multi-pod 2x16x16: **{n_ok_m} "
        f"compiled** (same skip policy). Full records: "
        f"`artifacts/dryrun_all.jsonl`."
    )
    table = "\n".join(lines)

    with open(EXP) as f:
        doc = f.read()
    marker = "<!-- ROOFLINE_TABLE -->"
    done = "<!-- ROOFLINE_DONE -->"
    if marker in doc:
        doc = doc.replace(marker, table + "\n" + done)
    elif done in doc:  # re-render: replace the previously generated block
        head = doc.index("| arch | shape |")
        end = doc.index(done) + len(done)
        doc = doc[:head] + table + "\n" + done + doc[end:]
    else:
        print("marker missing; appending", file=sys.stderr)
        doc += "\n" + table + "\n" + done
    with open(EXP, "w") as f:
        f.write(doc)
    print(f"rendered {n_ok_s}+{n_ok_m} cells into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
