"""CI bench-gate: keep the committed kernel perf records honest.

Compares the ``--smoke`` runs the CI job just produced
(``artifacts/BENCH_hotpath_smoke.json``, ``artifacts/BENCH_build_smoke.json``,
``artifacts/BENCH_serve_slo_smoke.json``) against the committed full-shape
records (``BENCH_hotpath.json``, ``BENCH_build.json``,
``BENCH_serve_slo.json``) and gates on two kinds of drift:

  * **shape / correctness — hard fail** (exit 1): a smoke artifact is
    missing or unparseable (the benchmark crashed), its schema lost a
    required section (a refactor silently dropped a measurement), a
    fused-vs-baseline speedup is non-finite, the hot-path record lost its
    ``autotune`` picks (the block-size autotuner stopped measuring or
    recording), the build benchmark's
    backend-parity check reported a divergence, the compact-storage
    section regressed — footprint ratio above ``--max-footprint-ratio``
    (default 0.55), |recall@10 delta| above ``--max-recall-delta``
    (default 0.01), or neighbor-codec ids not bit-identical — a quantized
    codec regressed (``_check_codecs``: int8 total ratio above
    ``--max-int8-ratio`` 0.35, PQ navigation ratio above
    ``--max-pq-nav-ratio`` 0.30 or total above ``--max-pq-total-ratio``
    0.40, |rerank recall@10 delta| above ``--max-recall-delta`` — checked
    on the committed full record AND the smoke run, the latter against the
    looser ``--max-smoke-recall-delta``) — or the
    executor compile gate tripped: any post-warmup compile, or more
    compiled programs than the declared ``configs x batch_buckets x
    k_buckets`` grid — or the serving SLO record shows lost requests or
    post-warmup compiles (``_check_slo``). All of these are deterministic,
    so they hard-fail even on shared runners.
  * **timing — soft warn** (exit 0, GitHub warning annotation): a smoke
    fused-vs-baseline ratio regressed more than ``--tolerance`` (default
    25%) relative to the committed record, or an autotuner pick drifted
    from the committed one (picks are min-of-iters timings on pinned probe
    shapes, so they legitimately move across hosts). Smoke shapes are tiny
    and shared runners are noisy, so timing only hard-fails under
    ``--strict`` (for dedicated hardware).

Baselines come from the committed records' ``smoke_ref`` section — the
same-shape ratios written by ``hotpath.py --smoke --update-smoke-ref`` /
``buildpath.py --smoke --update-smoke-ref`` on the dev host (full,
non-smoke re-runs carry the section forward) — falling back to the
full-shape ratio when a record predates it.

Usage: ``python benchmarks/ci_gate.py [--tolerance 0.25] [--strict]``
(run after ``hotpath.py --smoke`` and ``buildpath.py --smoke``).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

ARTIFACTS = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "artifacts"
)

# (committed file, smoke file) -> list of (section, ratio key) to compare.
# Each ratio is a fused-vs-baseline speedup, so the gate is unit-free.
GATES = {
    ("BENCH_hotpath.json", "BENCH_hotpath_smoke.json"): [
        ("expansion_step", "speedup"),
        ("edge_select_step", "speedup"),
        ("hop_fused", "speedup"),
        ("serve_latency", "small_batch_speedup"),
    ],
    ("BENCH_build.json", "BENCH_build_smoke.json"): [
        (None, "prune_speedup_best"),
    ],
    # serving SLO record: no speedup ratios — gated by _check_slo instead
    ("BENCH_serve_slo.json", "BENCH_serve_slo_smoke.json"): [],
}


def _load(path, errors):
    if not os.path.exists(path):
        errors.append(f"missing artifact {path}")
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        errors.append(f"unreadable artifact {path}: {e}")
        return None


def _ratio(doc, section, key, label, errors):
    node = doc if section is None else doc.get(section)
    if not isinstance(node, dict) or key not in node:
        errors.append(f"{label}: required key {section or ''}.{key} missing")
        return None
    v = node[key]
    if not isinstance(v, (int, float)) or not math.isfinite(v) or not v > 0:
        errors.append(f"{label}: {section or ''}.{key} = {v!r} not a "
                      "positive finite ratio")
        return None
    return float(v)


def _baseline(committed, section, key, label, errors):
    """Committed reference ratio for a smoke measurement.

    Prefers the record's ``smoke_ref`` section (same tiny shapes as the CI
    smoke run, measured on the dev host at commit time) and falls back to
    the full-shape ratio — comparable in kind, noisier across shapes."""
    ref = committed.get("smoke_ref")
    rkey = f"{section}.{key}" if section else key
    if isinstance(ref, dict) and isinstance(ref.get(rkey), (int, float)) \
            and math.isfinite(ref[rkey]) and ref[rkey] > 0:
        return float(ref[rkey])
    return _ratio(committed, section, key, label, errors)


def _check_storage(smoke, name, args, errors):
    """Compact-storage gate: deterministic, so every violation is hard.

    The footprint ratio is pure arithmetic over array dtypes and the codec
    bit-identity is integer-exact — runner noise cannot move them — and the
    recall delta at the pinned smoke config is reproducible, so all three
    hard-fail (unlike the timing ratios above).
    """
    sf = smoke.get("storage_footprint")
    if not isinstance(sf, dict):
        errors.append(f"{name}: storage_footprint section missing")
        return
    ratio = sf.get("footprint_ratio")
    if not isinstance(ratio, (int, float)) or not math.isfinite(ratio):
        errors.append(f"{name}: storage_footprint.footprint_ratio "
                      f"= {ratio!r} not a finite ratio")
    elif ratio > args.max_footprint_ratio:
        errors.append(
            f"{name}: compact/f32 footprint ratio {ratio:.3f} exceeds "
            f"{args.max_footprint_ratio} (compact storage stopped paying "
            "for itself)")
    else:
        print(f"ok: {name} footprint ratio {ratio:.3f} "
              f"<= {args.max_footprint_ratio}")
    delta = sf.get("recall_delta")
    if not isinstance(delta, (int, float)) or not math.isfinite(delta):
        errors.append(f"{name}: storage_footprint.recall_delta "
                      f"= {delta!r} not finite")
    elif abs(delta) > args.max_recall_delta:
        errors.append(
            f"{name}: compact recall@10 delta {delta:+.4f} exceeds "
            f"±{args.max_recall_delta}")
    else:
        print(f"ok: {name} compact recall delta {delta:+.4f}")
    if sf.get("neighbor_codec_ids_identical") is not True:
        errors.append(
            f"{name}: int16/int32 neighbor codecs returned different ids")
    if "neighbor_codec_ids_identical_split" in sf \
            and sf.get("neighbor_codec_ids_identical_split") is not True:
        errors.append(
            f"{name}: split/int32 neighbor codecs returned different ids")


def _check_codecs(doc, name, args, errors):
    """Quantized-codec gate (DESIGN.md §9): deterministic, hard.

    int8 must hold total footprint <= ``--max-int8-ratio`` (0.35); PQ must
    hold the *navigation* footprint (vectors + neighbors + attrs, what the
    hot path touches) <= ``--max-pq-nav-ratio`` (0.30) and the total
    including its rerank sidecar <= ``--max-pq-total-ratio`` (0.40). Both
    must keep |recall@10 delta| (with rerank) <= ``--max-recall-delta``.
    Applied to the committed full record AND the fresh smoke run — the
    ratios are arithmetic over dtypes and the recall config is pinned, so
    runner noise cannot move them. Exception: the recall-delta cap on
    *smoke* records is ``--max-smoke-recall-delta`` (0.05) rather than
    the full-bench 0.01 — the smoke workload is 16 queries (recall
    quantum 1/160) on a tiny max-recall dataset, so the tight cap is not
    measurable there; the loose one still trips when the rerank wiring
    breaks (PQ without rerank sits ~0.28 below baseline).
    """
    sf = doc.get("storage_footprint")
    if not isinstance(sf, dict):
        return  # section-missing already reported for the smoke artifact
    checks = [
        ("int8", "footprint_ratio", args.max_int8_ratio),
        ("pq", "nav_footprint_ratio", args.max_pq_nav_ratio),
        ("pq", "footprint_ratio", args.max_pq_total_ratio),
    ]
    for tag, key, cap in checks:
        leg = sf.get(tag)
        if not isinstance(leg, dict):
            errors.append(f"{name}: storage_footprint.{tag} leg missing")
            continue
        v = leg.get(key)
        if not isinstance(v, (int, float)) or not math.isfinite(v):
            errors.append(f"{name}: {tag}.{key} = {v!r} not finite")
        elif v > cap:
            errors.append(
                f"{name}: {tag} {key} {v:.3f} exceeds {cap} (the codec "
                "stopped paying for itself)")
        else:
            print(f"ok: {name} {tag} {key} {v:.3f} <= {cap}")
    recall_cap = (args.max_smoke_recall_delta if doc.get("smoke")
                  else args.max_recall_delta)
    for tag in ("int8", "pq"):
        leg = sf.get(tag)
        if not isinstance(leg, dict):
            continue
        delta = leg.get("recall_delta")
        if not isinstance(delta, (int, float)) or not math.isfinite(delta):
            errors.append(f"{name}: {tag}.recall_delta = {delta!r} "
                          "not finite")
        elif abs(delta) > recall_cap:
            errors.append(
                f"{name}: {tag} recall@10 delta {delta:+.4f} exceeds "
                f"±{recall_cap} (rerank stopped holding the "
                "recall floor)")
        else:
            print(f"ok: {name} {tag} recall delta {delta:+.4f} "
                  f"<= ±{recall_cap}")


_AUTOTUNE_KINDS = ("hop", "gather_dist", "gather_dist_codec",
                   "edge_select", "prune")


def _check_autotune(smoke, committed, name, errors, warnings):
    """Autotuner-record gate: schema is hard, pick drift is soft.

    A missing/malformed ``autotune`` section means the benchmark stopped
    measuring (or recording) the block-size picks — hard fail, like any
    dropped section. A *changed* pick only warns: picks are min-of-iters
    timings on pinned probe shapes, so they legitimately move across hosts
    and runner load.
    """
    at = smoke.get("autotune")
    picks = at.get("picks") if isinstance(at, dict) else None
    if not isinstance(picks, dict):
        errors.append(f"{name}: autotune section missing or malformed")
        return
    missing = [k for k in _AUTOTUNE_KINDS
               if not isinstance(picks.get(k), dict) or not picks[k]]
    if missing:
        errors.append(f"{name}: autotune picks missing for {missing}")
        return
    print(f"ok: {name} autotune picks recorded for "
          f"{len(_AUTOTUNE_KINDS)} kernels")
    ref = (committed.get("autotune") or {}).get("picks")
    if not isinstance(ref, dict):
        return  # committed record predates the autotuner
    for kind in _AUTOTUNE_KINDS:
        want, got = ref.get(kind), picks.get(kind)
        if want is not None and got != want:
            warnings.append(
                f"{name} autotune pick drift for {kind}: smoke {got} vs "
                f"committed {want}")


def _check_serve(smoke, name, errors):
    """Executor compile-count gate: deterministic, so violations are hard.

    A warmed executor must serve its mixed workload with zero post-warmup
    compiles, and the total program count can never exceed the declared
    ``len(configs) * len(batch_buckets) * len(k_buckets)`` grid — if either
    moves, a refactor broke the compile-cache keying or the bucket math.
    """
    sl = smoke.get("serve_latency")
    if not isinstance(sl, dict):
        errors.append(f"{name}: serve_latency section missing")
        return
    pwc = sl.get("post_warmup_compiles")
    if not isinstance(pwc, int):
        errors.append(f"{name}: serve_latency.post_warmup_compiles "
                      f"= {pwc!r} not an int")
    elif pwc != 0:
        errors.append(
            f"{name}: {pwc} post-warmup compiles (a warmed executor must "
            "serve its declared grid from cache)")
    else:
        print(f"ok: {name} zero post-warmup compiles")
    compiles, max_programs = sl.get("compiles"), sl.get("max_programs")
    if not isinstance(compiles, int) or not isinstance(max_programs, int) \
            or max_programs < 1:
        errors.append(f"{name}: serve_latency compile accounting missing "
                      f"(compiles={compiles!r}, max_programs="
                      f"{max_programs!r})")
    elif compiles > max_programs:
        errors.append(
            f"{name}: {compiles} compiled programs exceed the "
            f"{max_programs}-program (configs x batch_buckets x k_buckets) "
            "grid")
    else:
        print(f"ok: {name} {compiles} programs <= grid {max_programs}")


def _check_slo(smoke, committed, name, args, errors, warnings):
    """Serving-loop SLO gate over ``serve_slo.py --smoke`` output.

    The exactly-once accounting is deterministic, so it hard-fails: every
    leg (nominal / overload / chaos) must show ``resolved == offered`` and
    ``lost == 0`` — a request that never resolved means a stuck future in
    the async loop — and the executor must report zero post-warmup
    compiles (the loop's batch formation must stay on the warmed grid even
    under shedding and injected faults). The nominal leg must actually
    serve (ok > 0 with a finite p99). Timing-shaped numbers — nominal p99
    and overload shed rate vs the committed ``smoke_ref`` — only warn
    (hard under ``--strict``), like the kernel speedup ratios above.
    """
    legs = ("nominal", "overload", "chaos")
    for leg in legs:
        doc = smoke.get(leg)
        if not isinstance(doc, dict):
            errors.append(f"{name}: {leg} leg missing")
            continue
        offered, resolved = doc.get("offered"), doc.get("resolved")
        lost = doc.get("lost")
        if not isinstance(offered, int) or not isinstance(resolved, int) \
                or not isinstance(lost, int):
            errors.append(f"{name}: {leg} outcome accounting missing "
                          f"(offered={offered!r}, resolved={resolved!r}, "
                          f"lost={lost!r})")
        elif lost != 0 or resolved != offered:
            errors.append(
                f"{name}: {leg} leg lost requests ({offered} offered, "
                f"{resolved} resolved) — every submit() must settle with "
                "exactly one terminal outcome")
        else:
            print(f"ok: {name} {leg} resolved {resolved}/{offered}")
    serve = smoke.get("serve")
    pwc = serve.get("post_warmup_compiles") if isinstance(serve, dict) \
        else None
    if not isinstance(pwc, int):
        errors.append(f"{name}: serve.post_warmup_compiles = {pwc!r} "
                      "not an int")
    elif pwc != 0:
        errors.append(
            f"{name}: {pwc} post-warmup compiles (the async loop's batch "
            "formation left the warmed bucket grid)")
    else:
        print(f"ok: {name} zero post-warmup compiles")
    nominal = smoke.get("nominal")
    if isinstance(nominal, dict):
        ok, p99 = nominal.get("ok"), nominal.get("p99_ms")
        if not isinstance(ok, int) or ok <= 0:
            errors.append(f"{name}: nominal leg served nothing (ok={ok!r})")
        elif not isinstance(p99, (int, float)) or not math.isfinite(p99):
            errors.append(f"{name}: nominal p99_ms = {p99!r} not finite")
        else:
            ref = committed.get("smoke_ref") or {}
            want = ref.get("nominal.p99_ms")
            if isinstance(want, (int, float)) and math.isfinite(want) \
                    and want > 0:
                rel = p99 / want - 1.0
                line = (f"{name} nominal.p99_ms: smoke {p99:.1f}ms vs "
                        f"committed {want:.1f}ms ({rel:+.0%})")
                if rel > args.slo_p99_tolerance:
                    warnings.append(line)
                else:
                    print("ok:", line)
        shed_ref = (committed.get("smoke_ref") or {}).get(
            "overload.shed_rate")
        overload = smoke.get("overload")
        got_shed = overload.get("shed_rate") if isinstance(overload, dict) \
            else None
        if isinstance(shed_ref, (int, float)) \
                and isinstance(got_shed, (int, float)):
            delta = got_shed - shed_ref
            line = (f"{name} overload.shed_rate: smoke {got_shed:.2f} vs "
                    f"committed {shed_ref:.2f} ({delta:+.2f})")
            if abs(delta) > args.slo_shed_tolerance:
                warnings.append(line)
            else:
                print("ok:", line)


# The replint findings baseline (lint_baseline.json, DESIGN.md §10) may
# only ever SHRINK: every entry is a justified, fenced violation (the
# seed-vestigial module fence), and new findings must be fixed or
# argued into the baseline in review — at which point this constant
# moves in the same commit, making growth a reviewable act instead of
# an accretion.
MAX_LINT_BASELINE_ENTRIES = 33


def _check_lint_baseline(errors):
    path = os.path.join(os.path.dirname(ARTIFACTS), "lint_baseline.json")
    if not os.path.exists(path):
        errors.append("lint_baseline.json: missing (replint baseline)")
        return
    try:
        with open(path, encoding="utf-8") as f:
            entries = json.load(f).get("entries", [])
    except (OSError, json.JSONDecodeError) as e:
        errors.append(f"lint_baseline.json: unparseable ({e})")
        return
    if len(entries) > MAX_LINT_BASELINE_ENTRIES:
        errors.append(
            f"lint_baseline.json grew to {len(entries)} entries "
            f"(max {MAX_LINT_BASELINE_ENTRIES}): fix the new findings "
            f"instead of baselining them, or justify the growth by "
            f"raising MAX_LINT_BASELINE_ENTRIES in this file in the "
            f"same commit"
        )
    for e in entries:
        if not str(e.get("reason", "")).strip():
            errors.append(
                f"lint_baseline.json: entry {e.get('key')!r} has no "
                f"reason — every baselined finding carries a one-line "
                f"justification"
            )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="max relative speedup regression before warning")
    ap.add_argument("--strict", action="store_true",
                    help="timing regressions fail instead of warning "
                         "(dedicated hardware only)")
    ap.add_argument("--max-footprint-ratio", type=float, default=0.55,
                    help="max compact/f32 nbytes ratio (hard fail)")
    ap.add_argument("--max-recall-delta", type=float, default=0.01,
                    help="max |recall@10 drift| under compact storage "
                         "(hard fail)")
    ap.add_argument("--max-int8-ratio", type=float, default=0.35,
                    help="max int8/f32 total footprint ratio (hard fail)")
    ap.add_argument("--max-pq-nav-ratio", type=float, default=0.30,
                    help="max PQ/f32 navigation footprint ratio — vectors "
                         "+ neighbors + attrs, no rerank sidecar "
                         "(hard fail)")
    ap.add_argument("--max-pq-total-ratio", type=float, default=0.40,
                    help="max PQ/f32 total footprint ratio incl. the "
                         "rerank sidecar (hard fail)")
    ap.add_argument("--max-smoke-recall-delta", type=float, default=0.05,
                    help="codec recall-delta cap applied to smoke records "
                         "(16-query workload: the full-bench 0.01 is below "
                         "the smoke recall quantum; this still trips when "
                         "the rerank wiring breaks)")
    ap.add_argument("--slo-p99-tolerance", type=float, default=1.0,
                    help="max relative nominal-p99 regression vs smoke_ref "
                         "before warning (latency on shared runners is very "
                         "noisy, so the default is loose)")
    ap.add_argument("--slo-shed-tolerance", type=float, default=0.35,
                    help="max |overload shed-rate drift| vs smoke_ref "
                         "before warning")
    args = ap.parse_args(argv)

    errors: list[str] = []
    warnings: list[str] = []

    _check_lint_baseline(errors)

    for (committed_name, smoke_name), keys in GATES.items():
        committed = _load(os.path.join(ARTIFACTS, committed_name), errors)
        smoke = _load(os.path.join(ARTIFACTS, smoke_name), errors)
        if committed is None or smoke is None:
            continue
        # correctness flags are hard: a parity divergence is a real bug
        if smoke.get("parity") is False or committed.get("parity") is False:
            errors.append(f"{smoke_name}: backend parity check failed")
        if smoke_name == "BENCH_hotpath_smoke.json":
            _check_storage(smoke, smoke_name, args, errors)
            _check_codecs(smoke, smoke_name, args, errors)
            _check_codecs(committed, committed_name, args, errors)
            _check_serve(smoke, smoke_name, errors)
            _check_autotune(smoke, committed, smoke_name, errors, warnings)
        if smoke_name == "BENCH_serve_slo_smoke.json":
            _check_slo(smoke, committed, smoke_name, args, errors, warnings)
        for section, key in keys:
            want = _baseline(committed, section, key, committed_name, errors)
            got = _ratio(smoke, section, key, smoke_name, errors)
            if want is None or got is None:
                continue
            rel = got / want - 1.0
            line = (f"{smoke_name} {section or 'root'}.{key}: smoke "
                    f"{got:.2f}x vs committed {want:.2f}x ({rel:+.0%})")
            if rel < -args.tolerance:
                warnings.append(line)
            else:
                print("ok:", line)

    for w in warnings:
        print(f"::warning::bench-gate timing regression: {w}")
    for e in errors:
        print(f"::error::bench-gate: {e}")
    if errors:
        print(f"bench-gate: FAIL ({len(errors)} shape/correctness errors)")
        return 1
    if warnings and args.strict:
        print(f"bench-gate: FAIL ({len(warnings)} timing regressions, "
              "--strict)")
        return 1
    print(f"bench-gate: ok ({len(warnings)} timing warnings)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
