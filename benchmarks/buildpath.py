"""Build-path microbenchmark: fused vs legacy construction prune.

The build-side analog of ``hotpath.py``. Two measurements, emitted to
``artifacts/BENCH_build.json``:

  * ``prune_step`` — one batched RNG prune per representative level shape
    (search levels C = m + ef_construction, brute levels
    C = brute_threshold, reverse pass C = 3m), swept over chunk sizes and
    backends: the legacy eager path (XLA gather + full [C, C]
    candidate-candidate matrix + C-step scan, ``core/rng.py``) against the
    fused lazy-column one (``ops.prune`` — ``kernels/ref.py::prune`` off-TPU,
    the Pallas construction-prune kernel on TPU; pass ``--interpret`` to
    force the kernel through the interpreter, orders of magnitude slower,
    only useful as a smoke test). Backends are asserted bit-identical
    before timing; ``parity`` records it for the CI bench-gate.
  * ``build_levels`` — end-to-end ``build_neighbor_table`` per prune
    backend under the production default ``chunk=None`` (the C*d-bytes
    auto-tuner, ``core/build.py::auto_chunk``), recording nodes/sec AND the
    auto-chosen chunk per level (the ``level_times`` hook), so the
    whole-build win, its per-level breakdown, and the tuner's choices get
    the same perf record the hop side has.

Usage: ``PYTHONPATH=src python benchmarks/buildpath.py [--n 32768]
[--d 64] [--m 16] [--efc 64] [--iters 8] [--chunks 512,2048,4096]
[--no-e2e] [--interpret] [--smoke]``

``--smoke`` shrinks every shape and iteration count to a seconds-long CI
pass that still exercises both measurements (shape or parity regressions in
the build path fail loudly, numbers are meaningless).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp

from common import artifacts_dir, carry_smoke_ref, time_it, update_smoke_ref
from repro.core import build as build_mod
from repro.core import knobs as knobs_mod
from repro.kernels import ops


def _prune_case(rng, table_np, chunk, C, d):
    """Synthetic but build-shaped candidate lists: ~10% invalid slots, one
    duplicated slot per row, distances computed the way build.py does."""
    n = table_np.shape[0]
    ids = rng.integers(0, n, (chunk, C)).astype(np.int32)
    ids[:, -1] = ids[:, 0]                       # duplicates exercise dedup
    ids = np.where(rng.random((chunk, C)) < 0.1, -1, ids).astype(np.int32)
    u = rng.standard_normal((chunk, d)).astype(np.float32)
    cvec = table_np[np.maximum(ids, 0)]
    du = ((cvec - u[:, None, :]) ** 2).sum(-1).astype(np.float32)
    du = np.where(ids < 0, np.inf, du)
    return jnp.asarray(ids), jnp.asarray(du), jnp.asarray(cvec)


def bench_prune_step(n, d, m, efc, brute_threshold, chunks, iters,
                     fused_impl):
    """Per level shape x chunk size: legacy vs fused prune throughput."""
    rng = np.random.default_rng(0)
    table_np = rng.standard_normal((n, d)).astype(np.float32)
    table = jnp.asarray(table_np)
    shapes = [
        ("search", m + efc),
        ("brute", brute_threshold),
        ("reverse", 3 * m),
    ]
    rows = []
    parity = True
    for kind, C in shapes:
        for chunk in chunks:
            ids, du, cvec = _prune_case(rng, table_np, chunk, C, d)

            # the build loop hands the jnp paths its already-gathered
            # candidate vectors; the Pallas path ignores them and DMAs
            # from the table — time the calls the way the build makes them
            def step(ids, du, impl):
                return ops.prune(
                    ids, du, table, m=m, alpha=1.0, fill=True, impl=impl,
                    cand_vecs=cvec,
                )

            # backends must agree before we time them
            want = np.asarray(step(ids, du, "legacy"))
            got = np.asarray(step(ids, du, fused_impl))
            if not np.array_equal(want, got):
                parity = False

            legacy_s = time_it(step, ids, du, "legacy", iters=iters)
            fused_s = time_it(step, ids, du, fused_impl, iters=iters)
            rows.append({
                "kind": kind, "C": int(C), "m": int(m), "d": int(d),
                "chunk": int(chunk), "fused_impl": fused_impl,
                "legacy_us": legacy_s * 1e6, "fused_us": fused_s * 1e6,
                "legacy_nodes_per_s": chunk / legacy_s,
                "fused_nodes_per_s": chunk / fused_s,
                "speedup": legacy_s / fused_s,
            })
    return rows, parity


def bench_build_levels(n, d, m, efc, brute_threshold, fused_impl):
    """End-to-end build per prune backend with per-level nodes/sec.

    Runs ``chunk=None``: each level's prune chunk comes from the C*d-bytes
    auto-tuner and lands in the per-level record (``chunk`` /
    ``chunk_reverse`` keys)."""
    rng = np.random.default_rng(1)
    vectors = rng.standard_normal((n, d)).astype(np.float32)
    out = {}
    tables = {}
    for impl in ("legacy", fused_impl):
        cfg = build_mod.BuildConfig(
            m=m, ef_construction=efc, brute_threshold=brute_threshold,
            prune_impl=impl,
        )
        build_mod.build_neighbor_table(vectors, cfg)  # compile outside timing
        times: list = []
        t0 = time.perf_counter()
        tables[impl] = build_mod.build_neighbor_table(
            vectors, cfg, level_times=times
        )
        total = time.perf_counter() - t0
        out[impl] = {
            "total_s": total,
            "nodes_per_s": n / total,
            "levels": [
                {**lt, "nodes_per_s": n / max(lt["seconds"], 1e-9)}
                for lt in times
            ],
        }
    parity = bool(np.array_equal(tables["legacy"], tables[fused_impl]))
    speedup = out["legacy"]["total_s"] / out[fused_impl]["total_s"]
    return out, parity, speedup


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32_768)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--efc", type=int, default=64)
    ap.add_argument("--brute-threshold", type=int, default=128)
    ap.add_argument("--chunks", type=str, default="512,2048,4096")
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--e2e-n", type=int, default=8192)
    ap.add_argument("--no-e2e", action="store_true",
                    help="skip the end-to-end per-level build sweep")
    ap.add_argument("--interpret", action="store_true",
                    help="force the Pallas kernel through the interpreter")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few iters: a CI regression probe "
                         "for build-path shapes, not a measurement")
    ap.add_argument("--update-smoke-ref", action="store_true",
                    help="with --smoke: record this run's ratios as the "
                         "committed BENCH_build.json smoke_ref baseline "
                         "(what the CI bench-gate compares against)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.n, args.d, args.m, args.efc = 2048, 32, 8, 24
        args.brute_threshold, args.chunks = 32, "256"
        args.iters, args.e2e_n = 2, 1024

    chunks = tuple(int(c) for c in args.chunks.split(","))
    backend = jax.default_backend()
    # resolve the backend the fused side will actually use so the artifact
    # attributes the numbers correctly
    fused_impl = "pallas" if (args.interpret or backend == "tpu") else "xla"

    step_rows, step_parity = bench_prune_step(
        args.n, args.d, args.m, args.efc, args.brute_threshold, chunks,
        args.iters, fused_impl,
    )
    for r in step_rows:
        print(
            f"prune {r['kind']:7s} C={r['C']:3d} chunk={r['chunk']:5d}: "
            f"legacy {r['legacy_us']:.0f}us  fused {r['fused_us']:.0f}us  "
            f"({r['speedup']:.2f}x, {r['fused_nodes_per_s']:.0f} nodes/s)"
        )

    e2e = None
    e2e_parity = True
    e2e_speedup = None
    if not args.no_e2e:
        e2e, e2e_parity, e2e_speedup = bench_build_levels(
            args.e2e_n, args.d, args.m, args.efc, args.brute_threshold,
            fused_impl,
        )
        print(
            f"e2e build n={args.e2e_n}: legacy {e2e['legacy']['total_s']:.2f}s"
            f"  fused {e2e[fused_impl]['total_s']:.2f}s  "
            f"({e2e_speedup:.2f}x)"
        )

    best = max(r["speedup"] for r in step_rows)
    payload = {
        "host": {
            "backend": backend,
            "device": str(jax.devices()[0]),
            "kernel_interpreted": args.interpret and backend != "tpu",
            "smoke": args.smoke,
        },
        "config": {
            "n": args.n, "d": args.d, "m": args.m, "efc": args.efc,
            "brute_threshold": args.brute_threshold, "chunks": list(chunks),
            "iters": args.iters, "fused_impl": fused_impl,
        },
        # the chunk auto-tuner's picks at this run's level shapes (the e2e
        # build below runs chunk=None, so its level records carry these);
        # search levels floor at _SEARCH_CHUNK_FLOOR — report what the
        # build actually uses, not the raw budget math
        "auto_chunk": {
            "budget_mb": knobs_mod.get_int("REPRO_CHUNK_BUDGET_MB"),
            "search": build_mod.resolve_chunk(
                build_mod.BuildConfig(), args.m + args.efc, args.d,
                floor=build_mod._SEARCH_CHUNK_FLOOR),
            "brute": build_mod.resolve_chunk(
                build_mod.BuildConfig(), args.brute_threshold, args.d),
            "reverse": build_mod.resolve_chunk(
                build_mod.BuildConfig(), 3 * args.m, args.d),
        },
        "parity": bool(step_parity and e2e_parity),
        "prune_step": step_rows,
        "prune_speedup_best": best,
        "build_levels": e2e,
        "build_speedup": e2e_speedup,
    }
    if not payload["parity"]:
        print("ERROR: fused and legacy prune backends diverged", flush=True)
    # smoke numbers are meaningless; never clobber the real perf record
    committed = os.path.join(artifacts_dir(), "BENCH_build.json")
    if args.smoke:
        out = os.path.join(artifacts_dir(), "BENCH_build_smoke.json")
        if args.update_smoke_ref:
            if update_smoke_ref(committed, {"prune_speedup_best": best}):
                print("updated smoke_ref in", committed)
            else:
                print("no committed record to update:", committed)
    else:
        out = committed
        payload = carry_smoke_ref(payload, committed)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print("wrote", out)
    return 0 if payload["parity"] else 1


if __name__ == "__main__":
    sys.exit(main())
