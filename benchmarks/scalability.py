"""Paper §5.2.3 scalability: build time, memory, and query metrics vs n."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import BuildConfig, RangeGraphIndex, SearchConfig, recall
from repro.data.pipeline import vector_dataset


def run(quick=False):
    rows = []
    sizes = (2048, 4096) if quick else (2048, 8192, 16384)
    for n in sizes:
        vectors, attrs, qv = vector_dataset(n, 64, seed=7, queries=64)
        t0 = time.perf_counter()
        idx = RangeGraphIndex.build(
            vectors, attrs[:, 0], BuildConfig(m=12, ef_construction=48)
        )
        build_s = time.perf_counter() - t0
        wl = common.make_workload(idx, "mixed", n_queries=64)
        m = common.measure(
            lambda q, L, R, k: idx.search_ranks(
                q, L, R, k=k, config=SearchConfig(ef=64)),
            wl, idx,
        )
        rows.append((
            "scalability", f"n{n}", round(build_s, 2),
            round(idx.nbytes / 1e6, 1), round(m["qps"], 1),
            round(m["recall"], 4),
        ))
    return rows


if __name__ == "__main__":
    common.emit(run())
