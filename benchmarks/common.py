"""Shared benchmark harness: datasets, workloads, qps/recall measurement.

Mirrors the paper's §5.1 setup at CPU-tractable scale: five synthetic
datasets shaped like Table 1 (dims 128..2048), query ranges with fractions
2^0..2^-9 in fixed and mixed workloads, recall@10, qps measured post-compile
over batched queries.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.core import BuildConfig, RangeGraphIndex, SearchConfig, recall
from repro.core import config as config_mod
from repro.data.pipeline import vector_dataset

# CPU-scale stand-ins for the paper's five datasets (Table 1)
BENCH_DATASETS = {
    # name: (n, dim, attr_kind)
    "wit-like": (8192, 128, "uniform"),
    "tripclick-like": (4096, 96, "clustered"),
    "ytaudio-like": (4096, 64, "uniform"),
}
DEFAULT_K = 10
_CACHE: dict = {}


@dataclasses.dataclass
class Workload:
    name: str
    L: np.ndarray
    R: np.ndarray
    queries: np.ndarray


def build_index(name: str, *, m=16, efc=64, seed=0,
                storage=None) -> RangeGraphIndex:
    """``storage``: optional ``StorageConfig`` (compact-storage sweeps)."""
    from repro.core import storage as storage_mod

    # resolve before keying so storage=None and an equal explicit config
    # share one cached build
    storage = storage or storage_mod.default_config()
    key = (name, m, efc, seed, storage)
    if key not in _CACHE:
        n, dim, attr_kind = BENCH_DATASETS[name]
        vectors, attrs, _ = vector_dataset(
            n, dim, seed=seed, attr_kind=attr_kind
        )
        _CACHE[key] = RangeGraphIndex.build(
            vectors, attrs[:, 0],
            BuildConfig(m=m, ef_construction=efc),
            storage=storage,
        )
    return _CACHE[key]


def make_workload(index: RangeGraphIndex, kind: str, n_queries=128,
                  seed=1) -> Workload:
    """kind: 'frac_<i>' (range fraction 2^-i) or 'mixed' (i in 0..9)."""
    n, dim = index.n, index.dim
    rng = np.random.default_rng(seed)
    _, _, qv = vector_dataset(
        n, dim, seed=seed + 100, queries=n_queries
    )
    if kind.startswith("frac_"):
        i = int(kind.split("_")[1])
        spans = np.full(n_queries, max(n >> i, 8))
    else:
        fr = rng.integers(0, 10, n_queries)
        spans = np.maximum(n >> fr, 8)
    L = np.array([rng.integers(0, n - s + 1) for s in spans], np.int32)
    R = (L + spans - 1).astype(np.int32)
    return Workload(kind, L, R, qv)


def make_searcher(index: RangeGraphIndex, *, config=None, ef=None,
                  expand_width=None, dist_impl=None, edge_impl=None,
                  skip_layers=None, k_bucket=None, bucket=True):
    """Bind index + a ``SearchConfig`` into the ``search_fn(q, L, R, k)``
    shape that ``measure`` consumes (the loose kwargs are the deprecation
    shim, resolved onto the config).

    ``bucket`` applies the serve-side k rounding
    (``SearchConfig.bucket_k`` — the same rule ``ServingEngine`` /
    ``SearchExecutor`` use): the requested k rounds up to the next
    ``config.k_bucket`` multiple (clamped to ef) before it reaches the
    jitted search, so mixed-k qps sweeps hit a bounded set of compiled
    programs instead of one retrace per distinct k; results are sliced
    back to the caller's k. Pass ``bucket=False`` to disable."""
    config = config_mod.merge(
        config, ef=ef, expand_width=expand_width, dist_impl=dist_impl,
        edge_impl=edge_impl, skip_layers=skip_layers, k_bucket=k_bucket,
        _warn_where="make_searcher",
    )

    def search_fn(q, L, R, k):
        kb = config.bucket_k(k) if bucket else k
        res = index.search_ranks(q, L, R, k=kb, config=config)
        if kb != k:
            res = res._replace(ids=res.ids[:, :k], dists=res.dists[:, :k])
        return res

    return search_fn


def time_it(fn, *args, iters=50, warmup=2):
    """Mean seconds per call, post-compile (the one timing loop both perf
    benchmarks use, so their records stay comparable)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def measure(search_fn, wl: Workload, index, *, k=DEFAULT_K, warmup=True):
    """Returns dict(qps, recall, mean_dists). search_fn(q, L, R, k) -> res."""
    gt, _ = index.brute_force(wl.queries, wl.L, wl.R, k=k)
    if warmup:  # compile outside the timed region
        search_fn(wl.queries[:8], wl.L[:8], wl.R[:8], k)
    t0 = time.perf_counter()
    res = search_fn(wl.queries, wl.L, wl.R, k)
    ids = np.asarray(res.ids)
    dt = time.perf_counter() - t0
    return {
        "qps": len(wl.queries) / dt,
        "recall": recall(ids, gt),
        "mean_dists": float(np.mean(np.asarray(res.n_dists))),
    }


def emit(rows, header=("name", "us_per_call", "derived")):
    """Print the assignment's ``name,us_per_call,derived`` CSV."""
    for r in rows:
        print(",".join(str(x) for x in r))


def artifacts_dir():
    d = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "artifacts")
    os.makedirs(d, exist_ok=True)
    return d


def carry_smoke_ref(payload: dict, committed_path: str) -> dict:
    """Preserve the committed record's ``smoke_ref`` on a full re-run.

    ``smoke_ref`` holds fused-vs-baseline ratios measured at *smoke* shapes
    — the same-shape baselines ``ci_gate.py`` compares CI smoke runs
    against. A full benchmark run measures different shapes, so it must not
    drop the section; refresh it explicitly with ``--smoke
    --update-smoke-ref``."""
    import json

    if os.path.exists(committed_path):
        try:
            with open(committed_path) as f:
                old = json.load(f)
        except (OSError, json.JSONDecodeError):
            return payload
        if isinstance(old.get("smoke_ref"), dict):
            payload.setdefault("smoke_ref", old["smoke_ref"])
    return payload


def update_smoke_ref(committed_path: str, refs: dict) -> bool:
    """Write this smoke run's ratios into the committed record's
    ``smoke_ref`` section (the ``--update-smoke-ref`` flag). Returns False
    when there is no committed record to update."""
    import json

    if not os.path.exists(committed_path):
        return False
    with open(committed_path) as f:
        doc = json.load(f)
    doc["smoke_ref"] = {k: round(float(v), 4) for k, v in refs.items()}
    with open(committed_path, "w") as f:
        json.dump(doc, f, indent=2)
    return True
