"""Poisson load-generator SLO benchmark for the async serving loop.

Drives ``serve/loop.py::AsyncServingEngine`` with open-loop Poisson
arrivals (exponential inter-arrival gaps — requests keep arriving whether
or not the server keeps up, unlike a closed benchmark loop) and records
the latency/outcome distribution per leg, emitted to
``artifacts/BENCH_serve_slo.json``:

  * ``nominal``  — target QPS at ~half the measured full-batch capacity:
    the steady-state SLO numbers (p50/p99 of served requests).
  * ``overload`` — ~4x capacity against the bounded queue: admission
    control and deadline shedding take over; the interesting numbers are
    the shed/timeout/reject rates and that p99 of what IS served stays
    bounded (that is the whole point of deadline-aware serving).
  * ``chaos``    — overload plus fault injection (``serve/faults.py``:
    latency spikes, flush errors, queue-full bursts): the soak proof that
    every request still resolves with exactly one terminal outcome.

Every leg hard-records ``offered == resolved`` (no lost or stuck
requests) and the executor's post-warmup compile count (0 — the loop
serves entirely from the AOT-warmed grid). ``benchmarks/ci_gate.py``
hard-fails on either, and soft-warns on nominal-p99 / overload-shed-rate
drift against the committed ``smoke_ref``.

Usage: ``PYTHONPATH=src python benchmarks/serve_slo.py [--smoke]
[--update-smoke-ref] [--duration 4.0] [--max-batch 32]``
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys
import time
from collections import Counter

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax

from common import DEFAULT_K, artifacts_dir, build_index, carry_smoke_ref, \
    make_workload, time_it, update_smoke_ref
from repro.core import SearchConfig, ServeConfig
from repro.serve import AsyncServingEngine, DeadlineExceededError, \
    FaultConfig, OverloadedError, Request, SearchExecutor, ShedError, \
    ShutdownError

OUTCOMES = ("ok", "rejected", "shed", "timeout", "shutdown", "failed")


def measure_capacity(executor, wl, k, iters=5) -> float:
    """Queries/sec of a warmed full-batch flush — the denominator the
    nominal/overload QPS targets scale from, so the legs stress the same
    relative load on any host."""
    B = executor.max_batch
    q, L, R = wl.queries[:B], wl.L[:B], wl.R[:B]
    t = time_it(lambda: executor.search_ranks(q, L, R, k=k), iters=iters)
    return B / t


async def run_leg(index, executor, wl, *, qps, duration_s, serve_cfg,
                  faults, k, seed):
    """One open-loop Poisson leg; returns outcome counts + percentiles."""
    eng = AsyncServingEngine(
        index, serve=serve_cfg, executor=executor, faults=faults
    )
    rng = np.random.default_rng(seed)
    nq = len(wl.queries)
    # value-space bounds for the workload's rank ranges
    lo = index.attrs[wl.L]
    hi = index.attrs[wl.R]
    arrivals = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / qps)
        if t >= duration_s:
            break
        arrivals.append(t)
    outcomes: list[tuple[str, float]] = []

    async def fire(j, delay):
        await asyncio.sleep(delay)
        i = j % nq
        t0 = time.monotonic()
        try:
            await eng.submit(Request(wl.queries[i], lo[i], hi[i], k=k))
            kind = "ok"
        except OverloadedError:
            kind = "rejected"
        except ShedError:
            kind = "shed"
        except DeadlineExceededError:
            kind = "timeout"
        except ShutdownError:
            kind = "shutdown"
        except Exception:  # noqa: BLE001 — typed flush failures
            kind = "failed"
        outcomes.append((kind, time.monotonic() - t0))

    t_start = time.monotonic()
    await asyncio.gather(*(
        asyncio.create_task(fire(j, a)) for j, a in enumerate(arrivals)
    ))
    await eng.aclose(drain=True)
    wall = time.monotonic() - t_start
    counts = Counter(kind for kind, _ in outcomes)
    ok_lat = np.array([l for kind, l in outcomes if kind == "ok"])
    offered = len(arrivals)
    out = {
        "target_qps": float(qps),
        "duration_s": float(duration_s),
        "offered": offered,
        "resolved": len(outcomes),
        "lost": offered - len(outcomes),   # ci_gate hard-fails != 0
        **{kind: int(counts.get(kind, 0)) for kind in OUTCOMES},
        "shed_rate": counts.get("shed", 0) / max(offered, 1),
        "timeout_rate": counts.get("timeout", 0) / max(offered, 1),
        "reject_rate": counts.get("rejected", 0) / max(offered, 1),
        "achieved_qps": counts.get("ok", 0) / max(wall, 1e-9),
        "p50_ms": float(np.percentile(ok_lat, 50) * 1e3) if len(ok_lat)
        else None,
        "p99_ms": float(np.percentile(ok_lat, 99) * 1e3) if len(ok_lat)
        else None,
        "engine": {kk: v for kk, v in eng.stats.items()
                   if isinstance(v, int)},
    }
    if eng.faults is not None:
        out["injected"] = dict(eng.faults.counts)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ytaudio-like")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--duration", type=float, default=4.0)
    ap.add_argument("--deadline", type=float, default=0.25)
    ap.add_argument("--smoke", action="store_true",
                    help="short legs on a small index: a CI regression "
                         "probe for the serving loop, not a measurement")
    ap.add_argument("--update-smoke-ref", action="store_true",
                    help="with --smoke: record this run's p99/shed-rate as "
                         "the committed BENCH_serve_slo.json smoke_ref")
    args = ap.parse_args(argv)
    if args.smoke:
        args.duration = 1.0
        args.max_batch = 16

    index = build_index(args.dataset)
    cfg = SearchConfig(ef=64, k_bucket=DEFAULT_K)
    executor = SearchExecutor(index, cfg, max_batch=args.max_batch,
                              warmup=False)
    # warm exactly the grid the legs use: every batch bucket at one k
    warmed = executor.warmup(k_buckets=(DEFAULT_K,))
    wl = make_workload(index, "mixed", n_queries=256)
    cap = measure_capacity(executor, wl, DEFAULT_K)
    print(f"capacity ~{cap:.0f} qps (max_batch={args.max_batch}, "
          f"{warmed} programs warmed)")

    # size the queue off measured capacity so that at overload the back of
    # the queue waits ~2x the shed threshold: the shed path (not just
    # admission rejects) is exercised regardless of host speed
    margin = args.deadline / 5
    max_queue = max(4 * args.max_batch,
                    int(2 * cap * (args.deadline - margin)))
    serve_cfg = ServeConfig(
        deadline_s=args.deadline, max_queue=max_queue,
        backpressure="reject", max_wait_s=0.01,
        deadline_margin_s=margin,
    )
    legs = {}
    legs["nominal"] = asyncio.run(run_leg(
        index, executor, wl, qps=0.5 * cap, duration_s=args.duration,
        serve_cfg=serve_cfg, faults=False, k=DEFAULT_K, seed=1,
    ))
    legs["overload"] = asyncio.run(run_leg(
        index, executor, wl, qps=4.0 * cap, duration_s=args.duration,
        serve_cfg=serve_cfg, faults=False, k=DEFAULT_K, seed=2,
    ))
    chaos_faults = FaultConfig(
        kinds=("latency", "flush_error", "queue_full"),
        latency_s=2 * args.deadline, latency_rate=0.1,
        flush_error_rate=0.1, queue_full_rate=0.1, seed=7,
    )
    legs["chaos"] = asyncio.run(run_leg(
        index, executor, wl, qps=4.0 * cap, duration_s=args.duration,
        serve_cfg=serve_cfg, faults=chaos_faults, k=DEFAULT_K, seed=3,
    ))
    for name, leg in legs.items():
        p50 = f"{leg['p50_ms']:.1f}" if leg["p50_ms"] is not None else "-"
        p99 = f"{leg['p99_ms']:.1f}" if leg["p99_ms"] is not None else "-"
        print(
            f"{name}: target {leg['target_qps']:.0f} qps, offered "
            f"{leg['offered']}, ok {leg['ok']} (p50 {p50}ms p99 {p99}ms), "
            f"shed {leg['shed']}, timeout {leg['timeout']}, rejected "
            f"{leg['rejected']}, failed {leg['failed']}, lost {leg['lost']}"
        )

    post_warmup = executor.stats["compiles"] - executor.stats[
        "warmup_compiles"]
    print(f"executor: {executor.stats['compiles']} programs, "
          f"{post_warmup} post-warmup")
    payload = {
        "host": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "smoke": args.smoke,
        },
        "config": {
            "dataset": args.dataset, "max_batch": args.max_batch,
            "duration_s": args.duration, "k": DEFAULT_K,
            "deadline_s": serve_cfg.deadline_s,
            "max_queue": serve_cfg.max_queue,
            "backpressure": serve_cfg.backpressure,
            "max_wait_s": serve_cfg.max_wait_s,
            "deadline_margin_s": serve_cfg.deadline_margin_s,
        },
        "capacity_qps": float(cap),
        **legs,
        "serve": {
            "compiles": int(executor.stats["compiles"]),
            "warmup_compiles": int(executor.stats["warmup_compiles"]),
            "post_warmup_compiles": int(post_warmup),
        },
    }
    committed = os.path.join(artifacts_dir(), "BENCH_serve_slo.json")
    if args.smoke:
        out = os.path.join(artifacts_dir(), "BENCH_serve_slo_smoke.json")
        if args.update_smoke_ref:
            refs = {
                "nominal.p99_ms": legs["nominal"]["p99_ms"],
                "overload.shed_rate": legs["overload"]["shed_rate"],
            }
            if update_smoke_ref(committed, refs):
                print("updated smoke_ref in", committed)
            else:
                print("no committed record to update:", committed)
    else:
        out = committed
        payload = carry_smoke_ref(payload, committed)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print("wrote", out)


if __name__ == "__main__":
    main()
