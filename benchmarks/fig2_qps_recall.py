"""Paper Fig. 2: qps-recall across methods x datasets x workloads.

Sweeps the beam size (ef) for every graph-based method; Pre-filtering is the
exact scan. Emits CSV rows:
  fig2,<dataset>,<workload>,<method>,<ef>,<qps>,<recall>,<mean_dists>
"""
from __future__ import annotations

import functools

import numpy as np

from benchmarks import common
from repro.core import SearchConfig, baselines

EFS = (16, 48, 96)
WORKLOADS = ("frac_2", "frac_8", "mixed")


def _methods(index):
    def irange(q, L, R, k, ef):
        return index.search_ranks(q, L, R, k=k, config=SearchConfig(ef=ef))

    def pre(q, L, R, k, ef):
        return baselines.prefilter(index, q, L, R, k=k)

    return {
        "iRangeGraph": irange,
        "Pre-filtering": pre,
        "Post-filtering": functools.partial(_wrap, baselines.postfilter,
                                            index),
        "In-filtering": functools.partial(_wrap, baselines.infilter, index),
        "SuperPost": functools.partial(_wrap, baselines.super_postfilter,
                                       index),
    }


def _wrap(fn, index, q, L, R, k, ef):
    return fn(index, q, L, R, k=k, config=SearchConfig(ef=ef))


def run(quick=False, n_queries=64):
    rows = []
    datasets = list(common.BENCH_DATASETS)[:2]
    if quick:
        datasets = datasets[:1]
    for ds in datasets:
        index = common.build_index(ds)
        for wl_kind in (WORKLOADS[:2] if quick else WORKLOADS):
            wl = common.make_workload(index, wl_kind, n_queries=n_queries)
            for name, fn in _methods(index).items():
                efs = (64,) if name == "Pre-filtering" else (
                    EFS[:2] if quick else EFS
                )
                for ef in efs:
                    m = common.measure(
                        lambda q, L, R, k, _ef=ef, _fn=fn: _fn(
                            q, L, R, k, _ef
                        ),
                        wl, index,
                    )
                    rows.append((
                        "fig2", ds, wl_kind, name, ef,
                        round(m["qps"], 1), round(m["recall"], 4),
                        round(m["mean_dists"], 1),
                    ))
    return rows


if __name__ == "__main__":
    common.emit(run())
