"""Per-kernel roofline: bytes moved, FLOPs, achieved fraction of host peaks.

The honesty check behind every claimed kernel speedup
(``artifacts/BENCH_hotpath.json``): for each hot-path kernel this measures
the *production dispatch path* (``kernels/ops.py``, so XLA off-TPU and the
Pallas kernels on TPU) at the benchmark shape, pairs the timing with an
analytic count of bytes moved and arithmetic ops, and reports the achieved
fraction of the roofline bound

    t_bound = max(flops / peak_flops, bytes / peak_bw)

where both peaks are *measured* on this host right before the kernel rows
(a big f32 matmul for FLOPs, an out-of-cache elementwise stream for
bandwidth) — no datasheet numbers. ``bottleneck`` says which side of the
roofline the kernel sits on at its arithmetic intensity. For the integer
kernels (edge-select, the hop's dedup/bitset phases) "flops" counts
compare/select VPU ops — the units still cancel in the fraction. A
fraction above 1.0 means the working set stayed cache-resident (the
bandwidth peak is measured out-of-cache), not a broken clock.

Emits ``artifacts/BENCH_roofline.json`` (``BENCH_roofline_smoke.json``
under ``--smoke``) plus the historical CSV rows on stdout.

``--strict`` makes every degraded outcome a non-zero exit: a kernel row
that errored, a non-finite measurement, or (with ``--with-dryrun``)
missing dry-run artifacts. The seed version of this file silently emitted
a placeholder row when artifacts were missing, so a CI perf-gate could
"pass" on an empty roofline; ``--strict`` exists so it can't. The
distributed dry-run table is still available behind ``--with-dryrun``
(reads ``artifacts/dryrun_all.jsonl`` / ``dryrun_paper.jsonl`` produced by
``python -m repro.launch.dryrun --all --both-meshes``).

Usage: ``PYTHONPATH=src python benchmarks/roofline.py [--smoke] [--strict]
[--with-dryrun] [--b 64] [--n 100000] [--d 128] [--m 16] [--iters 20]``
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp

import common
from repro.core import bitset
from repro.kernels import ops


def _best_s(fn, *args, iters=10):
    """Min seconds per call, post-compile (min, not mean: roofline compares
    against a peak, so the least-disturbed iteration is the right sample)."""
    import time

    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def measure_peaks(iters=10):
    """Measured host peaks: f32 matmul FLOP/s and out-of-cache stream GB/s."""
    k = 1024
    a = jnp.ones((k, k), jnp.float32)
    b = jnp.ones((k, k), jnp.float32)
    t = _best_s(jax.jit(lambda a, b: a @ b), a, b, iters=iters)
    peak_flops = 2.0 * k ** 3 / t
    # 128 MiB stream: far past any cache, reads + writes both count
    x = jnp.ones((32 * 1024 * 1024,), jnp.float32)
    t = _best_s(jax.jit(lambda x: x * 1.5 + 0.5), x, iters=iters)
    peak_bw = 2.0 * x.nbytes / t
    return {
        "peak_gflops": peak_flops / 1e9,
        "peak_gbps": peak_bw / 1e9,
        "ridge_intensity_flop_per_byte": peak_flops / peak_bw,
    }


def _mk_problem(B, n, d, M, seed=11):
    """One shared problem at the hotpath benchmark shape."""
    from hotpath import _elemental_table

    rng = np.random.default_rng(seed)
    W, m_out = 4, M
    logn = max(int(np.ceil(np.log2(max(n, 2)))), 1)
    return {
        "B": B, "n": n, "d": d, "M": M, "W": W, "m_out": m_out,
        "logn": logn, "K": (logn + 1) * M,
        "q": jnp.asarray(rng.standard_normal((B, d)), jnp.float32),
        "table": jnp.asarray(rng.standard_normal((n, d)), jnp.float32),
        "nbrs": jnp.asarray(_elemental_table(rng, n, M, logn)),
        "u": jnp.asarray(rng.integers(0, n, (B, W)).astype(np.int32)),
        "L": jnp.asarray(rng.integers(0, n // 2, B * W).astype(np.int32)),
        "gids": jnp.asarray(
            rng.integers(-1, n, (B, W * m_out)).astype(np.int32)),
        "cand_ids": jnp.asarray(
            rng.integers(0, n, (B, 4 * M)).astype(np.int32)),
        "cand_dists": jnp.asarray(rng.random((B, 4 * M)), jnp.float32),
    }


def _kernel_rows(p, iters):
    """(name, run_fn, flops, bytes) per hot-path kernel.

    Byte counts assume every table access misses cache (the tables are the
    benchmark's n-row working set); flops count multiply-adds as 2 and, for
    the integer kernels, compare/select ops as 1 each — coarse by design,
    the fraction is a sanity bound, not a cycle model.
    """
    B, n, d = p["B"], p["n"], p["d"]
    W, m_out, K, logn = p["W"], p["m_out"], p["K"], p["logn"]
    F, WM = B * W, W * m_out
    C = p["cand_ids"].shape[1]
    words = bitset.num_words(n)
    q, table, nbrs = p["q"], p["table"], p["nbrs"]
    u, L, gids = p["u"], p["L"], p["gids"]
    R = L + n // 2 - 1
    vis = bitset.make(B, n)
    exp_ok = jnp.ones((B, W), bool)

    # integer op estimates shared by edge_select and the hop's select phase
    scan_ops = 12 * F * K              # validity: bounds + layer-mask tests
    dedup_ops = 4 * F * K * m_out      # m_out masked-argmin + wipe sweeps

    return [
        (
            "pairwise_dist",
            jax.jit(lambda: ops.pairwise_dist(q, table)),
            2 * B * n * d + 3 * B * n,
            4 * (B * d + n * d + B * n),
        ),
        (
            "gather_dist",
            jax.jit(lambda: ops.gather_dist(q, table, gids)),
            2 * B * WM * d + 3 * B * WM,
            4 * (B * d + B * WM * d + 2 * B * WM),
        ),
        (
            "edge_select",
            jax.jit(lambda: ops.select_edges(
                nbrs, u.reshape(F), L, R, logn=logn, m_out=m_out)),
            scan_ops + dedup_ops,
            4 * (F * K + 3 * F + F * m_out),
        ),
        (
            "hop",
            jax.jit(lambda: ops.hop(
                q, table, nbrs, u, L, R, vis, exp_ok,
                logn=logn, m_out=m_out)),
            scan_ops + dedup_ops + 2 * B * WM * d + 13 * B * WM,
            4 * (F * K + B * WM * d + 2 * B * words + B * d + 3 * B * WM),
        ),
        (
            "prune",
            jax.jit(lambda: ops.prune(
                p["cand_ids"], p["cand_dists"], table, m=p["M"])),
            B * (2 * p["M"] * C * d + 8 * p["M"] * C + 3 * C * C),
            4 * (B * C * d + 2 * B * C + B * p["M"]),
        ),
    ]


def run_kernels(p, peaks, iters):
    rows = []
    pf = peaks["peak_gflops"] * 1e9
    pb = peaks["peak_gbps"] * 1e9
    for name, fn, flops, nbytes in _kernel_rows(p, iters):
        row = {"kernel": name, "flops": int(flops), "bytes": int(nbytes),
               "intensity_flop_per_byte": flops / nbytes}
        try:
            t = _best_s(fn, iters=iters)
        except Exception as e:  # a backend that can't run this op
            row["error"] = f"{type(e).__name__}: {e}"
            rows.append(row)
            continue
        t_bound = max(flops / pf, nbytes / pb)
        row.update({
            "time_us": t * 1e6,
            "achieved_gflops": flops / t / 1e9,
            "achieved_gbps": nbytes / t / 1e9,
            "bound_us": t_bound * 1e6,
            "achieved_fraction": t_bound / t,
            "bottleneck": (
                "compute" if flops / pf >= nbytes / pb else "memory"
            ),
        })
        rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# legacy distributed dry-run table (--with-dryrun)
# ---------------------------------------------------------------------------

def load(path):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def run_dryrun():
    """Rows from the 512-device dry-run artifacts; [] when absent (the
    caller decides whether that is fatal — see ``--strict``)."""
    rows = []
    art = common.artifacts_dir()
    recs = load(os.path.join(art, "dryrun_all.jsonl")) + load(
        os.path.join(art, "dryrun_paper.jsonl")
    )
    for r in recs:
        if r.get("mesh") != "16x16":
            continue
        if r.get("status") == "skipped":
            rows.append(("dryrun", r["arch"], r["shape"], "skipped",
                         r["reason"][:40], "", "", "", ""))
            continue
        if r.get("status") != "ok" or "t_compute" not in r:
            rows.append(("dryrun", r.get("arch"), r.get("shape"),
                         r.get("status"), r.get("error", "")[:40],
                         "", "", "", ""))
            continue
        rows.append((
            "dryrun", r["arch"], r["shape"], r["bottleneck"],
            f"{r['t_compute']:.3e}", f"{r['t_memory']:.3e}",
            f"{r['t_collective']:.3e}",
            f"{r.get('useful_flop_frac') or 0:.3f}",
            r.get("bytes_per_device", ""),
        ))
    return rows


def _csv_rows(rows, failures):
    """Kernel dict rows -> historical CSV tuples, collecting failures."""
    csv = []
    for r in rows:
        if "error" in r:
            failures.append(f"kernel {r['kernel']} errored: {r['error']}")
            csv.append(("roofline", r["kernel"], "error", r["error"][:60],
                        "", "", "", "", ""))
            continue
        if not math.isfinite(r["achieved_fraction"]):
            failures.append(
                f"kernel {r['kernel']} non-finite achieved_fraction")
        csv.append((
            "roofline", r["kernel"], r["bottleneck"],
            f"{r['time_us']:.1f}us", f"{r['flops']:.3e}",
            f"{r['bytes']:.3e}",
            f"{r['intensity_flop_per_byte']:.2f}",
            f"{r['achieved_gbps']:.2f}GB/s",
            f"{r['achieved_fraction']:.3f}",
        ))
    return csv


def run(quick=False):
    """Aggregator entry (``benchmarks/run.py``): kernel roofline rows, plus
    the dry-run table when its artifacts exist (placeholder row when not —
    the standalone CLI's ``--strict`` is where that becomes fatal)."""
    peaks = measure_peaks(iters=3)
    p = _mk_problem(8, 4096, 32, 8) if quick \
        else _mk_problem(64, 100_000, 128, 16)
    failures: list[str] = []
    csv = _csv_rows(run_kernels(p, peaks, 3 if quick else 10), failures)
    dr = run_dryrun()
    if dr:
        csv.extend(dr)
    else:
        csv.append(("dryrun", "no-dryrun-artifacts",
                    "run python -m repro.launch.dryrun --all first",
                    "", "", "", "", "", ""))
    return csv


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--b", type=int, default=64)
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few iters; writes the _smoke "
                         "artifact (numbers are a schema probe, not a "
                         "measurement)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on any errored/placeholder row "
                         "(so a perf-gate cannot pass on an empty or "
                         "broken roofline)")
    ap.add_argument("--with-dryrun", action="store_true",
                    help="append the distributed dry-run table (requires "
                         "the dryrun artifacts)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.b, args.n, args.d, args.m = 8, 4096, 32, 8
        args.iters = 3

    failures = []
    peaks = measure_peaks(iters=max(3, args.iters // 2))
    print(f"host peaks: {peaks['peak_gflops']:.1f} GFLOP/s  "
          f"{peaks['peak_gbps']:.1f} GB/s  "
          f"(ridge {peaks['ridge_intensity_flop_per_byte']:.1f} flop/B)")
    if not all(math.isfinite(v) and v > 0 for v in peaks.values()):
        failures.append(f"non-finite host peaks: {peaks}")

    p = _mk_problem(args.b, args.n, args.d, args.m)
    rows = run_kernels(p, peaks, args.iters)
    csv = _csv_rows(rows, failures)

    dryrun_rows = None
    if args.with_dryrun:
        dryrun_rows = run_dryrun()
        if not dryrun_rows:
            failures.append(
                "dry-run artifacts missing (run python -m "
                "repro.launch.dryrun --all --both-meshes first)")
            csv.append(("dryrun", "no-dryrun-artifacts",
                        "run python -m repro.launch.dryrun --all first",
                        "", "", "", "", "", ""))
        else:
            csv.extend(dryrun_rows)

    common.emit(csv)

    payload = {
        "host": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "smoke": args.smoke,
        },
        "config": {"B": args.b, "n": args.n, "d": args.d, "M": args.m,
                   "iters": args.iters},
        "peaks": peaks,
        "kernels": rows,
    }
    if dryrun_rows is not None:
        payload["dryrun"] = [list(r) for r in dryrun_rows]
    name = "BENCH_roofline_smoke.json" if args.smoke \
        else "BENCH_roofline.json"
    out = os.path.join(common.artifacts_dir(), name)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print("wrote", out)

    if failures:
        for msg in failures:
            print(f"roofline: {msg}", file=sys.stderr)
        if args.strict:
            print(f"roofline: FAIL ({len(failures)} degraded rows, "
                  "--strict)", file=sys.stderr)
            return 1
        print(f"roofline: {len(failures)} degraded rows (pass --strict "
              "to fail on these)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
