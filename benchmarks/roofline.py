"""Roofline table reader: renders §Roofline from the dry-run artifacts.

Reads ``artifacts/dryrun_all.jsonl`` + ``artifacts/dryrun_paper.jsonl``
(produced by ``python -m repro.launch.dryrun --all --both-meshes --out ...``)
and emits the per-cell terms as CSV. Run the dry-run first; this module
never builds 512-device meshes itself.
"""
from __future__ import annotations

import json
import os

from benchmarks import common


def load(path):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def run(quick=False):
    rows = []
    art = common.artifacts_dir()
    recs = load(os.path.join(art, "dryrun_all.jsonl")) + load(
        os.path.join(art, "dryrun_paper.jsonl")
    )
    for r in recs:
        if r.get("mesh") != "16x16":
            continue
        if r.get("status") == "skipped":
            rows.append(("roofline", r["arch"], r["shape"], "skipped",
                         r["reason"][:40], "", "", "", ""))
            continue
        if r.get("status") != "ok" or "t_compute" not in r:
            rows.append(("roofline", r.get("arch"), r.get("shape"),
                         r.get("status"), r.get("error", "")[:40],
                         "", "", "", ""))
            continue
        rows.append((
            "roofline", r["arch"], r["shape"], r["bottleneck"],
            f"{r['t_compute']:.3e}", f"{r['t_memory']:.3e}",
            f"{r['t_collective']:.3e}",
            f"{r.get('useful_flop_frac') or 0:.3f}",
            r.get("bytes_per_device", ""),
        ))
    if not rows:
        rows.append(("roofline", "no-dryrun-artifacts",
                     "run python -m repro.launch.dryrun --all first",
                     "", "", "", "", "", ""))
    return rows


if __name__ == "__main__":
    common.emit(run())
