"""Beam-search hot-path microbenchmark: fused vs reference expansion step.

Two measurements, emitted to ``artifacts/BENCH_hotpath.json``:

  * ``expansion_step`` — one beam-search hop in isolation at the acceptance
    shape (B=64, n=100k, d=128 by default): the seed formulation (dense
    ``bool[B, n]`` visited + XLA ``[B, M, d]`` gather + einsum) against the
    fused one (packed uint32 bitset + ``ops.gather_dist``). On TPU the fused
    side runs the Pallas gather-distance kernel; off-TPU it runs the XLA
    reference distance with the packed bitset (pass ``--interpret`` to force
    the kernel through the interpreter — orders of magnitude slower, only
    useful as a smoke test).
  * ``search_sweep`` — end-to-end ``search_ranks`` qps/recall over
    ``expand_width`` in {1, 2, 4, 8} on a CPU-tractable index, giving future
    PRs a perf trajectory.

Usage: ``PYTHONPATH=src python benchmarks/hotpath.py [--no-sweep] [--b 64]
[--n 100000] [--d 128] [--m 16] [--iters 50]``
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp

from common import DEFAULT_K, artifacts_dir, build_index, make_searcher, \
    make_workload, measure
from repro.core import bitset
from repro.core.search import _pairdist
from repro.kernels import ops


def time_it(fn, *args, iters=50, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_expansion_step(B, n, d, M, iters, dist_impl):
    """One hop: visited test+mark and neighbor distances for [B, M] ids."""
    rng = np.random.default_rng(0)
    vectors = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    nbr = jnp.asarray(rng.integers(0, n, (B, M)).astype(np.int32))

    @jax.jit
    def seed_step(visited, q, nbr):
        nvalid = nbr >= 0
        b = jnp.arange(B)[:, None]
        seen = visited[b, jnp.maximum(nbr, 0)]
        nvalid &= ~seen
        visited = visited.at[b, jnp.maximum(nbr, 0)].max(nvalid)
        nx = vectors[jnp.maximum(nbr, 0)]                  # [B, M, d] in HBM
        nd = jnp.where(nvalid, _pairdist(q, nx, "l2"), jnp.inf)
        return visited, nd

    @jax.jit
    def fused_step(bits, q, nbr):
        bits, seen = bitset.test_and_set(bits, nbr, nbr >= 0)
        nvalid = (nbr >= 0) & ~seen
        nd = ops.gather_dist(
            q, vectors, jnp.where(nvalid, nbr, -1), impl=dist_impl
        )
        return bits, nd

    dense = jnp.zeros((B, n), bool)
    bits = bitset.make(B, n)
    seed_s = time_it(seed_step, dense, q, nbr, iters=iters)
    fused_s = time_it(fused_step, bits, q, nbr, iters=iters)
    return {
        "seed_us": seed_s * 1e6,
        "fused_us": fused_s * 1e6,
        "speedup": seed_s / fused_s,
        "visited_state_bytes": {
            "dense": int(B * n),
            "bitset": int(B * bitset.num_words(n) * 4),
        },
    }


def bench_search_sweep(widths=(1, 2, 4, 8)):
    index = build_index("wit-like")
    wl = make_workload(index, "mixed", n_queries=128)
    rows = []
    for w in widths:
        fn = make_searcher(index, ef=64, expand_width=w)
        r = measure(fn, wl, index, k=DEFAULT_K)
        rows.append({"expand_width": w, **{k: float(v) for k, v in r.items()}})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--b", type=int, default=64)
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--iters", type=int, default=50)
    ap.add_argument("--no-sweep", action="store_true",
                    help="skip the end-to-end expand_width sweep")
    ap.add_argument("--interpret", action="store_true",
                    help="force the Pallas kernel through the interpreter")
    args = ap.parse_args(argv)

    backend = jax.default_backend()
    # resolve the backend the fused side will actually use so the artifact
    # attributes the numbers correctly
    dist_impl = "pallas" if (args.interpret or backend == "tpu") else "xla"
    kernel_interpreted = args.interpret and backend != "tpu"

    step = bench_expansion_step(
        args.b, args.n, args.d, args.m, args.iters, dist_impl
    )
    print(
        f"expansion step B={args.b} n={args.n} d={args.d} M={args.m}: "
        f"seed {step['seed_us']:.1f}us  fused {step['fused_us']:.1f}us  "
        f"({step['speedup']:.2f}x)"
    )

    sweep = None
    if not args.no_sweep:
        sweep = bench_search_sweep()
        for row in sweep:
            print(
                f"expand_width={row['expand_width']}: "
                f"qps={row['qps']:.1f} recall={row['recall']:.3f} "
                f"mean_dists={row['mean_dists']:.0f}"
            )

    payload = {
        "host": {
            "backend": backend,
            "device": str(jax.devices()[0]),
            "kernel_interpreted": kernel_interpreted,
        },
        "config": {
            "B": args.b, "n": args.n, "d": args.d, "M": args.m,
            "iters": args.iters, "dist_impl": dist_impl,
        },
        "expansion_step": step,
        "search_sweep": sweep,
    }
    out = os.path.join(artifacts_dir(), "BENCH_hotpath.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print("wrote", out)


if __name__ == "__main__":
    main()
