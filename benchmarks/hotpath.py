"""Beam-search hot-path microbenchmark: fused vs reference hop pieces.

Three measurements, emitted to ``artifacts/BENCH_hotpath.json``:

  * ``expansion_step`` — one beam-search hop in isolation at the acceptance
    shape (B=64, n=100k, d=128 by default): the seed formulation (dense
    ``bool[B, n]`` visited + XLA ``[B, M, d]`` gather + einsum) against the
    fused one (packed uint32 bitset + ``ops.gather_dist``). On TPU the fused
    side runs the Pallas gather-distance kernel; off-TPU it runs the XLA
    reference distance with the packed bitset (pass ``--interpret`` to force
    the kernel through the interpreter — orders of magnitude slower, only
    useful as a smoke test).
  * ``edge_select_step`` — one batched edge improvisation for a [B*W]
    frontier at the same shape: the historical stable-argsort formulation
    against the sort-free one (equality-matrix dedup + masked argmin top-m,
    ``kernels/ref.py::select_edges`` / the Pallas edge-selection kernel on
    TPU).
  * ``hop_fused`` — one WHOLE beam-search hop, three ways: the seed
    composition (argsort edge selection + dense ``bool[B, n]`` visited +
    HBM gather/einsum distances, three separate launches), today's
    composed dispatch (``ops.hop(impl="composed")``, still three
    launches), and the fused ``ops.hop`` (one launch: the Pallas
    megakernel on TPU, the one-program jnp hop off-TPU). ``speedup`` is
    fused vs the seed composition — the same seed-vs-fused framing as
    ``expansion_step``, now over the full hop; ``launch_fusion_speedup``
    is fused vs the modern composed three-launch path and isolates the
    launch fusion alone (≈1.0 off-TPU, where both sides compile to
    near-identical XLA; the VMEM-residency win needs the real TPU).
    Composed and fused outputs are asserted identical before timing.
  * ``autotune`` — measured block-size / pipeline-depth picks for every
    Pallas kernel (``kernels/autotune.py``) on pinned probe shapes; the
    winners are installed process-wide (they feed the ``ops.py`` Pallas
    branches) and recorded here so ``ci_gate.py`` can flag pick drift.
  * ``search_sweep`` — end-to-end ``search_ranks`` qps/recall over
    ``expand_width`` in {1, 2, 4, 8} and over ``edge_impl`` backends on a
    CPU-tractable index, giving future PRs a perf trajectory.
  * ``storage_footprint`` — the compact-storage trade (``core/storage.py``):
    real ``nbytes`` of the same index under f32/int32 vs bf16/int16 storage
    (the two tables every hop reads, so the ratio is also the hop-bandwidth
    ratio), qps + recall@10 at both, and a bit-identity probe of the
    neighbor codec. ``ci_gate.py`` hard-fails when the ratio exceeds 0.55
    or the recall delta exceeds 0.01.
  * ``serve_latency`` — the executor layer (``serve/executor.py``): warmed
    small-batch flush latency with power-of-two batch buckets vs the
    historical always-pad-to-max executor, plus a mixed-workload
    compile-count probe (random k <= ef, random batch sizes, two configs).
    ``ci_gate.py`` hard-fails any post-warmup compile or a program count
    above the ``len(configs) * len(batch_buckets) * len(k_buckets)`` grid.

Usage: ``PYTHONPATH=src python benchmarks/hotpath.py [--no-sweep] [--b 64]
[--n 100000] [--d 128] [--m 16] [--iters 50] [--smoke]``

``--smoke`` shrinks every shape and iteration count to a seconds-long CI
pass that still exercises all three measurements (shape regressions in the
hot path fail loudly, numbers are meaningless).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp

from common import DEFAULT_K, artifacts_dir, build_index, carry_smoke_ref, \
    make_searcher, make_workload, measure, time_it, update_smoke_ref
from repro.core import SearchConfig, bitset
from repro.core import edge_select as edge_select_mod
from repro.core import storage as storage_mod
from repro.core.search import _pairdist
from repro.kernels import autotune as autotune_mod
from repro.kernels import edge_select as edge_select_k
from repro.kernels import gather_distance as gather_k
from repro.kernels import hop as hop_k
from repro.kernels import ops
from repro.kernels import prune as prune_k


def _elemental_table(rng, n, m, logn):
    """Synthetic but structurally valid elemental-graph table: every edge
    stays inside its layer's segment, 15% of slots are -1 padding."""
    layers = logn + 1
    base = rng.integers(0, n, (n, layers, m)).astype(np.int32)
    u_ids = np.arange(n, dtype=np.int32)[:, None, None]
    shift = (logn - np.arange(layers, dtype=np.int32))[None, :, None]
    seg_lo = (u_ids >> shift) << shift
    seg_size = (1 << shift)
    nbrs = np.minimum(seg_lo + base % seg_size, n - 1).astype(np.int32)
    nbrs[rng.random(nbrs.shape) < 0.15] = -1
    return nbrs


def bench_expansion_step(B, n, d, M, iters, dist_impl):
    """One hop: visited test+mark and neighbor distances for [B, M] ids."""
    rng = np.random.default_rng(0)
    vectors = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    nbr = jnp.asarray(rng.integers(0, n, (B, M)).astype(np.int32))

    @jax.jit
    def seed_step(visited, q, nbr):
        nvalid = nbr >= 0
        b = jnp.arange(B)[:, None]
        seen = visited[b, jnp.maximum(nbr, 0)]
        nvalid &= ~seen
        visited = visited.at[b, jnp.maximum(nbr, 0)].max(nvalid)
        nx = vectors[jnp.maximum(nbr, 0)]                  # [B, M, d] in HBM
        nd = jnp.where(nvalid, _pairdist(q, nx, "l2"), jnp.inf)
        return visited, nd

    @jax.jit
    def fused_step(bits, q, nbr):
        bits, seen = bitset.test_and_set(bits, nbr, nbr >= 0)
        nvalid = (nbr >= 0) & ~seen
        nd = ops.gather_dist(
            q, vectors, jnp.where(nvalid, nbr, -1), impl=dist_impl
        )
        return bits, nd

    dense = jnp.zeros((B, n), bool)
    bits = bitset.make(B, n)
    seed_s = time_it(seed_step, dense, q, nbr, iters=iters)
    fused_s = time_it(fused_step, bits, q, nbr, iters=iters)
    return {
        "seed_us": seed_s * 1e6,
        "fused_us": fused_s * 1e6,
        "speedup": seed_s / fused_s,
        "visited_state_bytes": {
            "dense": int(B * n),
            "bitset": int(B * bitset.num_words(n) * 4),
        },
    }


def bench_edge_select(B, n, m, iters, edge_impl):
    """One batched edge improvisation for a [B*W] frontier: the historical
    argsort formulation vs the sort-free one (the half of the hop PR 2
    fuses). Ids are bit-identical; only the formulation changes."""
    rng = np.random.default_rng(1)
    logn = max(int(np.ceil(np.log2(max(n, 2)))), 1)
    layers = logn + 1
    nbrs = _elemental_table(rng, n, m, logn)

    F = B * 4  # the flattened [B*W] frontier at the default expand_width
    us = jnp.asarray(rng.integers(0, n, F).astype(np.int32))
    L = jnp.asarray(rng.integers(0, n // 2, F).astype(np.int32))
    R = jnp.asarray((np.asarray(L) + n // 2 - 1).astype(np.int32))
    nbrs = jnp.asarray(nbrs)

    @jax.jit
    def argsort_step(us, L, R):
        return edge_select_mod.select_edges_batch(
            nbrs, us, L, R, logn=logn, m_out=m
        )

    @jax.jit
    def sortfree_step(us, L, R):
        return ops.select_edges(
            nbrs, us, L, R, logn=logn, m_out=m, impl=edge_impl
        )

    # sanity: formulations must agree before we time them
    a = np.asarray(argsort_step(us, L, R))
    b = np.asarray(sortfree_step(us, L, R))
    assert np.array_equal(a, b), "edge-selection formulations diverged"

    argsort_s = time_it(argsort_step, us, L, R, iters=iters)
    sortfree_s = time_it(sortfree_step, us, L, R, iters=iters)
    return {
        "frontier": int(F),
        "K": int(layers * m),
        "logn": int(logn),
        "argsort_us": argsort_s * 1e6,
        "sortfree_us": sortfree_s * 1e6,
        "speedup": argsort_s / sortfree_s,
        "edge_impl": edge_impl,
    }


def bench_hop_fused(B, n, d, M, iters, hop_impl):
    """One whole beam-search hop (edge improvisation + visited test-and-set
    + gather-distance), three ways — see the module docstring. Integer
    outputs of the composed and fused paths are asserted bit-identical and
    distances allclose before anything is timed."""
    rng = np.random.default_rng(3)
    W, m_out = 4, M
    logn = max(int(np.ceil(np.log2(max(n, 2)))), 1)
    layers = logn + 1
    nbrs = jnp.asarray(_elemental_table(rng, n, M, logn))
    table = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    u = jnp.asarray(rng.integers(0, n, (B, W)).astype(np.int32))
    L = jnp.asarray(rng.integers(0, n // 2, B * W).astype(np.int32))
    R = L + n // 2 - 1
    vis = bitset.make(B, n)
    dense = jnp.zeros((B, n), bool)
    exp_ok = jnp.ones((B, W), bool)

    # -- seed composition: argsort select / dense visited / HBM gather ------
    @jax.jit
    def seed_select(u):
        return edge_select_mod.select_edges_batch(
            nbrs, u.reshape(B * W), L, R, logn=logn, m_out=m_out
        ).reshape(B, W * m_out)

    @jax.jit
    def seed_visited(dense, nbr, exp_ok):
        pre = (nbr >= 0) & jnp.repeat(exp_ok, m_out, axis=1)
        b = jnp.arange(B)[:, None]
        seen = dense[b, jnp.maximum(nbr, 0)]
        nvalid = pre & ~seen
        dense = dense.at[b, jnp.maximum(nbr, 0)].max(nvalid)
        return dense, nvalid

    @jax.jit
    def seed_gdist(nbr, nvalid):
        nx = table[jnp.maximum(nbr, 0)]                   # [B, WM, d] in HBM
        return jnp.where(nvalid, _pairdist(q, nx, "l2"), jnp.inf)

    def seed_hop(u, exp_ok, dense):
        nbr = seed_select(u)
        dense, nvalid = seed_visited(dense, nbr, exp_ok)
        return nbr, seed_gdist(nbr, nvalid), nvalid, dense

    # -- modern composed dispatch, still three launches ---------------------
    @jax.jit
    def c_select(u):
        return ops.select_edges(
            nbrs, u.reshape(B * W), L, R, logn=logn, m_out=m_out
        ).reshape(B, W * m_out)

    @jax.jit
    def c_bitset(vis, nbr, exp_ok):
        pre = (nbr >= 0) & jnp.repeat(exp_ok, m_out, axis=1)
        vis, seen = bitset.test_and_set(vis, nbr, pre)
        return vis, pre & ~seen

    @jax.jit
    def c_gdist(nbr, nvalid):
        return ops.gather_dist(q, table, jnp.where(nvalid, nbr, -1))

    def composed_hop(u, exp_ok, vis):
        nbr = c_select(u)
        vis, nvalid = c_bitset(vis, nbr, exp_ok)
        return nbr, c_gdist(nbr, nvalid), nvalid, vis

    # -- fused: one launch --------------------------------------------------
    @jax.jit
    def fused_hop(u, exp_ok, vis):
        return ops.hop(q, table, nbrs, u, L, R, vis, exp_ok,
                       logn=logn, m_out=m_out, impl=hop_impl)

    # parity before timing: composed vs fused must be identical; the seed
    # composition must improvise the same edges (its newly-visited mask is
    # NOT compared — the dense formulation marks in-row duplicate ids
    # visited twice, the exactly-once defect the packed bitset fixed)
    a = seed_hop(u, exp_ok, dense)
    b = composed_hop(u, exp_ok, vis)
    c = fused_hop(u, exp_ok, vis)
    assert np.array_equal(np.asarray(a[0]), np.asarray(b[0])), \
        "seed vs composed edge ids diverged"
    for x, y, what in zip(b, c, ("nbr", "ndist", "nvalid", "visited")):
        x, y = np.asarray(x), np.asarray(y)
        if x.dtype.kind == "f":
            ok = np.allclose(x, y, rtol=1e-5, atol=1e-5, equal_nan=True)
        else:
            ok = np.array_equal(x, y)
        assert ok, f"composed vs fused hop diverged on {what}"

    seed_s = time_it(seed_hop, u, exp_ok, dense, iters=iters)
    composed_s = time_it(composed_hop, u, exp_ok, vis, iters=iters)
    fused_s = time_it(fused_hop, u, exp_ok, vis, iters=iters)
    return {
        "W": int(W), "m_out": int(m_out), "K": int(layers * M),
        "logn": int(logn), "hop_impl": hop_impl,
        "seed_us": seed_s * 1e6,
        "composed_us": composed_s * 1e6,
        "fused_us": fused_s * 1e6,
        "speedup": seed_s / fused_s,
        "launch_fusion_speedup": composed_s / fused_s,
    }


def bench_autotune(iters=3, interpret=False):
    """Measure block-size / pipeline-depth picks for every Pallas kernel on
    pinned probe shapes and install the winners process-wide.

    The probe shapes are deliberately identical between full and ``--smoke``
    runs so the ``autotune.picks`` section is comparable across artifacts —
    ``ci_gate.py`` hard-fails a missing/malformed section and soft-warns on
    pick drift (timing is host-dependent). Off-TPU the kernels run under
    the interpreter, so the picks only matter for interpret-mode runs; on a
    TPU host the same probe drives the real Mosaic kernels.
    """
    B, n, d, m = 8, 4096, 32, 8
    W, m_out, C = 4, 8, 64
    rng = np.random.default_rng(7)
    logn = max(int(np.ceil(np.log2(max(n, 2)))), 1)
    nbrs = jnp.asarray(_elemental_table(rng, n, m, logn))
    table = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    u = jnp.asarray(rng.integers(0, n, (B, W)).astype(np.int32))
    L = jnp.asarray(rng.integers(0, n // 2, B * W).astype(np.int32))
    R = L + n // 2 - 1
    vis = bitset.make(B, n)
    exp_ok = jnp.ones((B, W), bool)
    gids = jnp.asarray(rng.integers(-1, n, (B, W * m_out)).astype(np.int32))
    cand_ids = jnp.asarray(rng.integers(0, n, (B, C)).astype(np.int32))
    cand_dists = jnp.asarray(rng.random((B, C)), jnp.float32)

    # codec probe: the int8 table changes the DMA row dtype and adds the
    # in-register dequant, so its tile/window optimum is tuned separately
    table_i8 = storage_mod.as_device(storage_mod.encode_vectors(
        np.asarray(table), storage_mod.StorageConfig.int8()))

    runs = {
        "hop": lambda **p: hop_k.hop_kernel_call(
            q, table, nbrs, u, L, R, vis, exp_ok, logn=logn, m_out=m_out,
            interpret=interpret, **p),
        "gather_dist": lambda **p: gather_k.gather_distance_kernel_call(
            q, table, gids, interpret=interpret, **p),
        "gather_dist_codec": lambda **p: gather_k.gather_distance_kernel_call(
            q, table_i8, gids, interpret=interpret, **p),
        "edge_select": lambda **p: edge_select_k.edge_select_kernel_call(
            nbrs, u.reshape(B * W), L, R, logn=logn, m_out=m_out,
            interpret=interpret, **p),
        "prune": lambda **p: prune_k.prune_kernel_call(
            cand_ids, cand_dists, table, m=m, interpret=interpret, **p),
    }
    records = {}
    for kind, run in runs.items():
        rec = autotune_mod.autotune(kind, run, iters=iters)
        autotune_mod.set_pick(kind, rec["best"])
        records[kind] = rec
    return {
        "probe": {"B": B, "n": n, "d": d, "m": m, "W": W, "m_out": m_out,
                  "C": C, "logn": int(logn), "iters": int(iters)},
        "interpret": bool(interpret),
        "picks": autotune_mod.all_picks(),
        "records": records,
    }


def bench_search_sweep(widths=(1, 2, 4, 8), edge_impls=("argsort", "xla"),
                      dataset="wit-like", n_queries=128):
    index = build_index(dataset)
    wl = make_workload(index, "mixed", n_queries=n_queries)
    auto_edge = ops.default_impl("edge")
    rows = []
    for w in widths:
        fn = make_searcher(index, config=SearchConfig(ef=64, expand_width=w))
        r = measure(fn, wl, index, k=DEFAULT_K)
        # label the resolved backend so rows are self-describing
        rows.append({"expand_width": w, "edge_impl": auto_edge,
                     **{k: float(v) for k, v in r.items()}})
    for impl in edge_impls:
        if impl == auto_edge:
            continue  # already measured as the width-4 auto row
        fn = make_searcher(
            index, config=SearchConfig(ef=64, edge_impl=impl))
        r = measure(fn, wl, index, k=DEFAULT_K)
        rows.append({
            "expand_width": 4, "edge_impl": impl,
            **{k: float(v) for k, v in r.items()},
        })
    return rows


def bench_storage_footprint(dataset="wit-like", n_queries=64):
    """Footprint + hot-path cost of compact storage vs the f32 baseline.

    The compact index is the SAME graph re-encoded (``astype_storage``), so
    the recall delta isolates bf16 vector quantization, and neighbor ids are
    checked bit-identical across the int16/int32 codecs (the decode is a
    plain -1-preserving widening cast).
    """
    # pin the baseline storage explicitly so a REPRO_STORAGE=compact CI leg
    # still measures compact against true f32/int32
    idx32 = build_index(dataset, storage=storage_mod.StorageConfig())
    compact = storage_mod.StorageConfig.compact()
    idxc = idx32.astype_storage(compact)
    wl = make_workload(idx32, "mixed", n_queries=n_queries)
    out = {
        "dataset": dataset,
        "f32_bytes": int(idx32.nbytes),
        "compact_bytes": int(idxc.nbytes),
        "footprint_ratio": idxc.nbytes / idx32.nbytes,
        "vector_dtype": str(idxc.vectors.dtype),
        "neighbor_dtype": str(idxc.neighbors.dtype),
        "hop_tables_bytes": {
            "f32": int(idx32.vectors.nbytes + idx32.neighbors.nbytes),
            "compact": int(idxc.vectors.nbytes + idxc.neighbors.nbytes),
        },
    }
    for tag, idx in (("f32", idx32), ("compact", idxc)):
        # ground truth always comes from the f32 index: recall_delta must
        # see quantization-induced loss, not a self-consistent compact gt
        r = measure(make_searcher(idx, config=SearchConfig(ef=64)), wl,
                    idx32, k=DEFAULT_K)
        out[tag] = {k: float(v) for k, v in r.items()}
    out["recall_delta"] = out["compact"]["recall"] - out["f32"]["recall"]
    # int16/split vs int32 neighbor storage with identical vectors: ids must
    # be bit-identical end-to-end (the acceptance criterion ci_gate enforces)
    nq = min(16, len(wl.queries))
    a = idx32.search_ranks(wl.queries[:nq], wl.L[:nq], wl.R[:nq],
                           k=DEFAULT_K, config=SearchConfig(ef=64))
    for codec in ("int16", "split"):
        idxn = idx32.astype_storage(
            storage_mod.StorageConfig(neighbor_dtype=codec)
        )
        b = idxn.search_ranks(wl.queries[:nq], wl.L[:nq], wl.R[:nq],
                              k=DEFAULT_K, config=SearchConfig(ef=64))
        out[f"neighbor_codec_ids_identical_{codec}"] = bool(
            np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
        )
    out["neighbor_codec_ids_identical"] = \
        out["neighbor_codec_ids_identical_int16"]

    # --- quantized vector codecs (DESIGN.md §9): int8 + PQ, fused decode ---
    # Same graph (astype_storage), so the recall delta isolates vector
    # quantization; the rerank pass re-scores the beam's top-r against the
    # sidecar (int8 for pq profiles) inside the jitted search. nav_* counts
    # only what the hot path touches (vectors + neighbors + attrs); the
    # footprint_ratio includes the rerank sidecar. The quantized legs buy
    # their recall back with a deeper beam (ef 64 -> 128; the memory-for-
    # compute trade the codecs exist to make) — lossy navigation distances
    # swap near-ties the wider beam re-covers, and the exact-sidecar
    # rerank then fixes the final ordering. Measured wit-like deltas vs
    # f32@ef=64: int8 -0.005, pq -0.008 (both inside the 0.01 gate);
    # int8's rerank is a no-op (it re-scores the same int8 vectors), pq
    # without rerank sits at ~0.67 recall.
    codec_cfg = {
        "int8": SearchConfig(ef=128),
        "pq": SearchConfig(ef=128, rerank=128),
    }
    for tag, st in (("int8", storage_mod.StorageConfig.int8()),
                    ("pq", storage_mod.StorageConfig.pq())):
        qidx = idx32.astype_storage(st)
        nav_bytes = (storage_mod.table_nbytes(qidx.vectors)
                     + storage_mod.table_nbytes(qidx.neighbors)
                     + qidx.attrs.nbytes)
        leg = {
            "bytes": int(qidx.nbytes),
            "nav_bytes": int(nav_bytes),
            "rerank_bytes": int(storage_mod.table_nbytes(qidx.rerank)),
            "footprint_ratio": qidx.nbytes / idx32.nbytes,
            "nav_footprint_ratio": nav_bytes / idx32.nbytes,
        }
        for mode, cfg in (
            ("plain", SearchConfig(ef=64)),
            ("rerank", codec_cfg[tag]),
        ):
            r = measure(make_searcher(qidx, config=cfg), wl, idx32,
                        k=DEFAULT_K)
            leg[mode] = {k: float(v) for k, v in r.items()}
        leg["recall_delta"] = leg["rerank"]["recall"] - out["f32"]["recall"]
        out[tag] = leg
    return out


def bench_serve_latency(dataset="ytaudio-like", max_batch=64,
                        small_batches=(1, 2, 4, 8), iters=20):
    """Bucketed flushes vs always-pad-to-max on small batches, plus the
    mixed-workload compile-count probe (the ci_gate hard gate).

    Both executors are warmed, so the timings isolate the padded compute:
    the pad-to-max side runs every flush at [max_batch] rows, the bucketed
    side at the next power of two.
    """
    from repro.serve.executor import SearchExecutor

    index = build_index(dataset)
    cfg = SearchConfig(ef=64, k_bucket=DEFAULT_K)
    bucketed = SearchExecutor(index, cfg, max_batch=max_batch, warmup=False)
    padmax = SearchExecutor(index, cfg, max_batch=max_batch,
                            batch_buckets=(max_batch,), warmup=False)
    # warm only what the sweep serves (k=10 at the touched batch buckets):
    # the full-grid warmup is the compile probe below
    small_bbs = sorted({bucketed.batch_bucket(b) for b in small_batches})
    bucketed.warmup(batch_buckets=small_bbs, k_buckets=(DEFAULT_K,))
    padmax.warmup(batch_buckets=(max_batch,), k_buckets=(DEFAULT_K,))
    wl = make_workload(index, "mixed", n_queries=max_batch)
    rows = []
    for B in small_batches:
        q, L, R = wl.queries[:B], wl.L[:B], wl.R[:B]
        tb = time_it(
            lambda q=q, L=L, R=R: bucketed.search_ranks(q, L, R, k=DEFAULT_K),
            iters=iters)
        tp = time_it(
            lambda q=q, L=L, R=R: padmax.search_ranks(q, L, R, k=DEFAULT_K),
            iters=iters)
        rows.append({
            "B": int(B), "bucket": int(bucketed.batch_bucket(B)),
            "bucketed_us": tb * 1e6, "padmax_us": tp * 1e6,
            "speedup": tp / tb,
        })
    # compile-count probe: warmed executor, mixed workload, two configs —
    # zero post-warmup compiles inside the declared grid (hard-gated).
    # The probe has its own small grid (ef=32, max_batch=8) so the full
    # benchmark doesn't pay a 70-program warmup.
    pcfg = SearchConfig(ef=32, k_bucket=DEFAULT_K)
    pcfg2 = pcfg.replace(expand_width=2)
    probe = SearchExecutor(index, pcfg, max_batch=8, warmup=False)
    warm = probe.warmup(configs=(pcfg, pcfg2))
    rng = np.random.default_rng(5)
    for config in (pcfg, pcfg2):
        for _ in range(16):
            B = int(rng.integers(1, probe.max_batch + 1))
            k = int(rng.integers(1, config.ef + 1))
            probe.search_ranks(wl.queries[:B], wl.L[:B], wl.R[:B], k=k,
                               config=config)
    return {
        "dataset": dataset, "max_batch": int(max_batch),
        "batch_buckets": list(bucketed.batch_buckets),
        "k_buckets": list(cfg.k_buckets()),
        "rows": rows,
        # the one unit-free ratio the bench-gate tracks: how much the
        # smallest flush gains from bucketing
        "small_batch_speedup": rows[0]["speedup"],
        "warmup_compiles": int(warm),
        "post_warmup_compiles": int(
            probe.stats["compiles"] - probe.stats["warmup_compiles"]
        ),
        "max_programs": int(probe.program_grid(configs=(pcfg, pcfg2))),
        "compiles": int(probe.stats["compiles"]),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--b", type=int, default=64)
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--iters", type=int, default=50)
    ap.add_argument("--no-sweep", action="store_true",
                    help="skip the end-to-end expand_width sweep")
    ap.add_argument("--interpret", action="store_true",
                    help="force the Pallas kernel through the interpreter")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few iters: a CI regression probe "
                         "for hot-path shapes, not a measurement")
    ap.add_argument("--update-smoke-ref", action="store_true",
                    help="with --smoke: record this run's ratios as the "
                         "committed BENCH_hotpath.json smoke_ref baseline "
                         "(what the CI bench-gate compares against)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.b, args.n, args.d, args.m = 8, 4096, 32, 8
        args.iters = 3

    backend = jax.default_backend()
    # resolve the backend the fused side will actually use so the artifact
    # attributes the numbers correctly
    dist_impl = "pallas" if (args.interpret or backend == "tpu") else "xla"
    edge_impl = "pallas" if (args.interpret or backend == "tpu") else "xla"
    hop_impl = "pallas" if (args.interpret or backend == "tpu") else "xla"
    kernel_interpreted = args.interpret and backend != "tpu"

    # autotune first: the installed picks feed every later Pallas call
    at = bench_autotune(iters=1 if args.smoke else 3,
                        interpret=backend != "tpu")
    print("autotune picks: " + "  ".join(
        f"{k}={v}" for k, v in sorted(at["picks"].items())))

    step = bench_expansion_step(
        args.b, args.n, args.d, args.m, args.iters, dist_impl
    )
    print(
        f"expansion step B={args.b} n={args.n} d={args.d} M={args.m}: "
        f"seed {step['seed_us']:.1f}us  fused {step['fused_us']:.1f}us  "
        f"({step['speedup']:.2f}x)"
    )

    edge = bench_edge_select(args.b, args.n, args.m, args.iters, edge_impl)
    print(
        f"edge select F={edge['frontier']} K={edge['K']}: "
        f"argsort {edge['argsort_us']:.1f}us  "
        f"sort-free {edge['sortfree_us']:.1f}us  ({edge['speedup']:.2f}x)"
    )

    hop = bench_hop_fused(
        args.b, args.n, args.d, args.m, args.iters, hop_impl
    )
    print(
        f"hop fused B={args.b} W={hop['W']} K={hop['K']}: "
        f"seed {hop['seed_us']:.1f}us  composed {hop['composed_us']:.1f}us  "
        f"fused[{hop['hop_impl']}] {hop['fused_us']:.1f}us  "
        f"({hop['speedup']:.2f}x vs seed, "
        f"{hop['launch_fusion_speedup']:.2f}x launch fusion)"
    )

    if args.smoke:
        storage = bench_storage_footprint("ytaudio-like", n_queries=16)
        serve = bench_serve_latency(
            "ytaudio-like", max_batch=16, small_batches=(1, 4), iters=3
        )
    else:
        storage = bench_storage_footprint("wit-like", n_queries=64)
        serve = bench_serve_latency("ytaudio-like")
    for row in serve["rows"]:
        print(
            f"serve flush B={row['B']} (bucket {row['bucket']}): "
            f"bucketed {row['bucketed_us']:.0f}us  "
            f"pad-to-max {row['padmax_us']:.0f}us  ({row['speedup']:.2f}x)"
        )
    print(
        f"serve compile probe: {serve['compiles']} programs "
        f"(grid max {serve['max_programs']}, "
        f"{serve['post_warmup_compiles']} post-warmup)"
    )
    print(
        f"storage {storage['dataset']}: f32 {storage['f32_bytes']/1e6:.2f}MB"
        f" -> compact {storage['compact_bytes']/1e6:.2f}MB "
        f"(ratio {storage['footprint_ratio']:.3f}, "
        f"{storage['vector_dtype']}/{storage['neighbor_dtype']}) "
        f"recall {storage['f32']['recall']:.3f} -> "
        f"{storage['compact']['recall']:.3f} "
        f"qps {storage['f32']['qps']:.1f} -> {storage['compact']['qps']:.1f}"
    )
    for tag in ("int8", "pq"):
        leg = storage[tag]
        print(
            f"storage {tag}: ratio {leg['footprint_ratio']:.3f} "
            f"(nav {leg['nav_footprint_ratio']:.3f}) recall "
            f"{leg['plain']['recall']:.3f} -> {leg['rerank']['recall']:.3f} "
            f"rerank (delta {leg['recall_delta']:+.4f})"
        )

    sweep = None
    if not args.no_sweep:
        if args.smoke:
            sweep = bench_search_sweep(
                widths=(1, 4), edge_impls=("argsort", "xla"),
                dataset="ytaudio-like", n_queries=16,
            )
        else:
            sweep = bench_search_sweep()
        for row in sweep:
            tag = f" edge_impl={row['edge_impl']}" if "edge_impl" in row \
                else ""
            print(
                f"expand_width={row['expand_width']}{tag}: "
                f"qps={row['qps']:.1f} recall={row['recall']:.3f} "
                f"mean_dists={row['mean_dists']:.0f}"
            )

    payload = {
        "host": {
            "backend": backend,
            "device": str(jax.devices()[0]),
            "kernel_interpreted": kernel_interpreted,
            "smoke": args.smoke,
        },
        "config": {
            "B": args.b, "n": args.n, "d": args.d, "M": args.m,
            "iters": args.iters, "dist_impl": dist_impl,
            "edge_impl": edge_impl, "hop_impl": hop_impl,
        },
        "expansion_step": step,
        "edge_select_step": edge,
        "hop_fused": hop,
        "autotune": at,
        "storage_footprint": storage,
        "serve_latency": serve,
        "search_sweep": sweep,
    }
    # smoke numbers are meaningless; never clobber the real perf record
    committed = os.path.join(artifacts_dir(), "BENCH_hotpath.json")
    if args.smoke:
        out = os.path.join(artifacts_dir(), "BENCH_hotpath_smoke.json")
        if args.update_smoke_ref:
            refs = {
                "expansion_step.speedup": step["speedup"],
                "edge_select_step.speedup": edge["speedup"],
                "hop_fused.speedup": hop["speedup"],
                "serve_latency.small_batch_speedup":
                    serve["small_batch_speedup"],
            }
            if update_smoke_ref(committed, refs):
                print("updated smoke_ref in", committed)
            else:
                print("no committed record to update:", committed)
    else:
        out = committed
        payload = carry_smoke_ref(payload, committed)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print("wrote", out)


if __name__ == "__main__":
    main()
