"""Paper Table 3: indexing time — iRangeGraph's bottom-up build vs a
from-scratch flat graph (HNSW stand-in) and the paper's <=3x claim."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import BuildConfig, build_flat_graph, build_neighbor_table
from repro.data.pipeline import vector_dataset


def run(quick=False):
    rows = []
    n, dim = (4096, 64) if quick else (8192, 64)
    vectors, attrs, _ = vector_dataset(n, dim, seed=3)
    order = np.argsort(attrs[:, 0], kind="stable")
    vs = vectors[order]
    cfg = BuildConfig(m=12, ef_construction=48)

    t0 = time.perf_counter()
    build_neighbor_table(vs, cfg)
    t_full = time.perf_counter() - t0

    t0 = time.perf_counter()
    build_flat_graph(vs, cfg)  # root graph only == single-HNSW stand-in
    t_flat = time.perf_counter() - t0

    rows.append(("table3", f"n{n}", "iRangeGraph_s", round(t_full, 2)))
    rows.append(("table3", f"n{n}", "flat_graph_s", round(t_flat, 2)))
    rows.append((
        "table3", f"n{n}", "ratio_vs_single_graph",
        round(t_full / max(t_flat, 1e-9), 2),
    ))
    return rows


if __name__ == "__main__":
    common.emit(run())
