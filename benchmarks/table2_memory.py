"""Paper Table 2: memory footprint per method (index + raw vectors),
including the compact-storage encoding (bf16 vectors + narrow neighbor
ids) and the quantized codecs (int8 / PQ vectors + split segment-offset
neighbor ids, ``core/storage.py``, DESIGN.md §9) of the same index."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import storage as storage_mod


def run(quick=False):
    rows = []
    for ds in list(common.BENCH_DATASETS)[: 1 if quick else None]:
        # pin the baseline: under REPRO_STORAGE=compact the default build
        # would already be compact and compact_over_f32 would report ~1.0
        index = common.build_index(ds, storage=storage_mod.StorageConfig())
        raw = index.vectors.nbytes
        elemental = index.neighbors.nbytes
        n, layers, m = index.neighbors.shape
        rows.append(("table2", ds, "raw_vectors_mb", round(raw / 1e6, 2)))
        rows.append((
            "table2", ds, "iRangeGraph_mb",
            round((raw + elemental + index.attrs.nbytes) / 1e6, 2),
        ))
        compact = index.astype_storage(storage_mod.StorageConfig.compact())
        rows.append((
            "table2", ds, "iRangeGraph_compact_mb",
            round(compact.nbytes / 1e6, 2),
        ))
        rows.append((
            "table2", ds, "compact_over_f32",
            round(compact.nbytes / index.nbytes, 3),
        ))
        for tag, st in (("int8", storage_mod.StorageConfig.int8()),
                        ("pq", storage_mod.StorageConfig.pq())):
            qidx = index.astype_storage(st)
            rows.append((
                "table2", ds, f"iRangeGraph_{tag}_mb",
                round(qidx.nbytes / 1e6, 2),
            ))
            rows.append((
                "table2", ds, f"{tag}_over_f32",
                round(qidx.nbytes / index.nbytes, 3),
            ))
        # single flat graph (Milvus/HNSW-style baseline): one layer of edges
        rows.append((
            "table2", ds, "flat_graph_mb",
            round((raw + elemental / layers) / 1e6, 2),
        ))
        # the O(n^2) dedicated-graph strawman the paper argues against
        rows.append((
            "table2", ds, "oracle_all_ranges_gb(theoretical)",
            round(n * n * m * 4 / 2 / 1e9, 1),
        ))
        rows.append(("table2", ds, "layers", layers))
    return rows


if __name__ == "__main__":
    common.emit(run())
