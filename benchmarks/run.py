"""Benchmark aggregator: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick]`` runs everything and
prints CSV rows (``table,dataset,...``). Individual modules run standalone:
``python -m benchmarks.fig2_qps_recall`` etc. The roofline module reads the
dry-run artifacts (produce them with ``python -m repro.launch.dryrun --all
--both-meshes --out artifacts/dryrun_all.jsonl``).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sweeps (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names")
    args = ap.parse_args()

    from benchmarks import (
        common,
        fig2_qps_recall,
        fig3_ablation,
        fig4_oracle,
        fig5_multiattr,
        roofline,
        scalability,
        table2_memory,
        table3_indexing,
    )

    modules = {
        "fig2": fig2_qps_recall,
        "table2": table2_memory,
        "table3": table3_indexing,
        "fig3": fig3_ablation,
        "fig4": fig4_oracle,
        "fig5": fig5_multiattr,
        "scalability": scalability,
        "roofline": roofline,
    }
    if args.only:
        keep = set(args.only.split(","))
        modules = {k: v for k, v in modules.items() if k in keep}

    print("table,col1,col2,col3,col4,col5,col6,col7,col8")
    for name, mod in modules.items():
        t0 = time.time()
        try:
            rows = mod.run(quick=args.quick)
        except Exception as e:  # noqa: BLE001 — keep the suite going
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            continue
        common.emit(rows)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
