"""Paper Fig. 3 ablation: improvised dedicated graph vs BasicSearch
(segment-decomposition search) and efficient edge selection (skip layers,
iRangeGraph) vs naive (iRangeGraph-)."""
from __future__ import annotations

from benchmarks import common
from repro.core import SearchConfig, baselines

EFS = (32, 96)


def run(quick=False):
    rows = []
    for ds in list(common.BENCH_DATASETS)[: 1 if quick else 2]:
        index = common.build_index(ds)
        wl = common.make_workload(index, "mixed", n_queries=64)
        for ef in EFS[:2] if quick else EFS:
            m = common.measure(
                lambda q, L, R, k, _ef=ef: index.search_ranks(
                    q, L, R, k=k, config=SearchConfig(ef=_ef)
                ), wl, index,
            )
            rows.append(("fig3", ds, "iRangeGraph", ef,
                         round(m["qps"], 1), round(m["recall"], 4)))
            m = common.measure(
                lambda q, L, R, k, _ef=ef: index.search_ranks(
                    q, L, R, k=k,
                    config=SearchConfig(ef=_ef, skip_layers=False)
                ), wl, index,
            )
            rows.append(("fig3", ds, "iRangeGraph-", ef,
                         round(m["qps"], 1), round(m["recall"], 4)))
            m = common.measure(
                lambda q, L, R, k, _ef=ef: baselines.basic_search(
                    index, q, L, R, k=k, config=SearchConfig(ef=_ef)
                ), wl, index,
            )
            rows.append(("fig3", ds, "BasicSearch", ef,
                         round(m["qps"], 1), round(m["recall"], 4)))
    return rows


if __name__ == "__main__":
    common.emit(run())
