"""Paper Fig. 5 / §5.2.5: multi-attribute conjunctive RFANN.

Compares the §4 extension modes: post-filtering, in-filtering, and
iRangeGraph+ (visit out-of-range neighbors with p = exp(-t)), plus
Pre-filtering exact. Workload: range fraction ~2^-2 on each attribute."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import SearchConfig, multiattr

EFS = (32, 96)


def run(quick=False):
    rows = []
    ds = list(common.BENCH_DATASETS)[0]
    index = common.build_index(ds)
    n = index.n
    rng = np.random.default_rng(5)
    attr2 = rng.uniform(0, 1.0, n).astype(np.float32)
    B = 48 if quick else 64
    wl = common.make_workload(index, "frac_2", n_queries=B)
    lo2 = rng.uniform(0, 0.5, B).astype(np.float32)
    hi2 = (lo2 + 0.25).astype(np.float32)

    gt, _ = multiattr.brute_force_multiattr(
        index, attr2, wl.queries, wl.L, wl.R, lo2, hi2, k=10
    )
    import time

    from repro.core.index import recall as recall_fn

    for mode, label in (("post", "iRangeGraph-post"),
                        ("in", "iRangeGraph-in"),
                        ("adaptive", "iRangeGraph+")):
        for ef in EFS[:2] if quick else EFS:
            multiattr.search_multiattr(  # warmup/compile
                index, attr2, wl.queries[:8], wl.L[:8], wl.R[:8],
                lo2[:8], hi2[:8], k=10, mode=mode,
                config=SearchConfig(ef=ef),
            )
            t0 = time.perf_counter()
            res = multiattr.search_multiattr(
                index, attr2, wl.queries, wl.L, wl.R, lo2, hi2,
                k=10, mode=mode, config=SearchConfig(ef=ef),
            )
            ids = np.asarray(res.ids)
            dt = time.perf_counter() - t0
            rows.append((
                "fig5", ds, label, ef, round(B / dt, 1),
                round(recall_fn(ids, gt), 4),
            ))
    # Pre-filtering exact
    t0 = time.perf_counter()
    ids, _ = multiattr.brute_force_multiattr(
        index, attr2, wl.queries, wl.L, wl.R, lo2, hi2, k=10
    )
    dt = time.perf_counter() - t0
    rows.append(("fig5", ds, "Pre-filtering", 0, round(B / dt, 1), 1.0))
    return rows


if __name__ == "__main__":
    common.emit(run())
